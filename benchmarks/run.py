"""Benchmark harness entry point: one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only NAME]
Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (
    engine_overhead, fig1_schedules, fig34_grouping, fig56_matmul_study,
    roofline,
)

SUITES = {
    "fig1": fig1_schedules.run,
    "fig34": fig34_grouping.run,
    "fig56": fig56_matmul_study.run,
    "engine": engine_overhead.run,
    "roofline": roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(SUITES))
    args = ap.parse_args()

    failures = 0
    print("name,us_per_call,derived")
    for name, suite in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            for row, us, derived in suite():
                print(f'{row},{us:.1f},"{derived}"')
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f'{name},FAILED,""')
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
