"""Paper Fig. 1 — execution behaviour of 25 jobs under submission regimes.

Uses the real scheduler's event-driven simulator: *optimal* (25 slots),
*serial* (1 slot), *common* (multi-tenant jitter), and PaPaS *grouped*
(batched dispatch into one allocation).  Reports makespan and scheduler
interaction counts — the quantities the paper's figure contrasts.
"""
from __future__ import annotations

import time

from repro.core import Scheduler, TaskDAG, TaskNode, dispatch_count, makespan

N_JOBS = 25
JOB_SECONDS = 30.0 * 60.0     # ~30 min, as in the paper's §6


def build() -> tuple[TaskDAG, dict[str, float]]:
    dag = TaskDAG()
    for i in range(N_JOBS):
        dag.add(TaskNode(id=f"job{i:02d}", task="netlogo", combo={"run": i}))
    return dag, {f"job{i:02d}": JOB_SECONDS for i in range(N_JOBS)}


def run() -> list[tuple[str, float, dict]]:
    dag, durations = build()
    rows = []
    for policy, slots, delay in [
        ("optimal", N_JOBS, 0.0),
        ("serial", 1, 0.0),
        ("common", 4, 120.0),      # 4 nodes, ~2 min scheduler latency/job
        ("grouped", 4, 0.0),       # PaPaS: one cluster job hosts all tasks
    ]:
        t0 = time.perf_counter_ns()
        ev = Scheduler(slots=slots).simulate(
            dag, durations, policy, queue_delay=delay, seed=0)
        us = (time.perf_counter_ns() - t0) / 1e3
        rows.append((
            f"fig1_{policy}", us,
            {"makespan_s": round(makespan(ev), 1),
             "dispatches": dispatch_count(ev),
             "slots": slots},
        ))
    # derived check: grouped strictly beats common at equal slots
    g = next(r for r in rows if r[0] == "fig1_grouped")[2]["makespan_s"]
    c = next(r for r in rows if r[0] == "fig1_common")[2]["makespan_s"]
    rows.append(("fig1_grouped_speedup_vs_common", 0.0,
                 {"speedup": round(c / g, 3)}))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
