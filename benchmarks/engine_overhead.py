"""Framework-overhead microbenchmarks (the paper's 'lightweight' claim).

PaPaS positions itself as a lightweight user-space tool; these rows
quantify the framework tax: WDL parse time, combinatorial expansion
throughput at growing N_W, DAG build + topological order, provenance
write overhead per task — plus the engine-backend comparison: serial vs
thread-pool vs process-pool makespan on a sleep-task DAG (the paper's
"increasing resource utilization" claim, §4.2/§4.3, measured for real).

The streaming rows quantify the windowed pipeline: startup-to-first-
dispatch for a 10^5-combination study, eager (materialize + build the
whole DAG + v1 journal) vs windowed (index addressing + bounded
admission + v2 journal), and the journal footprint of each format.

The throughput rows quantify the short-task dispatch path: tasks/sec on
10^4 no-op shell tasks through the full study pipeline (render →
dispatch → journal → provenance), thread pool vs persistent worker
lanes vs windowed lanes — compiled templates, gang-style lane batching,
and group-commit recording are what separate the rows.  The
``lane_capture`` row re-runs the lane case with two regex ``capture:``
extractors per task (the results subsystem's whole per-completion tax:
extraction + classification + metric recording).  The per-lever rows
(``lane_mux`` → ``lane_adaptive`` → ``lane_sharded``) re-run the sweep
with the throughput levers enabled one at a time — selector mux alone
(static batch, per-command spools, one journal/DB shard), plus adaptive
batch sizing, plus sharded group commit (= the default stack) — so a
regression names its lever.  ``engine_spawn_*`` microbenches the
``run_subprocess`` spawn paths (``posix_spawn`` vs ``subprocess.run``).

The harness rows quantify the two always-in-the-path seams:
``lane_chaos`` re-runs the lane sweep with an armed fault plan that
SIGKILLs one lane mid-sweep (retried to completion — the chaos
harness's tax when faults actually fire); ``lane_telemetry`` re-runs it
with the telemetry layer armed (spans + counters on every
dispatch/frame/flush) and ``lane_telemetry_off`` with it explicitly
disarmed — the latter measures only the seams' identity checks and is
gated at ≥95% of the recorded floor, the zero-cost-when-off contract.

``--throughput`` runs only these rows, writes them as a JSON artifact
(``BENCH_throughput.json``; override with ``PAPAS_BENCH_OUT``), and
exits nonzero if the lane pool regresses below half the recorded
baseline (the CI floor), loses its ≥5× margin over the thread pool,
capture drops below 80% of the bare-lane floor, or disarmed telemetry
drops below 95% of it.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time
from pathlib import Path

from repro.core import FaultEvent, FaultPlan, InlinePool, LaneWorkerPool, \
    LocalTransport, ParameterStudy, Scheduler, StudyJournal, TaskDAG, \
    TaskNode, Telemetry, make_pool, parse_yaml, run_subprocess

N_SLEEP = 32
SLEEP_S = 0.05
SLOTS = 8

#: recorded lane-pool baseline on the reference box (tasks/sec at 10^4
#: no-op tasks, 8 lanes, full lever stack: selector mux + adaptive
#: batching + sharded group commit + spool reuse).  ``--throughput``
#: fails below half this — a regression gate, not a leaderboard.
LANE_TASKS_PER_SEC_BASELINE = 10_000.0

WDL_SMALL = """
t:
  args:
    a: ["1:10"]
    b: ["1:10"]
  command: run ${args:a} ${args:b}
"""

WDL_LARGE = """
t:
  args:
    a: ["1:40"]
    b: ["1:40"]
    c: ["1:10"]
  command: run ${args:a} ${args:b} ${args:c}
"""


#: 100 × 100 × 10 = 10^5 combinations — large enough that eager
#: materialization dominates, small enough to benchmark its startup.
WDL_HUGE = """
t:
  args:
    a: ["1:100"]
    b: ["1:100"]
    c: ["1:10"]
  command: run ${args:a} ${args:b} ${args:c}
"""


class _FirstDispatch(Exception):
    """Raised by the probe pool at the first submit to stop the run."""


class _ProbePool(InlinePool):
    """Measures startup latency: aborts the engine at the first dispatch,
    so the elapsed time is pure expansion + DAG + journal + scheduling
    setup with zero task execution."""

    def submit(self, token, runner, nodes):
        raise _FirstDispatch


def _first_dispatch_s(study: ParameterStudy, window: int | None) -> float:
    t0 = time.perf_counter()
    try:
        study.run(pool=_ProbePool(), window=window)
    except _FirstDispatch:
        pass
    return time.perf_counter() - t0


def _streaming_rows() -> list[tuple[str, float, dict]]:
    """Startup-to-first-dispatch at 10^5 combos: eager vs windowed."""
    rows = []
    with tempfile.TemporaryDirectory() as root:
        eager = ParameterStudy(parse_yaml(WDL_HUGE), root=root, name="eager")
        n = eager.instance_count()
        eager_s = _first_dispatch_s(eager, window=None)
        windowed = ParameterStudy(parse_yaml(WDL_HUGE), root=root,
                                  name="windowed")
        windowed_s = _first_dispatch_s(windowed, window=64)
        rows.append(("engine_first_dispatch_eager_1e5", eager_s * 1e6,
                     {"n": n, "wall_s": round(eager_s, 3)}))
        rows.append(("engine_first_dispatch_windowed_1e5", windowed_s * 1e6,
                     {"n": n, "window": 64, "wall_s": round(windowed_s, 4)}))
        rows.append(("engine_windowed_startup_speedup", 0.0,
                     {"speedup": round(eager_s / windowed_s, 1),
                      "meets_10x": eager_s / windowed_s >= 10}))

        # journal footprint: v1 carries the instance list, v2 carries
        # range-compressed completed indices — O(N_W) vs O(ranges)
        space = eager.space()
        insts = eager.instances()
        j1 = StudyJournal(Path(root) / "v1.json")
        j1.save(insts, {f"t@{i}" for i in range(n)}, {})
        j2 = StudyJournal(Path(root) / "v2.json")
        j2.save_indexed(space.space_hash(), n, {"t": range(n)}, {})
        v1_bytes = j1.path.stat().st_size
        v2_bytes = j2.path.stat().st_size
        rows.append(("engine_journal_bytes_1e5_complete", 0.0,
                     {"v1": v1_bytes, "v2": v2_bytes,
                      "ratio": round(v1_bytes / v2_bytes)}))
    return rows


#: 10^4 no-op combinations — the NetLogo/BehaviorSpace regime: tasks so
#: short the framework, not the hardware, sets the completion rate.
WDL_NOOP = """
t:
  args:
    i: ["1:10000"]
  command: "true"
"""

#: the no-op sweep with metric capture: the task emits one line (echo is
#: a shell builtin, like ``true`` — no fork) and two regex extractors
#: pull metrics from it per completion.  The delta vs the bare lane row
#: is the whole results-subsystem tax: extraction + classification +
#: metric recording.
WDL_NOOP_CAPTURE = """
t:
  args:
    i: ["1:10000"]
  command: echo "a=1 b=2"
  capture:
    a:
      regex: "a=([0-9]+)"
      required: true
    b: "b=([0-9]+)"
"""


def _throughput_rows() -> list[tuple[str, float, dict]]:
    """tasks/sec at 10^4 no-op shell tasks through the full pipeline
    (compiled-template render → pool dispatch → group-commit journal +
    provenance): thread pool vs persistent lanes vs windowed lanes."""
    rows = []
    tps: dict[str, float] = {}
    with tempfile.TemporaryDirectory() as root:
        cases = [
            ("thread", dict(pool="thread", slots=SLOTS)),
            ("lane", dict(pool="lane", slots=SLOTS)),
            ("windowed_lane", dict(pool="lane", slots=SLOTS, window=256,
                                   keep_results=False)),
            ("lane_capture", dict(pool="lane", slots=SLOTS)),
            # chaos-armed: one lane SIGKILL mid-sweep, retried to
            # completion — the harness's tax when a fault actually fires
            ("lane_chaos", dict(
                pool="lane", slots=SLOTS, max_retries=3,
                retry={"base": 0.01},
                chaos=FaultPlan([FaultEvent("kill_lane", lane=0,
                                            after=50)]).controller())),
            # telemetry-armed: spans + counters on every dispatch,
            # lane frame, and group-commit flush
            ("lane_telemetry", dict(pool="lane", slots=SLOTS,
                                    trace=Telemetry())),
            # telemetry explicitly disarmed (trace=False also shields
            # against a PAPAS_TRACE env leak): the seams' identity
            # checks only — the zero-cost-when-off contract, gated in
            # check_throughput_floor()
            ("lane_telemetry_off", dict(pool="lane", slots=SLOTS,
                                        trace=False)),
        ]
        for label, kwargs in cases:
            wdl = WDL_NOOP_CAPTURE if label == "lane_capture" else WDL_NOOP
            study = ParameterStudy(parse_yaml(wdl), root=root,
                                   name=f"tp_{label}")
            n = study.instance_count()
            done = [0]
            t0 = time.perf_counter()
            study.run(on_result=lambda r: done.__setitem__(0, done[0] + 1),
                      **kwargs)
            wall = time.perf_counter() - t0
            assert done[0] == n, f"{label}: {done[0]}/{n} resolved"
            tps[label] = n / wall
            rows.append((f"engine_throughput_{label}", n / wall,
                         {"tasks": n, "slots": SLOTS,
                          "wall_s": round(wall, 2),
                          "tasks_per_sec": round(n / wall)}))
            if label == "lane":
                # group-commit amortization: appends per actual flush —
                # the 2-opens-2-flushes-per-task world is ~1.0 here
                rows.append(("engine_group_commit_amortization", 0.0,
                             {"journal_appends": study.journal.n_appends,
                              "journal_flushes": study.journal.n_flushes,
                              "db_appends": study.db.n_appends,
                              "db_flushes": study.db.n_flushes,
                              "appends_per_flush": round(
                                  study.journal.n_appends
                                  / max(1, study.journal.n_flushes))}))

        # per-lever attribution: the same sweep with the throughput
        # levers enabled one at a time.  mux = selector front-end only
        # (static batch 8, per-command stderr spools, single journal/DB
        # shard); adaptive adds duration-driven batch sizing + spool
        # reuse; sharded adds sharded group commit (= the default stack,
        # the headline ``lane`` row above).
        levers = [
            ("lane_mux", dict(batch=8, reuse_spool=False), 1),
            ("lane_adaptive", dict(batch="auto"), 1),
            ("lane_sharded", dict(batch="auto"), None),
        ]
        for label, pool_kw, shards in levers:
            study = ParameterStudy(parse_yaml(WDL_NOOP), root=root,
                                   name=f"tp_{label}")
            if shards is not None:
                # pin the journal/DB shard count (None: engine default)
                study._auto_shards = lambda worker, _k=shards: _k
            n = study.instance_count()
            pool = LaneWorkerPool(SLOTS, render=study.render_node,
                                  **pool_kw)
            done = [0]
            t0 = time.perf_counter()
            try:
                study.run(pool=pool,
                          on_result=lambda r: done.__setitem__(
                              0, done[0] + 1))
            finally:
                pool.shutdown()
            wall = time.perf_counter() - t0
            assert done[0] == n, f"{label}: {done[0]}/{n} resolved"
            tps[label] = n / wall
            rows.append((f"engine_throughput_{label}", n / wall,
                         {"tasks": n, "slots": SLOTS,
                          "batch": pool_kw["batch"],
                          "shards": shards or "auto",
                          "wall_s": round(wall, 2),
                          "tasks_per_sec": round(n / wall)}))
    rows.append(("engine_lane_speedup_vs_thread", 0.0,
                 {"speedup": round(tps["lane"] / tps["thread"], 1),
                  "meets_5x": tps["lane"] >= 5 * tps["thread"],
                  "floor_tasks_per_sec": LANE_TASKS_PER_SEC_BASELINE / 2,
                  "above_floor": tps["lane"]
                  >= LANE_TASKS_PER_SEC_BASELINE / 2}))
    # results-subsystem tax: 2 regex captures per task must cost <20% of
    # the bare-lane throughput floor, so extraction can never silently
    # regress the short-task path.  Gated against the recorded floor
    # (stable across runs) with the measured same-run ratio reported.
    capture_floor = 0.8 * (LANE_TASKS_PER_SEC_BASELINE / 2)
    rows.append(("engine_capture_overhead", 0.0,
                 {"capture_tasks_per_sec": round(tps["lane_capture"]),
                  "bare_tasks_per_sec": round(tps["lane"]),
                  "measured_overhead_pct": round(
                      100 * (1 - tps["lane_capture"] / tps["lane"]), 1),
                  "floor_tasks_per_sec": round(capture_floor),
                  "above_floor": tps["lane_capture"] >= capture_floor}))
    # harness tax rows: the chaos seam with a fault actually firing, and
    # the telemetry seam armed vs disarmed.  Only the *disarmed* row is
    # gated (vs the recorded floor, stable across runs) — armed cost is
    # an informed choice, disarmed cost would be a tax on everyone.
    rows.append(("engine_chaos_overhead", 0.0,
                 {"chaos_tasks_per_sec": round(tps["lane_chaos"]),
                  "bare_tasks_per_sec": round(tps["lane"]),
                  "measured_overhead_pct": round(
                      100 * (1 - tps["lane_chaos"] / tps["lane"]), 1)}))
    telemetry_floor = 0.95 * (LANE_TASKS_PER_SEC_BASELINE / 2)
    rows.append(("engine_telemetry_overhead", 0.0,
                 {"armed_tasks_per_sec": round(tps["lane_telemetry"]),
                  "disarmed_tasks_per_sec":
                      round(tps["lane_telemetry_off"]),
                  "bare_tasks_per_sec": round(tps["lane"]),
                  "armed_overhead_pct": round(
                      100 * (1 - tps["lane_telemetry"] / tps["lane"]), 1),
                  "disarmed_overhead_pct": round(
                      100 * (1 - tps["lane_telemetry_off"] / tps["lane"]),
                      1),
                  "floor_tasks_per_sec": round(telemetry_floor),
                  "above_floor": tps["lane_telemetry_off"]
                  >= telemetry_floor}))
    return rows


def _spawn_rows() -> list[tuple[str, float, dict]]:
    """``run_subprocess`` spawn-path microbench: ``posix_spawn`` (vfork,
    no interpreter address-space fork) vs ``subprocess.run``."""
    rows = []
    popen_us, _ = _time_us(lambda: run_subprocess("true", spawn="popen"),
                           repeats=30)
    rows.append(("engine_spawn_popen", popen_us, {}))
    try:
        posix_us, _ = _time_us(
            lambda: run_subprocess("true", spawn="posix"), repeats=30)
    except RuntimeError:
        return rows     # platform without posix_spawnp
    rows.append(("engine_spawn_posix", posix_us,
                 {"speedup_vs_popen": round(popen_us / posix_us, 2)}))
    return rows


def _write_artifact(rows: list[tuple[str, float, dict]]) -> None:
    """Persist the throughput rows as JSON (CI artifact; path
    overridable via ``PAPAS_BENCH_OUT``)."""
    out = Path(os.environ.get("PAPAS_BENCH_OUT", "BENCH_throughput.json"))
    doc = {name: {"value_us_or_tps": round(val, 1), **derived}
           for name, val, derived in rows}
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"[artifact] {out}")


def check_throughput_floor() -> int:
    """CI gate: run only the throughput rows; nonzero exit when the lane
    pool falls below half the recorded baseline or loses its ≥5× margin
    over the thread pool."""
    rows = _spawn_rows() + _throughput_rows()
    _write_artifact(rows)
    ok = capture_ok = telemetry_ok = True
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
        if name == "engine_lane_speedup_vs_thread":
            ok = derived["meets_5x"] and derived["above_floor"]
        if name == "engine_capture_overhead":
            capture_ok = derived["above_floor"]
        if name == "engine_telemetry_overhead":
            telemetry_ok = derived["above_floor"]
    if not ok:
        print("FAIL: lane-pool throughput regressed "
              f"(floor {LANE_TASKS_PER_SEC_BASELINE / 2:.0f} tasks/s, "
              "required ≥5x thread pool)", file=sys.stderr)
        return 1
    if not capture_ok:
        print("FAIL: metric capture regressed the lane path "
              f"(capture rows must stay >= 80% of the "
              f"{LANE_TASKS_PER_SEC_BASELINE / 2:.0f} tasks/s bare-lane "
              "floor)", file=sys.stderr)
        return 1
    if not telemetry_ok:
        print("FAIL: disarmed telemetry regressed the lane path "
              f"(must stay >= 95% of the "
              f"{LANE_TASKS_PER_SEC_BASELINE / 2:.0f} tasks/s bare-lane "
              "floor — the zero-cost-when-off contract)", file=sys.stderr)
        return 1
    print("throughput floor OK (incl. capture + telemetry overhead)")
    return 0


def _sleep_node(node) -> str:
    """Module-level so the process pool can pickle it."""
    time.sleep(SLEEP_S)
    return node.id


def _sleep_dag() -> TaskDAG:
    dag = TaskDAG()
    for i in range(N_SLEEP):
        dag.add(TaskNode(id=f"s{i:02d}", task="sleep", combo={}))
    return dag


def _makespan_rows() -> list[tuple[str, float, dict]]:
    """Serial vs thread vs process makespan on 32 independent
    sleep(0.05) tasks — real wall clock through the unified engine."""
    rows = []
    walls: dict[str, float] = {}
    for kind, slots in [("inline", 1), ("thread", SLOTS), ("process", SLOTS)]:
        pool = make_pool(kind, slots)
        t0 = time.perf_counter()
        try:
            res = Scheduler(slots=slots).execute(_sleep_dag(), _sleep_node,
                                                 pool=pool)
        finally:
            pool.shutdown()
        wall = time.perf_counter() - t0
        walls[kind] = wall
        n_ok = sum(1 for r in res.values() if r.status == "ok")
        rows.append((f"engine_makespan_{kind}", wall * 1e6,
                     {"tasks": N_SLEEP, "slots": slots, "ok": n_ok,
                      "wall_s": round(wall, 3),
                      "slots_used": len({r.slot for r in res.values()})}))
    rows.append(("engine_thread_speedup_vs_serial", 0.0,
                 {"speedup": round(walls["inline"] / walls["thread"], 2),
                  "ratio": round(walls["thread"] / walls["inline"], 3),
                  "meets_half_serial": walls["thread"] < 0.5 * walls["inline"]}))
    rows.append(("engine_process_speedup_vs_serial", 0.0,
                 {"speedup": round(walls["inline"] / walls["process"], 2)}))
    rows.extend(_ssh_rows(walls["inline"]))
    return rows


def _ssh_rows(serial_wall: float) -> list[tuple[str, float, dict]]:
    """SSH-pool makespan over hosts × ppnode slots (LocalTransport fake:
    per-host slot accounting is real, the network is not) — the remote
    dispatch tax relative to the in-process thread pool."""
    dag = TaskDAG()
    for i in range(N_SLEEP):
        dag.add(TaskNode(id=f"s{i:02d}", task="sleep", combo={},
                         payload={"command": f"sleep {SLEEP_S}"}))
    pool = make_pool(
        "ssh", hosts=[f"h{i}" for i in range(SLOTS // 2)], ppnode=2,
        transport=LocalTransport(),
        render=lambda node: (node.payload["command"], {}))
    t0 = time.perf_counter()
    try:
        res = Scheduler(slots=pool.slots).execute(dag, None, pool=pool)
    finally:
        pool.shutdown()
    wall = time.perf_counter() - t0
    hosts_used = {r.host for r in res.values()}
    return [("engine_makespan_ssh", wall * 1e6,
             {"tasks": N_SLEEP, "slots": pool.slots,
              "hosts": len(pool.hosts), "ppnode": pool.ppnode,
              "hosts_used": len(hosts_used),
              "ok": sum(1 for r in res.values() if r.status == "ok"),
              "wall_s": round(wall, 3),
              "speedup_vs_serial": round(serial_wall / wall, 2)})]


def _time_us(fn, repeats=5):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        out = fn()
        best = min(best, (time.perf_counter_ns() - t0) / 1e3)
    return best, out


def run() -> list[tuple[str, float, dict]]:
    rows = []

    us, spec = _time_us(lambda: parse_yaml(WDL_SMALL))
    rows.append(("engine_parse_wdl", us, {}))

    study = ParameterStudy(spec, root="/tmp/papas_bench", name="ovh")
    us, insts = _time_us(lambda: study.instances())
    rows.append(("engine_expand_100", us, {"n": len(insts)}))

    big = ParameterStudy(parse_yaml(WDL_LARGE), root="/tmp/papas_bench",
                         name="ovh_big")
    us, insts_big = _time_us(lambda: big.instances(), repeats=2)
    rows.append(("engine_expand_16000", us,
                 {"n": len(insts_big),
                  "us_per_workflow": round(us / len(insts_big), 2)}))

    us, dag = _time_us(lambda: study.build_dag(insts))
    rows.append(("engine_build_dag_100", us, {"nodes": len(dag.nodes)}))

    us, _ = _time_us(lambda: list(dag.topological()))
    rows.append(("engine_topo_sort_100", us, {}))

    reg = {"t": lambda combo: 0}
    s2 = ParameterStudy(spec, registry=reg, root="/tmp/papas_bench",
                        name="ovh_run")
    t0 = time.perf_counter_ns()
    res = s2.run()
    total_us = (time.perf_counter_ns() - t0) / 1e3
    rows.append(("engine_run_overhead_per_task", total_us / len(res),
                 {"n": len(res), "includes": "journal+provenance"}))

    rows.extend(_streaming_rows())
    rows.extend(_makespan_rows())
    rows.extend(_spawn_rows())
    rows.extend(_throughput_rows())
    return rows


if __name__ == "__main__":
    if "--throughput" in sys.argv:
        sys.exit(check_throughput_floor())
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
