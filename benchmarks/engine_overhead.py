"""Framework-overhead microbenchmarks (the paper's 'lightweight' claim).

PaPaS positions itself as a lightweight user-space tool; these rows
quantify the framework tax: WDL parse time, combinatorial expansion
throughput at growing N_W, DAG build + topological order, provenance
write overhead per task.
"""
from __future__ import annotations

import time

from repro.core import ParameterStudy, parse_yaml

WDL_SMALL = """
t:
  args:
    a: ["1:10"]
    b: ["1:10"]
  command: run ${args:a} ${args:b}
"""

WDL_LARGE = """
t:
  args:
    a: ["1:40"]
    b: ["1:40"]
    c: ["1:10"]
  command: run ${args:a} ${args:b} ${args:c}
"""


def _time_us(fn, repeats=5):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        out = fn()
        best = min(best, (time.perf_counter_ns() - t0) / 1e3)
    return best, out


def run() -> list[tuple[str, float, dict]]:
    rows = []

    us, spec = _time_us(lambda: parse_yaml(WDL_SMALL))
    rows.append(("engine_parse_wdl", us, {}))

    study = ParameterStudy(spec, root="/tmp/papas_bench", name="ovh")
    us, insts = _time_us(lambda: study.instances())
    rows.append(("engine_expand_100", us, {"n": len(insts)}))

    big = ParameterStudy(parse_yaml(WDL_LARGE), root="/tmp/papas_bench",
                         name="ovh_big")
    us, insts_big = _time_us(lambda: big.instances(), repeats=2)
    rows.append(("engine_expand_16000", us,
                 {"n": len(insts_big),
                  "us_per_workflow": round(us / len(insts_big), 2)}))

    us, dag = _time_us(lambda: study.build_dag(insts))
    rows.append(("engine_build_dag_100", us, {"nodes": len(dag.nodes)}))

    us, _ = _time_us(lambda: list(dag.topological()))
    rows.append(("engine_topo_sort_100", us, {}))

    reg = {"t": lambda combo: 0}
    s2 = ParameterStudy(spec, registry=reg, root="/tmp/papas_bench",
                        name="ovh_run")
    t0 = time.perf_counter_ns()
    res = s2.run()
    total_us = (time.perf_counter_ns() - t0) / 1e3
    rows.append(("engine_run_overhead_per_task", total_us / len(res),
                 {"n": len(res), "includes": "journal+provenance"}))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
