"""Paper Figs. 5/6 — the matmul scaling performance study.

The paper's example: 88 instances (sizes 16..16384 ×2, threads 1..8).
OpenMP thread count has no TPU analogue, so the second parameter becomes
the JAX matmul block/precision knob closest in spirit: we sweep matrix
size × number of parallel study instances packed per dispatch.

The study is expressed in the PAPER'S OWN WDL (Fig. 5 syntax), parsed by
our parser, expanded by the combinatorial engine (asserting N_W = 88),
and executed through the study engine with runtimes captured by the task
profiler — exactly the paper's workflow.  Sizes are capped at 2048 on
this CPU container; the WDL itself carries the full 16..16384 range.
"""
from __future__ import annotations

import numpy as np

from repro.core import ParameterStudy, parse_yaml

WDL = """
matmulOMP:
  name: Matrix multiply scaling study with OpenMP
  environ:
    OMP_NUM_THREADS:
      - "1:8"
  args:
    size:
      - "16:*2:16384"
  command: matmul ${args:size} result_${args:size}N_${environ:OMP_NUM_THREADS}T.txt
"""

RUN_CAP = 2048   # sizes above this are skipped at execution time (CPU box)


def matmul_task(combo: dict) -> float:
    n = int(combo["args:size"])
    if n > RUN_CAP:
        return float("nan")
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n), np.float32)
    b = rng.standard_normal((n, n), np.float32)
    c = a @ b
    return float(c[0, 0])


def run() -> list[tuple[str, float, dict]]:
    rows = []
    spec = parse_yaml(WDL)
    study = ParameterStudy(spec, registry={"matmulOMP": matmul_task},
                           root="/tmp/papas_bench", name="matmul88")
    insts = study.instances()
    assert len(insts) == 88, len(insts)    # paper: "88 independent executions"
    res = study.run()
    summary = study.db.runtime_summary()
    rows.append(("fig5_expand_n_workflows", 0.0, {"n_instances": len(insts)}))
    rows.append(("fig6_study_execution", summary["total"] * 1e6 / 88,
                 {"ok": sum(1 for r in res.values() if r.status == "ok"),
                  "profiled_median_s": round(summary["median"], 4)}))

    # strong-scaling table from the profiler (per-size medians)
    by_size: dict[int, list[float]] = {}
    for rec in study.db.records():
        size = rec["combo"]["args:size"]
        if rec["status"] == "ok" and size <= RUN_CAP:
            by_size.setdefault(size, []).append(rec["runtime"])
    for size in sorted(by_size):
        times = sorted(by_size[size])
        rows.append((f"fig6_matmul_{size}", times[len(times) // 2] * 1e6,
                     {"runs": len(times)}))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
