"""§Roofline — aggregate the dry-run artifacts into the roofline table.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per (arch × shape) on the single-pod mesh: the three
roofline terms, the dominant bottleneck, and the useful-FLOPs ratio.
"""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments/dryrun"


def load(mesh: str = "16x16") -> list[dict]:
    rows = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        if rec.get("applicable", True):
            rows.append(rec)
    return rows


def run() -> list[tuple[str, float, dict]]:
    rows = []
    for rec in load():
        rl = rec["roofline"]
        rows.append((
            f"roofline_{rec['arch']}_{rec['shape']}",
            rl["step_s_lower_bound"] * 1e6,
            {
                "dominant": rl["dominant"],
                "compute_s": round(rl["compute_s"], 4),
                "memory_s": round(rl["memory_s"], 4),
                "collective_s": round(rl["collective_s"], 4),
                "useful_flops_ratio": round(rec["useful_flops_ratio"] or 0, 3),
                "peak_GB": round(rec["memory"]["peak_bytes"] / 1e9, 2),
            },
        ))
    if not rows:
        rows.append(("roofline_missing", 0.0,
                     {"note": "run python -m repro.launch.dryrun first"}))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
