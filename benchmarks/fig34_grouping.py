"""Paper Figs. 3/4 — 25 NetLogo-style simulations under N×P grouping.

The paper compares independent submission against grouped schemes
(1N-1P … 2N-2P): grouping tasks into one cluster job cuts scheduler
interactions and completion time.  We reproduce the comparison twice:

1. **simulated** — the event engine with the paper's schemes, including
   multi-tenant queue delays for the independent case (Fig. 3/4 shape);
2. **executed** — a real 25-instance agent-based-model parameter study
   (a tiny stochastic SIR-on-a-grid simulation standing in for the
   C. difficile NetLogo model) run through the actual study engine:
   one-per-task dispatch vs GangExecutor batched dispatch; we report
   real wall-clock and real dispatch counts.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    GangExecutor, ParameterStudy, Scheduler, TaskDAG, TaskNode,
    dispatch_count, makespan, parse_yaml, stackable_key,
)

N_SIMS = 25
SIM_SECONDS = 30.0 * 60.0


def abm_sim(combo: dict) -> float:
    """Stochastic SIR on a 32×32 grid — the stand-in simulation."""
    rng = np.random.default_rng(int(combo.get("args:seed", 0)))
    beta = float(combo.get("args:beta", 0.3))
    grid = np.zeros((32, 32), np.int8)
    grid[16, 16] = 1
    for _ in range(50):
        infected = grid == 1
        neighbors = (
            np.roll(infected, 1, 0) | np.roll(infected, -1, 0)
            | np.roll(infected, 1, 1) | np.roll(infected, -1, 1))
        new = (grid == 0) & neighbors & (rng.random((32, 32)) < beta)
        rec = infected & (rng.random((32, 32)) < 0.1)
        grid[new] = 1
        grid[rec] = 2
    return float((grid == 2).sum())


STUDY = """
abm:
  name: C.-difficile-style ABM sweep
  args:
    beta: [0.1, 0.2, 0.3, 0.4, 0.5]
    seed: ["0:4"]
  command: unused
"""


def run() -> list[tuple[str, float, dict]]:
    rows = []

    # --- simulated N×P schemes (Fig. 3/4) -----------------------------
    dag = TaskDAG()
    for i in range(N_SIMS):
        dag.add(TaskNode(id=f"s{i:02d}", task="sim", combo={}))
    dur = {f"s{i:02d}": SIM_SECONDS for i in range(N_SIMS)}
    schemes = {
        "independent": ("common", 2, 180.0),   # scheduler-managed
        "1N-1P": ("grouped", 1, 0.0),
        "1N-2P": ("grouped", 2, 0.0),
        "2N-1P": ("grouped", 2, 0.0),
        "2N-2P": ("grouped", 4, 0.0),
    }
    for name, (policy, slots, delay) in schemes.items():
        ev = Scheduler(slots=slots).simulate(dag, dur, policy,
                                             queue_delay=delay, seed=1)
        rows.append((f"fig34_sim_{name}", 0.0,
                     {"makespan_min": round(makespan(ev) / 60.0, 1),
                      "dispatches": dispatch_count(ev)}))

    # --- executed 25-instance study through the real engine -----------
    spec = parse_yaml(STUDY)

    study1 = ParameterStudy(spec, registry={"abm": abm_sim},
                            root="/tmp/papas_bench", name="abm_serial")
    t0 = time.perf_counter_ns()
    res1 = study1.run()
    serial_us = (time.perf_counter_ns() - t0) / 1e3
    rows.append(("fig34_exec_one_per_task", serial_us / N_SIMS,
                 {"n": len(res1), "dispatches": len(res1)}))

    study2 = ParameterStudy(spec, registry={"abm": abm_sim},
                            root="/tmp/papas_bench", name="abm_gang")
    gang = GangExecutor(stackable_key,
                        lambda nodes: [abm_sim(n.combo) for n in nodes])
    t0 = time.perf_counter_ns()
    res2 = study2.run(gang=gang)
    gang_us = (time.perf_counter_ns() - t0) / 1e3
    rows.append(("fig34_exec_gang", gang_us / N_SIMS,
                 {"n": len(res2), "dispatches": gang.stats.dispatches,
                  "batching_factor": gang.stats.batching_factor}))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
