#!/usr/bin/env bash
# One-shot local gate: byte-compile everything, run the tier-1 suite,
# then exercise the remote-execution path (SSH pool + batch rendering
# over the no-network fakes) explicitly.
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks scripts
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# remote backends: run their suites by name so a collection change can
# never silently drop them from the gate
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_remote_pool.py tests/test_batch_pool.py

# streaming pipeline: indexed addressing, windowed admission, journal v2
# — also pinned by name so collection changes cannot drop them
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_streaming_space.py tests/test_windowed_engine.py \
    tests/test_journal_v2.py

# short-task throughput path: compiled templates, persistent worker
# lanes (selector mux + frame reassembly), group-commit recording
# (incl. sharded segments), and the dispatch levers (adaptive batching,
# spawn elimination, straggler quantiles, auto window) — pinned by name
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_compiled_templates.py tests/test_lane_pool.py \
    tests/test_group_commit.py tests/test_dispatch_levers.py

# results subsystem: capture grammar, streaming aggregation, resume
# semantics for metrics, report rendering — pinned by name
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_results.py tests/test_report.py tests/test_viz.py

# static analysis: the lint rule pack, its property harness (skips
# without hypothesis), and the lock auditor — pinned by name
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_lint.py tests/test_lint_props.py tests/test_locklint.py

# observability: the telemetry layer — Chrome-trace schema validity
# (every B closed, stable tids across lane respawns), retry-backoff
# span timings under VirtualClock, and metrics counters checked against
# ScheduleEvent ground truth on a seeded chaos run — pinned by name
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_telemetry.py

# lint gate, positive half: every shipped example must lint clean even
# under --strict (zero findings is what keeps the gate honest)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.lint \
    examples/*.yaml --strict

# lint gate, negative half: the seeded-defect fixture must exit 1 and
# flag every planted rule id — a lint that stops seeing defects is as
# broken as one that invents them
if PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.lint \
    tests/fixtures/broken_study.yaml --format json > /tmp/papas_lint.json
then
    echo "lint gate: broken fixture unexpectedly passed" >&2
    exit 1
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
doc = json.load(open("/tmp/papas_lint.json"))
(rep,) = doc["files"].values()
ids = {f["rule"] for f in rep["findings"]}
want = {"E101", "E201", "E202", "E203", "E301", "E403", "E502", "W601",
        "W701", "W802"}
missing = want - ids
assert not missing, f"lint gate: fixture rules not flagged: {sorted(missing)}"
print(f"lint gate: fixture flagged {len(want)} seeded rule id(s)")
EOF

# lint smoke through the example driver: clean + broken studies through
# the same code path sweep.py --check runs (text and JSON renderers)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --lint

# engine concurrency smoke: run the lane-mux and group-commit suites
# under instrumented locks and fail the gate on any acquisition-order
# cycle (a potential deadlock that only load would surface)
PAPAS_LOCKLINT=1 PAPAS_LOCKLINT_OUT=/tmp/papas_locklint.json \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_lane_pool.py tests/test_group_commit.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
from repro.core.lint import findings_from_lock_report
report = json.load(open("/tmp/papas_locklint.json"))
assert report["locks"], "locklint smoke recorded no instrumented locks"
verdict = findings_from_lock_report(report)
print(verdict.render())
assert verdict.ok, "lock acquisition-order cycle detected"
EOF

# end-to-end smoke: a study through the SSH worker pool (hosts × ppnode
# slots, LocalTransport fake — commands run locally, no network), with
# per-task hosts asserted in the journal by the example itself
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --pool ssh --hosts localhost --ppnode 2

# large-space streaming smoke: a 16k-combination study through windowed
# admission — asserts the live-node bound + compact v2 journal, prints
# wall time and peak RSS for eyeballing regressions
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --window 64

# lane-pool smoke: persistent shell worker lanes end to end, with
# per-task lane hosts asserted in the journal by the example itself
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --pool lane

# performance-study smoke: the paper's §6 shape (threads × size with
# capture: + baseline:) streamed through windowed lanes; the example
# asserts the speedup/efficiency pivot AND that the offline
# records.jsonl report reproduces the live table
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --report

# chaos gate: deterministic fault injection through every backend seam
# (canned plans in examples/chaos/) — lane-worker kills retried to a
# byte-identical record set, host failure quarantined then *recovered*
# through probation, and a mid-run SIGKILL + torn journal segment that
# resume must replay exactly (idempotently).  The chaos suites are also
# pinned by name so collection changes cannot drop them.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_chaos.py tests/test_chaos_props.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --chaos lane
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --chaos host
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --chaos sigkill

# telemetry smoke: a chaos-armed windowed lane study with --trace
# --status — the example asserts the trace JSON loads, every B span is
# closed, spans cover every recorded instance, and the /metrics
# endpoint reports nonzero retry + fault counters
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/quickstart.py \
    --trace --status

# short-task throughput floor: 10^4 no-op tasks through thread vs lane
# vs windowed-lane vs lane+capture, plus per-lever rows (mux /
# adaptive-batch / sharded), chaos-armed and telemetry-armed/disarmed
# rows, and the spawn-path microbench; writes BENCH_throughput.json and
# fails if the lane pool drops below half the recorded 10^4 tasks/s
# baseline (5000 tasks/s floor, raised from 900 with the selector-mux
# dispatch path), loses its >=5x margin over the thread pool, metric
# capture costs more than 20% of the bare-lane floor, or the *disarmed*
# telemetry seams cost more than 5% of it (zero-cost-when-off contract)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python \
    benchmarks/engine_overhead.py --throughput
