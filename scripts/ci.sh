#!/usr/bin/env bash
# One-shot local gate: byte-compile everything, then run the tier-1 suite.
# Usage: scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks scripts
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
