"""Regenerate the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
experiments/dryrun JSONs (run after repro.launch.dryrun)."""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DRY = ROOT / "experiments/dryrun"

ARCH_ORDER = ["internvl2-26b", "gemma-7b", "h2o-danube-1.8b", "deepseek-7b",
              "gemma3-1b", "hubert-xlarge", "qwen2-moe-a2.7b", "olmoe-1b-7b",
              "mamba2-780m", "hymba-1.5b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

PEAK_BF16 = 197e12


def load():
    recs = {}
    for p in DRY.glob("*.json"):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | compile | HLO FLOPs/dev | peak GB/dev | AG GB | AR GB | A2A GB | dominant |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("16x16", "2x16x16"):
                r = recs.get((a, s, m))
                if not r or not r.get("applicable", True):
                    continue
                c = r["collectives"]
                rows.append(
                    f"| {a} | {s} | {m} | {r['compile_seconds']:.0f}s "
                    f"| {r['hlo_flops_per_device']:.2e} "
                    f"| {r['memory']['peak_bytes']/1e9:.1f} "
                    f"| {c['all-gather']['bytes']/1e9:.1f} "
                    f"| {c['all-reduce']['bytes']/1e9:.1f} "
                    f"| {c['all-to-all']['bytes']/1e9:.2f} "
                    f"| {r['roofline']['dominant']} |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = ["| arch | shape | compute | memory† | collective | bound | ideal‡ | frac | useful |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "16x16"))
            if not r or not r.get("applicable", True):
                continue
            rl = r["roofline"]
            ideal = r["model_flops_per_device"] / PEAK_BF16
            bound = rl["step_s_lower_bound"]
            rows.append(
                f"| {a} | {s} | {fmt_s(rl['compute_s'])} "
                f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
                f"| {rl['dominant']} | {fmt_s(ideal)} "
                f"| {ideal/bound*100:.0f}% "
                f"| {r['useful_flops_ratio']:.2f} |")
    return "\n".join(rows)


def main():
    recs = load()
    print("## table: dryrun")
    print(dryrun_table(recs))
    print()
    print("## table: roofline")
    print(roofline_table(recs))
    n_ok = sum(1 for r in recs.values() if r.get("applicable", True))
    print(f"\ncells compiled OK: {n_ok} (x2 meshes); "
          f"skipped: {66 - 2*0 - n_ok} inapplicable records")


if __name__ == "__main__":
    main()
