"""vmap-stack gang training — the paper's job-batching on SPMD hardware.

``train_members``  — one compiled dispatch PER member (the paper's
                     one-job-per-task baseline).
``train_ensemble`` — ALL members folded into one compiled program via
                     ``jax.vmap`` over the member axis; hyperparameters
                     that differ (lr, seed) become per-member arrays.
                     One dispatch total: the *optimal* regime of the
                     paper's Fig. 1, unreachable for an MPI dispatcher.

Members are combo dicts from the study engine, e.g.
``{"args:lr": 3e-4, "args:seed": 1, "args:arch": "gemma3-1b", ...}``.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models.transformer import init_params, loss_fn
from repro.optim.adamw import AdamW, cosine_schedule


def _arg(m: dict[str, Any], key: str, default: Any) -> Any:
    for k in (key, f"args:{key}"):
        if k in m:
            return m[k]
    return default


def _uniform(members: Sequence[dict], key: str, default: Any) -> Any:
    vals = {repr(_arg(m, key, default)) for m in members}
    if len(vals) != 1:
        raise ValueError(
            f"gang members must share {key!r} (shape-affecting); got {vals}. "
            f"Use mesh-slice / one-per-task for heterogeneous studies.")
    return _arg(members[0], key, default)


def _train_one_factory(arch: str, steps: int, batch: int, seq: int,
                       warmup: int):
    cfg = get_smoke(arch)

    def train_one(lr: jax.Array, seed: jax.Array) -> jax.Array:
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
        params = init_params(cfg, key)
        opt = AdamW(schedule=cosine_schedule(1.0, warmup, steps))
        state = opt.init(params)

        def body(carry, step_key):
            params, state = carry
            toks = jax.random.randint(step_key, (batch, seq), 0,
                                      cfg.vocab_size)
            b = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
            (loss, _), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, b), has_aux=True)(params)
            # per-member lr scales the unit-base schedule
            scaled = AdamW(schedule=lambda c, _o=opt: lr * _o.schedule(c))
            params, state, _ = scaled.update(grads, state, params)
            return (params, state), loss

        keys = jax.random.split(jax.random.fold_in(key, 1), steps)
        (_, _), losses = jax.lax.scan(body, (params, state), keys)
        return losses[-1]

    return train_one


def _common(members: Sequence[dict]):
    arch = _uniform(members, "arch", "gemma3-1b")
    steps = int(_uniform(members, "steps", 20))
    batch = int(_uniform(members, "batch", 4))
    seq = int(_uniform(members, "seq", 64))
    warmup = max(1, steps // 10)
    lrs = jnp.asarray([float(_arg(m, "lr", 1e-3)) for m in members])
    seeds = jnp.asarray([int(_arg(m, "seed", 0)) for m in members])
    return _train_one_factory(arch, steps, batch, seq, warmup), lrs, seeds


def train_members(members: Sequence[dict]) -> list[float]:
    """One dispatch per member (baseline)."""
    train_one, lrs, seeds = _common(members)
    fn = jax.jit(train_one)
    return [float(fn(lrs[i], seeds[i])) for i in range(len(members))]


def train_ensemble(members: Sequence[dict]) -> list[float]:
    """All members in ONE compiled program (vmap-stack gang)."""
    train_one, lrs, seeds = _common(members)
    losses = jax.jit(jax.vmap(train_one))(lrs, seeds)
    return [float(x) for x in losses]
