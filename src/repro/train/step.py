"""Train-step factory: loss → grads → AdamW, with microbatching and
optional int8 gradient compression on the data-parallel reduction."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.transformer import loss_fn
from repro.optim.adamw import AdamW, accumulate_grads, compress_int8, decompress_int8


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    n_micro: int = 1              # gradient-accumulation microbatches
    moe_groups: int = 1           # GShard dispatch groups
    compress_grads: bool = False  # int8 round-trip on the DP reduction
    seq_spec: Any = None          # sequence-parallel activation PartitionSpec


def make_train_step(cfg: ArchConfig, opt: AdamW,
                    step_cfg: TrainStepConfig = TrainStepConfig()
                    ) -> Callable:
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    state = {"params", "opt", "step"}; batch leaves have the global
    batch dim first.  With n_micro > 1 the batch is split on axis 0 and
    scanned (activation memory / n_micro).
    """

    def _loss(params, batch):
        return loss_fn(cfg, params, batch, step_cfg.moe_groups,
                       step_cfg.seq_spec)

    def train_step(state: dict[str, Any], batch: dict[str, jax.Array]
                   ) -> tuple[dict[str, Any], dict[str, jax.Array]]:
        params = state["params"]
        if step_cfg.n_micro > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((step_cfg.n_micro,
                                     x.shape[0] // step_cfg.n_micro)
                                    + x.shape[1:]), batch)
            grads, loss, aux = accumulate_grads(
                _loss, params, micro, step_cfg.n_micro)
        else:
            (loss, aux), grads = jax.value_and_grad(
                _loss, has_aux=True)(params, batch)

        if step_cfg.compress_grads:
            # int8 quantize → (implicit psum by GSPMD) → dequantize.
            # The quantized representation is what crosses the pod links.
            grads = decompress_int8(compress_int8(grads))

        new_params, new_opt, opt_metrics = opt.update(
            grads, state["opt"], params)
        metrics = {"loss": loss, **aux, **opt_metrics}
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def init_train_state(cfg: ArchConfig, opt: AdamW, key: jax.Array
                     ) -> dict[str, Any]:
    from repro.models.transformer import init_params
    params = init_params(cfg, key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ArchConfig, opt: AdamW) -> Any:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_train_state(cfg, opt, k), key)
