"""repro.train"""
