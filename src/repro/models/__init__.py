"""Model substrate: configs, layers, and the 10 assigned architectures."""
from .config import ArchConfig, ShapeConfig, SHAPES, cell_applicable
from .model import Model, cache_specs, input_specs, synthetic_batch
from .transformer import decode_step, forward, init_cache, init_params, loss_fn

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "cell_applicable",
    "Model", "cache_specs", "input_specs", "synthetic_batch",
    "decode_step", "forward", "init_cache", "init_params", "loss_fn",
]
