"""Shared neural layers: norms, rotary embeddings, gated MLPs.

Pure-functional jnp; parameters are plain dict pytrees.  Parameters are
stored in ``param_dtype`` (fp32 by default) and cast to ``compute_dtype``
at the point of use (mixed-precision training).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.jax_compat import mesh_axis_names


def cast(x: jax.Array, dtype: Any) -> jax.Array:
    return x.astype(dtype) if x.dtype != jnp.dtype(dtype) else x


def maybe_shard(x: jax.Array, *entries: Any) -> jax.Array:
    """Sharding constraint against the ambient abstract mesh; no-op when
    no mesh (or no "model" axis) is active — keeps model code usable on
    a single device and fully sharded under an active mesh."""
    names = mesh_axis_names()
    if "model" not in names:
        return x
    fixed = tuple(e if (e is None or (isinstance(e, str) and e in names)
                        or (isinstance(e, tuple)
                            and all(a in names for a in e)))
                  else None for e in entries)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*fixed))


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm computed in fp32 (numerics), output in x.dtype."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for half the head dim (fp32)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (x[..., :d/2], x[..., d/2:]).

    x: (B, S, H, D); positions: (B, S) int32.
    """
    dtype = x.dtype
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (B,S,D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (B,S,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def _act(name: str):
    if name in ("silu", "swiglu"):
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "gelu_nogate":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name!r}")


def mlp(x: jax.Array, p: dict[str, jax.Array], act: str,
        compute_dtype: Any = jnp.bfloat16) -> jax.Array:
    """Gated (SwiGLU/GeGLU) or plain two-layer MLP."""
    fn = _act(act)
    xc = cast(x, compute_dtype)
    if act == "gelu_nogate":
        h = fn(xc @ cast(p["wi"], compute_dtype) + cast(p["bi"], compute_dtype))
        return h @ cast(p["wo"], compute_dtype) + cast(p["bo"], compute_dtype)
    gate = xc @ cast(p["wi_gate"], compute_dtype)
    up = xc @ cast(p["wi_up"], compute_dtype)
    return (fn(gate) * up) @ cast(p["wo"], compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_tokens(tokens: jax.Array, table: jax.Array, scale: bool,
                 compute_dtype: Any = jnp.bfloat16) -> jax.Array:
    x = cast(jnp.take(table, tokens, axis=0), compute_dtype)
    if scale:
        x = x * jnp.asarray(table.shape[-1] ** 0.5, compute_dtype)
    return x


def unembed(x: jax.Array, table: jax.Array,
            compute_dtype: Any = jnp.bfloat16) -> jax.Array:
    """Logits; computed in compute dtype, cast up by the loss."""
    return cast(x, compute_dtype) @ cast(table, compute_dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(key: jax.Array, shape: tuple[int, ...], dtype: Any,
                stddev: float = 0.02) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


def zeros_init(shape: tuple[int, ...], dtype: Any) -> jax.Array:
    return jnp.zeros(shape, dtype)
