"""Mamba2 SSD (state-space duality) block — arXiv:2405.21060.

The SSD recurrence per head h with state (P, N):

    a_t = exp(dt_t · A_h)                       (scalar decay, A_h < 0)
    h_t = a_t · h_{t-1} + dt_t · x_t ⊗ B_t      (outer product update)
    y_t = C_t · h_t + D_h · x_t

Production XLA path: the chunked SSD algorithm — quadratic *within*
chunks of length Q (matmul-friendly for the MXU), associative scan
*across* chunk states — O(S·Q) work instead of O(S²).  The Pallas kernel
(``repro.kernels.ssd_scan``) fuses the intra-chunk stage on TPU.

Block layout follows mamba_ssm's Mamba2: fused in_proj → causal depthwise
conv over (x,B,C) → SSD → gated RMSNorm → out_proj.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import cast, maybe_shard, rms_norm


def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)  — dt-scaled inputs
    log_a: jax.Array,  # (B, S, H)     — per-step log decay (dt·A, ≤ 0)
    b_mat: jax.Array,  # (B, S, G, N)
    c_mat: jax.Array,  # (B, S, G, N)
    chunk: int,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N)).

    G groups broadcast over H heads (H % G == 0).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    q = min(chunk, s)
    if s % q:
        raise ValueError(f"seq {s} not divisible by chunk {q}")
    c = s // q
    rep = h // g

    xq = x.reshape(bsz, c, q, h, p)
    la = log_a.reshape(bsz, c, q, h).astype(jnp.float32)
    bq = b_mat.reshape(bsz, c, q, g, n)
    cq = c_mat.reshape(bsz, c, q, g, n)
    # broadcast groups → heads
    bh = jnp.repeat(bq, rep, axis=3)                      # (B,C,Q,H,N)
    ch = jnp.repeat(cq, rep, axis=3)

    cum = jnp.cumsum(la, axis=2)                          # (B,C,Q,H) inclusive
    seg_total = cum[:, :, -1, :]                          # (B,C,H)

    # ---- intra-chunk (quadratic in Q) --------------------------------
    # decay(i←j) = exp(cum_i - cum_j) for j ≤ i.  The masked (j > i)
    # entries have POSITIVE exponents: exp would overflow and poison the
    # where-gradient (NaN), so the argument is masked BEFORE exp.
    li = cum[:, :, :, None, :]                            # (B,C,Q,1,H)
    lj = cum[:, :, None, :, :]                            # (B,C,1,Q,H)
    mask = jnp.tril(jnp.ones((q, q), jnp.bool_))[None, None, :, :, None]
    delta = jnp.where(mask, li - lj, 0.0)
    decay = jnp.where(mask, jnp.exp(delta), 0.0)          # (B,C,Q,Q,H) fp32
    scores = jnp.einsum("bcihn,bcjhn->bcijh", ch.astype(jnp.float32),
                        bh.astype(jnp.float32)) * decay
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(x.dtype), xq)

    # ---- chunk states -------------------------------------------------
    # state contribution of step j within its chunk: decay to chunk end
    w = jnp.exp(seg_total[:, :, None, :] - cum)           # (B,C,Q,H)
    states = jnp.einsum("bcqhp,bcqhn,bcqh->bchpn",
                        xq.astype(jnp.float32), bh.astype(jnp.float32), w)

    # ---- inter-chunk associative scan over (decay, state) -------------
    seg = jnp.exp(seg_total.astype(jnp.float32))          # (B,C,H)

    def combine(left, right):
        a_l, s_l = left
        a_r, s_r = right
        return a_l * a_r, s_l * a_r[..., None, None] + s_r

    a_scan, s_scan = jax.lax.associative_scan(
        combine, (seg, states), axis=1)
    # state entering chunk c = scanned state of chunk c-1 (+ injected
    # initial state decayed by the cumulative product a_scan[c-1])
    if initial_state is not None:
        init = initial_state.astype(jnp.float32)[:, None]   # (B,1,H,P,N)
        prev = jnp.concatenate(
            [init, s_scan[:, :-1] + init * a_scan[:, :-1, :, None, None]],
            axis=1)
        final_state = s_scan[:, -1] + init[:, 0] * a_scan[:, -1, :, None, None]
    else:
        prev = jnp.concatenate(
            [jnp.zeros_like(s_scan[:, :1]), s_scan[:, :-1]], axis=1)
        final_state = s_scan[:, -1]

    # ---- inter-chunk output contribution ------------------------------
    dec_in = jnp.exp(cum)                                  # decay from chunk start
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         ch.astype(jnp.float32), prev, dec_in)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state.astype(x.dtype)


def ssd_step(
    state: jax.Array,   # (B, H, P, N) fp32
    x_t: jax.Array,     # (B, H, P) — dt-scaled input
    log_a_t: jax.Array, # (B, H)
    b_t: jax.Array,     # (B, G, N)
    c_t: jax.Array,     # (B, G, N)
) -> tuple[jax.Array, jax.Array]:
    """Single decode step of the SSD recurrence."""
    h = x_t.shape[1]
    g = b_t.shape[1]
    rep = h // g
    bh = jnp.repeat(b_t, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    ch = jnp.repeat(c_t, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(log_a_t.astype(jnp.float32))[..., None, None]
    new_state = state * a + jnp.einsum(
        "bhp,bhn->bhpn", x_t.astype(jnp.float32), bh)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return new_state, y.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array | None]:
    """Depthwise causal conv1d, kernel size K.  x (B,S,C); w (K,C).

    With ``state`` (B,K-1,C) performs a streaming step (S==1)."""
    k = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)         # (B,K,C)
        y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None, :]
        new_state = window[:, 1:]
        return (y + b.astype(jnp.float32)).astype(x.dtype), new_state
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # unfold: y_t = Σ_k w_k · x_{t-K+1+k}
    idx = jnp.arange(x.shape[1])[:, None] + jnp.arange(k)[None, :]  # (S,K)
    windows = pad[:, idx]                                    # (B,S,K,C)
    y = jnp.einsum("bskc,kc->bsc", windows.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x.dtype), None


def mamba2_block(
    x: jax.Array,                # (B, S, d)
    p: dict[str, Any],
    *,
    d_inner: int,
    state_dim: int,
    head_dim: int,
    n_groups: int,
    conv_width: int,
    chunk: int,
    compute_dtype: Any = jnp.bfloat16,
    cache: dict[str, jax.Array] | None = None,
    use_kernels: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Mamba2 mixer.  With ``cache`` performs one decode step (S==1)."""
    bsz, s, d = x.shape
    n_heads = d_inner // head_dim
    gn = n_groups * state_dim
    xc = cast(x, compute_dtype)

    zxbcdt = xc @ cast(p["in_proj"], compute_dtype)
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, d_inner + d_inner + 2 * gn], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if cache is not None:
        xbc_act, conv_state = _causal_conv(
            xbc, p["conv_w"], p["conv_b"], cache["conv"])
    else:
        xbc_act, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc_act = jax.nn.silu(xbc_act.astype(jnp.float32)).astype(compute_dtype)
    xs, b_mat, c_mat = jnp.split(xbc_act, [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(bsz, s, n_heads, head_dim)
    b_mat = b_mat.reshape(bsz, s, n_groups, state_dim)
    c_mat = c_mat.reshape(bsz, s, n_groups, state_dim)

    a = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,) negative
    log_a = dt.reshape(bsz, s, n_heads) * a                  # (B,S,H)
    x_scaled = xs * dt.reshape(bsz, s, n_heads, 1).astype(compute_dtype)

    new_cache = None
    if cache is not None:
        new_state, y = ssd_step(
            cache["ssm"], x_scaled[:, 0], log_a[:, 0],
            b_mat[:, 0], c_mat[:, 0])
        y = y[:, None]
        new_cache = {"conv": conv_state, "ssm": new_state,
                     "pos": cache["pos"] + 1}
    elif use_kernels:
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(x_scaled, log_a, b_mat, c_mat, chunk=chunk)
    else:
        y, _ = ssd_chunked(x_scaled, log_a, b_mat, c_mat, chunk=chunk)

    y = y + xs.astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_inner)
    # gated RMSNorm (mamba2: norm(y * silu(z)))
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(compute_dtype), p["norm"], 1e-5)
    out = y @ cast(p["out_proj"], compute_dtype)
    return out, new_cache


def init_ssm_cache(bsz: int, d_inner: int, state_dim: int, head_dim: int,
                   n_groups: int, conv_width: int,
                   dtype: Any = jnp.float32) -> dict[str, jax.Array]:
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * n_groups * state_dim
    return {
        "conv": jnp.zeros((bsz, conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((bsz, n_heads, head_dim, state_dim), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
