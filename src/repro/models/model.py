"""Public model API: a thin functional wrapper over the substrate.

``Model`` binds an ArchConfig to init/loss/decode callables, and
``input_specs`` produces ShapeDtypeStruct stand-ins for every input of a
given (arch × shape) cell — the dry-run lowers against these without
allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig, ShapeConfig
from .transformer import (
    decode_step, forward, init_cache, init_params, loss_fn,
)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    def init(self, key: jax.Array) -> dict[str, Any]:
        return init_params(self.cfg, key)

    def init_abstract(self, key: jax.Array | None = None):
        """Parameter shapes without allocation (for dry-run sharding)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: init_params(self.cfg, k), key)

    def forward(self, params, batch, moe_groups: int = 1):
        return forward(self.cfg, params, batch, moe_groups)

    def loss(self, params, batch, moe_groups: int = 1):
        return loss_fn(self.cfg, params, batch, moe_groups)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return init_cache(self.cfg, batch, max_len, dtype)

    def decode_step(self, params, cache, token):
        return decode_step(self.cfg, params, cache, token)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    train/prefill → the training batch; decode → one-token step inputs
    (the KV cache spec is produced separately via ``cache_specs``).
    """
    b, s = shape.global_batch, shape.seq_len
    f32, i32, bf16 = jnp.float32, jnp.int32, jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "tokens":
            return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.input_mode == "embeds":
            return {"embeds": sds((b, s, cfg.d_model), bf16),
                    "labels": sds((b, s), i32)}
        # mixed: patches occupy the first n_patches positions
        st = s - cfg.n_patches
        return {"tokens": sds((b, st), i32),
                "patch_embeds": sds((b, cfg.n_patches, cfg.d_model), bf16),
                "labels": sds((b, s), i32)}
    # decode: one new token against a seq_len-deep cache
    return {"token": sds((b, 1), i32)}


def cache_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Any:
    """Abstract KV/SSM cache for a decode cell (no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype))


def synthetic_batch(cfg: ArchConfig, batch: int, seq: int,
                    key: jax.Array) -> dict[str, jax.Array]:
    """Materialized random batch for smoke tests / examples."""
    ks = jax.random.split(key, 3)
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.input_mode == "embeds":
        emb = jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                jnp.bfloat16) * 0.1
        labels = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size)
        return {"embeds": emb, "labels": labels}
    npatch = min(cfg.n_patches, seq // 2)
    st = seq - npatch
    toks = jax.random.randint(ks[0], (batch, st), 0, cfg.vocab_size)
    patches = jax.random.normal(ks[1], (batch, npatch, cfg.d_model),
                                jnp.bfloat16) * 0.1
    labels = jnp.concatenate(
        [jnp.full((batch, npatch), -100, jnp.int32),
         jax.random.randint(ks[2], (batch, st), 0, cfg.vocab_size)], axis=1)
    return {"tokens": toks, "patch_embeds": patches, "labels": labels}
