"""Attention: GQA/MQA/MHA, causal + bidirectional, sliding-window.

Three structural code paths (the XLA reference; the Pallas flash kernel
replaces the inner computation on TPU when ``use_kernels``):

* ``full_attention``  — S×S masked attention (causal or bidirectional).
* ``local_attention`` — chunk-banded SWA: each W-query chunk attends to
  its own and the previous chunk, so FLOPs scale as S·2W not S².
* ``decode_attention``— one query against a KV cache.

Shapes: q (B,S,Hq,D); k,v (B,S,Hkv,D); GQA groups Hq into Hkv bundles.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import apply_rope, cast, maybe_shard, rms_norm

NEG_INF = -2.0e38


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return jnp.tanh(scores / cap) * cap
    return scores


def _repeat_kv(k: jax.Array, n_q: int) -> jax.Array:
    """GQA → MHA expansion: (B,S,Hkv,D) → (B,S,Hq,D).

    The repeated-KV formulation keeps every attention einsum shardable
    over the *query*-head axis (Hq is a multiple of the TP degree even
    when Hkv is not, e.g. kv=8 on a 16-way model axis); the expansion is
    a cheap gather that GSPMD shards on the head dim."""
    hkv = k.shape[2]
    if hkv == n_q:
        return k
    return jnp.repeat(k, n_q // hkv, axis=2)


def _sdp(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array | None,
         softcap: float) -> jax.Array:
    """Masked softmax(QKᵀ)V on (B,S,H,D) operands (softmax fp32).

    fp32 comes from the dot's ACCUMULATOR (preferred_element_type), not a
    result cast: ``convert(dot_bf16)`` is algebraically rewritten to
    ``dot(convert(k))`` — materializing an fp32 copy of the whole KV
    cache in the decode path."""
    d = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q * (d ** -0.5), k,
                        preferred_element_type=jnp.float32)
    scores = _softcap(scores, softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def full_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True,
    softcap: float = 0.0,
    q_chunk: int = 0,
) -> jax.Array:
    """Masked softmax attention.

    ``q_chunk`` > 0 streams query blocks through ``lax.map`` so the
    (Sq, Sk) score buffer never exceeds (q_chunk, Sk) — the XLA
    stand-in for the Pallas flash kernel's VMEM blocking."""
    b, sq, hq, d = q.shape
    kf = _repeat_kv(k, hq)
    vf = _repeat_kv(v, hq)
    sk = kf.shape[1]

    if not q_chunk or sq <= q_chunk:
        mask = None
        if causal:
            mask = jnp.tril(jnp.ones((sq, sk), jnp.bool_), k=sk - sq)
        return _sdp(q, kf, vf, mask, softcap)

    nq = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qb = q.reshape(b, nq, q_chunk, hq, d).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint  # map-bwd must not stack per-chunk score residuals
    def blk(args):
        qi, idx = args
        mask = None
        if causal:
            qpos = idx * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = jnp.arange(sk)[None, :]
            mask = qpos >= kpos
        return _sdp(qi, kf, vf, mask, softcap)

    out = jax.lax.map(blk, (qb, jnp.arange(nq)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, d)


def local_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    window: int,
    causal: bool = True,
    q_chunk: int = 0,
) -> jax.Array:
    """Chunk-banded sliding-window attention.

    Queries in chunk c attend to keys in chunks c-1 and c, masked to the
    true window: allowed iff 0 <= q_pos - k_pos < window.  Exact for
    window <= chunk width (we use chunk = window).  ``q_chunk`` streams
    the chunk axis through ``lax.map`` to bound the live score buffer.
    """
    b, s, hq, d = q.shape
    w = min(window, s)
    if s % w != 0:
        pad = w - s % w
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        pad = 0
    sp = q.shape[1]
    c = sp // w
    kf = _repeat_kv(k, hq)
    vf = _repeat_kv(v, hq)
    qc = q.reshape(b, c, w, hq, d)
    kc = kf.reshape(b, c, w, hq, d)
    vc = vf.reshape(b, c, w, hq, d)
    # previous chunk: shift right; chunk 0's "previous" is masked out
    k2 = jnp.concatenate([jnp.roll(kc, 1, axis=1), kc], axis=2)  # (B,C,2W,·)
    v2 = jnp.concatenate([jnp.roll(vc, 1, axis=1), vc], axis=2)

    i = jnp.arange(w)[:, None]
    j = jnp.arange(2 * w)[None, :]
    dist = i + w - j
    band = (dist >= 0) & (dist < w) if causal else (jnp.abs(dist) < w)

    @jax.checkpoint  # see full_attention: keep map-bwd residual-free
    def one_chunk(args):
        qi, ki, vi, idx = args                     # (B,W,H,D)/(B,2W,H,D)
        mask = band & ~((idx == 0) & (j < w))      # (W, 2W)
        return _sdp(qi, ki, vi, mask[None, None], 0.0)

    if q_chunk:
        out = jax.lax.map(
            one_chunk,
            (qc.transpose(1, 0, 2, 3, 4), k2.transpose(1, 0, 2, 3, 4),
             v2.transpose(1, 0, 2, 3, 4), jnp.arange(c)))
        out = out.transpose(1, 0, 2, 3, 4)
    else:
        scores = jnp.einsum("bcqhd,bckhd->bchqk", qc * (d ** -0.5), k2
                            ).astype(jnp.float32)
        chunk_idx = jnp.arange(c)[:, None, None]
        mask = band[None] & ~((chunk_idx == 0) & (j[None] < w))  # (C,W,2W)
        scores = jnp.where(mask[None, :, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bchqk,bckhd->bcqhd", probs, v2)
    out = out.reshape(b, sp, hq, d)
    return out[:, :s] if pad else out


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    lengths: jax.Array,
    softcap: float = 0.0,
) -> jax.Array:
    """One new query per sequence against the KV cache.

    q (B,1,Hq,D); caches (B,T,Hkv,D); lengths (B,) valid entries.

    Formulated as broadcast-multiply-reduce rather than dots: XLA fuses
    the product into the reduction, so neither a GQA-expanded KV copy
    nor an fp32-converted cache is ever materialized (XLA-CPU emulates
    bf16 dots by fp32-converting whole operands — fatal at 32k-deep
    caches; TPU Mosaic is unaffected but the fused form is never worse).
    """
    b, _, hq, d = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = (q[:, 0].reshape(b, hkv, g, d) * (d ** -0.5))      # (B,Hkv,G,D)
    # flash-decode: stream KV blocks with an online softmax.  Block-wise
    # dynamic slices defeat XLA's loop-invariant convert hoisting (which
    # otherwise materializes an fp32 copy of the WHOLE cache) and bound
    # live temps to one (B,blk,Hkv,G,D) product.
    blk = t if t % 4096 else 4096
    nb = t // blk

    def body(carry, idx):
        m, l, acc = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_cache, idx * blk, blk, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(v_cache, idx * blk, blk, 1)
        s = jnp.sum(qg[:, None] * k_blk[:, :, :, None, :], axis=-1,
                    dtype=jnp.float32)                       # (B,blk,Hkv,G)
        s = _softcap(s, softcap)
        kpos = idx * blk + jnp.arange(blk)
        valid = (kpos[None, :] < lengths[:, None])[:, :, None, None]
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))           # (B,Hkv,G)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[..., None] + jnp.sum(
            p[..., None].astype(v_blk.dtype) * v_blk[:, :, :, None, :],
            axis=1, dtype=jnp.float32)                       # (B,Hkv,G,D)
        return (m_new, l, acc), None

    init = (jnp.full((b, hkv, g), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g), jnp.float32),
            jnp.zeros((b, hkv, g, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(nb))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention sub-block (projections + rope + attention + out-proj)
# ---------------------------------------------------------------------------

def attn_block(
    x: jax.Array,
    p: dict[str, jax.Array],
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    kind: str,                 # attn | swa | enc
    window: int,
    positions: jax.Array,
    rope_theta: float,
    q_chunk: int = 0,
    softcap: float = 0.0,
    qk_norm: bool = False,
    norm_eps: float = 1e-6,
    compute_dtype: Any = jnp.bfloat16,
    use_kernels: bool = False,
    cache: dict[str, jax.Array] | None = None,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Complete attention sub-layer.  With ``cache`` (decode), x is
    (B,1,d) and the cache is updated at ``cache['pos']``."""
    b, s, _ = x.shape
    xc = cast(x, compute_dtype)
    q = (xc @ cast(p["wq"], compute_dtype)).reshape(b, s, n_heads, head_dim)
    k = (xc @ cast(p["wk"], compute_dtype)).reshape(b, s, n_kv_heads, head_dim)
    v = (xc @ cast(p["wv"], compute_dtype)).reshape(b, s, n_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    if kind != "enc" or True:  # encoders also use rope here (positional)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    new_cache = None
    if cache is not None:
        # decode: write k,v at pos (ring for SWA), then attend over cache
        t = cache["k"].shape[1]
        pos = cache["pos"]                                  # scalar int32
        slot = jnp.where(jnp.asarray(window > 0), pos % t, pos) if kind == "swa" else pos
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        lengths = jnp.minimum(pos + 1, t) * jnp.ones((b,), jnp.int32)
        out = decode_attention(q, k_cache, v_cache, lengths, softcap)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    elif kind == "swa" and window and s > window:
        out = local_attention(q, k, v, window, causal=True, q_chunk=q_chunk)
    elif kind == "enc":
        out = full_attention(q, k, v, causal=False, softcap=softcap,
                             q_chunk=q_chunk)
    else:
        if use_kernels:
            from repro.kernels import ops as kops
            out = kops.flash_attention(
                q, k, v, causal=True,
                window=window if kind == "swa" else 0)
        else:
            out = full_attention(q, k, v, causal=True, softcap=softcap,
                                 q_chunk=q_chunk)
    out = out.reshape(b, s, n_heads * head_dim)
    return out @ cast(p["wo"], compute_dtype), new_cache
