"""Mixture-of-Experts FFN: shared + routed experts, top-k routing.

Two dispatch strategies (selected by ``ArchConfig.moe_dispatch``):

* ``einsum`` — GShard-style capacity-based one-hot dispatch/combine
  einsums.  Partitions cleanly under pjit (everything is einsums) but
  pays ~2× FLOPs overhead for the dispatch tensors and drops tokens on
  capacity overflow.  This is the BASELINE.
* ``ragged`` — dropless sort-based dispatch: tokens are sorted by expert
  id and multiplied with per-expert weight slabs via
  ``jax.lax.ragged_dot``.  No dispatch-FLOPs, no drops.  Used by the
  §Perf hillclimb (and by the Pallas grouped-GEMM kernel path on TPU).

Expert weights are TP-sharded on ``moe_d_ff`` (each model shard holds a
slice of EVERY expert), so both strategies compose with the data/model
mesh without all_to_all re-sharding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.jax_compat import get_abstract_mesh, mesh_axis_names, shard_map

from .layers import _act, cast, maybe_shard


def router_probs(x: jax.Array, w_router: jax.Array) -> jax.Array:
    """Router softmax in fp32. x (T,d) → probs (T,E)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1), logits


def load_balance_loss(probs: jax.Array, expert_mask: jax.Array,
                      n_experts: int, top_k: int) -> jax.Array:
    """Switch-style auxiliary loss: E · Σ_e f_e · p_e.

    probs (T,E) router probabilities; expert_mask (T,E) count of the
    token's k slots that chose each expert.
    """
    f = jnp.mean(expert_mask.astype(jnp.float32), axis=0) / top_k
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def router_z_loss(logits: jax.Array) -> jax.Array:
    return jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))


def _expert_ffn(h_in: jax.Array, p: dict[str, jax.Array], act: str,
                compute_dtype: Any) -> jax.Array:
    """Batched per-expert gated FFN: h_in (G, E, C, d) → (G, E, C, d).

    Kept 4-D end to end: folding G into C would merge a data-sharded
    axis with a model-sharded one and force GSPMD to replicate."""
    fn = _act(act)
    gate = jnp.einsum("gecd,edf->gecf", h_in, cast(p["wi_gate"], compute_dtype))
    up = jnp.einsum("gecd,edf->gecf", h_in, cast(p["wi_up"], compute_dtype))
    return jnp.einsum("gecf,efd->gecd", fn(gate) * up,
                      cast(p["wo"], compute_dtype))


def moe_einsum(
    x: jax.Array,                  # (T, d) — flattened tokens
    p: dict[str, Any],
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float,
    act: str,
    router_renorm: bool,
    groups: int,
    compute_dtype: Any = jnp.bfloat16,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """GShard capacity dispatch.  Tokens reshaped to (G, Tg); capacity is
    per-group.  Returns (output (T,d), aux losses)."""
    t_total, d = x.shape
    g = max(1, min(groups, t_total))
    while t_total % g:
        g -= 1
    tg = t_total // g
    capacity = max(top_k, int(tg * top_k * capacity_factor / n_experts))
    capacity = ((capacity + 31) // 32) * 32   # model-axis shardable
    xg = x.reshape(g, tg, d)

    probs, logits = router_probs(xg.reshape(-1, d), p["router"])
    probs = probs.reshape(g, tg, n_experts)
    logits = logits.reshape(g, tg, n_experts)

    top_p, top_idx = jax.lax.top_k(probs, top_k)            # (G,Tg,K)
    if router_renorm:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32)  # (G,Tg,K,E)
    # position of each (token,k) within its expert queue, per group
    pos = jnp.cumsum(onehot.reshape(g, tg * top_k, n_experts), axis=1)
    pos = pos.reshape(g, tg, top_k, n_experts) * onehot - 1.0
    keep = ((pos >= 0) & (pos < capacity)).astype(jnp.float32)
    sel = onehot * keep                                      # (G,Tg,K,E)
    # top-k experts are distinct per token → at most one k hits each e,
    # so the k axis collapses BEFORE the capacity one-hot (avoids the
    # (G,Tg,K,E,C) rank-5 blow-up)
    pos_te = jnp.sum((pos + 1.0) * sel, axis=2) - 1.0        # (G,Tg,E)
    m_te = jnp.sum(sel, axis=2)
    w_te = jnp.sum(top_p[..., None].astype(jnp.float32) * sel, axis=2)
    cap_oh = jax.nn.one_hot(pos_te.astype(jnp.int32), capacity,
                            dtype=compute_dtype)             # (G,Tg,E,C)
    cap_oh = cap_oh * (m_te > 0)[..., None].astype(compute_dtype)
    # capacity dim sharded over the model axis: bounds every (G,Tg,E,C)
    # intermediate (incl. their f32 cotangents) to 1/TP per device
    dispatch = maybe_shard(cap_oh, "data", None, None, "model")
    combine = maybe_shard(
        cap_oh * w_te[..., None].astype(compute_dtype),
        "data", None, None, "model")

    xin = maybe_shard(
        jnp.einsum("gtec,gtd->gecd", dispatch, cast(xg, compute_dtype)),
        "data", None, "model", None)
    h = maybe_shard(_expert_ffn(xin, p, act, compute_dtype),
                    "data", None, "model", None)
    out = jnp.einsum("gtec,gecd->gtd", combine, h)

    mask = jnp.sum(onehot, axis=2)                          # (G,Tg,E)
    aux = {
        "load_balance": load_balance_loss(
            probs.reshape(-1, n_experts),
            mask.reshape(-1, n_experts), n_experts, top_k),
        "router_z": router_z_loss(logits.reshape(-1, n_experts)),
        "dropped": jnp.mean(1.0 - jnp.sum(keep, axis=(2, 3)) / top_k),
    }
    return out.reshape(t_total, d).astype(x.dtype), aux


def moe_ragged(
    x: jax.Array,                  # (T, d)
    p: dict[str, Any],
    *,
    n_experts: int,
    top_k: int,
    act: str,
    router_renorm: bool,
    compute_dtype: Any = jnp.bfloat16,
    **_: Any,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Dropless sort-based dispatch with ragged_dot grouped matmuls."""
    t, d = x.shape
    probs, logits = router_probs(x, p["router"])
    top_p, top_idx = jax.lax.top_k(probs, top_k)            # (T,K)
    if router_renorm:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_expert = top_idx.reshape(-1)                       # (T*K,)
    order = jnp.argsort(flat_expert)                        # stable
    token_of = order // top_k
    xs = jnp.take(cast(x, compute_dtype), token_of, axis=0)  # (T*K, d) sorted
    group_sizes = jnp.bincount(flat_expert, length=n_experts).astype(jnp.int32)

    fn = _act(act)
    gate = jax.lax.ragged_dot(xs, cast(p["wi_gate"], compute_dtype), group_sizes)
    up = jax.lax.ragged_dot(xs, cast(p["wi_up"], compute_dtype), group_sizes)
    h = jax.lax.ragged_dot(fn(gate) * up, cast(p["wo"], compute_dtype),
                           group_sizes)                      # (T*K, d)
    # un-sort and weight-combine
    weights = jnp.take(top_p.reshape(-1), order).astype(jnp.float32)
    h = h.astype(jnp.float32) * weights[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[token_of].add(h)

    onehot = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32)
    aux = {
        "load_balance": load_balance_loss(
            probs, onehot.sum(axis=1), n_experts, top_k),
        "router_z": router_z_loss(logits),
        "dropped": jnp.zeros((), jnp.float32),
    }
    return out.astype(x.dtype), aux


def moe_sorted_local(
    x: jax.Array,                  # (T, d) — one device's tokens
    p: dict[str, Any],
    *,
    n_experts: int,
    top_k: int,
    act: str,
    router_renorm: bool,
    compute_dtype: Any,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Sort + capacity-padded grouped GEMM (megablox-shaped, pure XLA).

    Dispatch is gathers/scatters (zero FLOPs); expert compute is one
    MXU-aligned batched matmul of (E, Cl, d)·(E, d, f).  Cl is padded to
    a multiple of 128; overflow beyond capacity_factor× mean load drops
    (reported in aux).  On TPU the batched matmul is replaced by the
    Pallas ``grouped_matmul`` kernel."""
    t, d = x.shape
    probs, logits = router_probs(x, p["router"])
    top_p, top_idx = jax.lax.top_k(probs, top_k)            # (T,K)
    if router_renorm:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    tk = t * top_k
    cl = int(tk * capacity_factor / n_experts)
    cl = max(128, ((cl + 127) // 128) * 128)

    flat_expert = top_idx.reshape(-1)                       # (T*K,)
    order = jnp.argsort(flat_expert)
    sorted_expert = jnp.take(flat_expert, order)
    token_of = order // top_k
    # position within the expert segment (sorted → runs are contiguous)
    pos_in_run = jnp.arange(tk, dtype=jnp.int32) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left").astype(jnp.int32)
    keep = pos_in_run < cl
    dest = jnp.where(keep, sorted_expert * cl + pos_in_run, n_experts * cl)

    xs = jnp.take(cast(x, compute_dtype), token_of, axis=0)  # (T*K, d)
    xin = jnp.zeros((n_experts * cl + 1, d), compute_dtype
                    ).at[dest].set(xs)[:-1]
    xin = xin.reshape(n_experts, cl, d)

    fn = _act(act)
    gate = jnp.einsum("ecd,edf->ecf", xin, cast(p["wi_gate"], compute_dtype))
    up = jnp.einsum("ecd,edf->ecf", xin, cast(p["wi_up"], compute_dtype))
    h = jnp.einsum("ecf,efd->ecd", fn(gate) * up,
                   cast(p["wo"], compute_dtype))             # (E, Cl, d)

    h_rows = jnp.take(
        h.reshape(n_experts * cl, d),
        jnp.minimum(dest, n_experts * cl - 1), axis=0)
    w = (jnp.take(top_p.reshape(-1), order)
         * keep.astype(jnp.float32))[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[token_of].add(
        h_rows.astype(jnp.float32) * w)

    onehot = jax.nn.one_hot(top_idx, n_experts, dtype=jnp.float32)
    aux = {
        "load_balance": load_balance_loss(
            probs, onehot.sum(axis=1), n_experts, top_k),
        "router_z": router_z_loss(logits),
        "dropped": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.astype(x.dtype), aux


def moe_ragged_sharded(
    x: jax.Array,                  # (B, S, d)
    p: dict[str, Any],
    *,
    n_experts: int,
    top_k: int,
    act: str,
    router_renorm: bool,
    compute_dtype: Any,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Dropless ragged dispatch under ``shard_map`` (TPU-native).

    GSPMD cannot partition a *global* token sort, so the sort becomes
    per-device: each data shard sorts ITS tokens locally and runs
    ragged_dot against the ffm-TP-sliced expert slabs held by its model
    shard; one psum over "model" combines the ffm partial sums.  Per
    layer this costs one AG(x) + one psum(out) instead of the einsum
    dispatch's O(E·C) traffic — and zero dispatch FLOPs."""
    am = get_abstract_mesh()
    names = getattr(am, "axis_names", None) or ()
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    P_ = jax.sharding.PartitionSpec

    def local_fn(x_loc, router, wig, wiu, wo):
        b_loc, s, d = x_loc.shape
        flat = x_loc.reshape(-1, d)
        out, aux = moe_sorted_local(
            flat, {"router": router, "wi_gate": wig, "wi_up": wiu,
                   "wo": wo},
            n_experts=n_experts, top_k=top_k, act=act,
            router_renorm=router_renorm, compute_dtype=compute_dtype)
        out = jax.lax.psum(out.astype(jnp.float32), "model")
        if dp:
            aux = jax.tree.map(lambda v: jax.lax.pmean(v, dp), aux)
        return out.reshape(b_loc, s, d).astype(x_loc.dtype), aux

    return shard_map(
        local_fn, mesh=am,
        in_specs=(P_(dp_entry, None, None), P_(None, None),
                  P_(None, None, "model"), P_(None, None, "model"),
                  P_(None, "model", None)),
        out_specs=(P_(dp_entry, None, None),
                   jax.tree.map(lambda _: P_(), ZERO_AUX_SPEC)),
    )(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])


ZERO_AUX_SPEC = {"load_balance": 0, "router_z": 0, "dropped": 0}


def moe_block(
    x: jax.Array,                  # (B, S, d)
    p: dict[str, Any],
    *,
    n_experts: int,
    n_shared: int,
    top_k: int,
    capacity_factor: float,
    act: str,
    router_renorm: bool,
    dispatch: str,
    groups: int,
    compute_dtype: Any = jnp.bfloat16,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Full MoE FFN: routed experts (+ optional fused shared expert)."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    am_names = mesh_axis_names()
    if dispatch == "ragged" and "model" in am_names:
        routed_bsd, aux = moe_ragged_sharded(
            x, p, n_experts=n_experts, top_k=top_k, act=act,
            router_renorm=router_renorm, compute_dtype=compute_dtype)
        routed = routed_bsd.reshape(b * s, d)
    elif dispatch == "ragged":
        routed, aux = moe_ragged(
            flat, p, n_experts=n_experts, top_k=top_k, act=act,
            router_renorm=router_renorm, compute_dtype=compute_dtype)
    else:
        routed, aux = moe_einsum(
            flat, p, n_experts=n_experts, top_k=top_k,
            capacity_factor=capacity_factor, act=act,
            router_renorm=router_renorm, groups=groups,
            compute_dtype=compute_dtype)
    out = routed
    if n_shared:
        fn = _act(act)
        xc = cast(flat, compute_dtype)
        sp = p["shared"]
        gate = xc @ cast(sp["wi_gate"], compute_dtype)
        up = xc @ cast(sp["wi_up"], compute_dtype)
        shared = (fn(gate) * up) @ cast(sp["wo"], compute_dtype)
        # qwen2-moe gates the shared expert with a sigmoid token gate
        sg = jax.nn.sigmoid(
            (flat.astype(jnp.float32) @ sp["gate"].astype(jnp.float32)))
        out = out + (shared.astype(jnp.float32) * sg).astype(out.dtype)
    return out.reshape(b, s, d), aux
