"""Model assembly: embeddings → scan-compiled layer segments → head.

Consecutive layers of the same kind are grouped into *segments*; each
segment's parameters are stacked on a leading axis and executed with
``jax.lax.scan`` (one trace per segment → fast compiles for 48-layer
models).  Heterogeneous patterns (gemma3's 5 local : 1 global, hymba's
3 global layers) become short segment lists that preserve exact layer
order.

Aux losses (MoE load-balance / router-z) are accumulated through the
scan carry.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .attention import attn_block
from .config import ArchConfig
from .layers import (
    cast, embed_tokens, layer_norm, mlp, normal_init, rms_norm, unembed,
)
from .moe import moe_block
from .ssm import init_ssm_cache, mamba2_block

ZERO_AUX = lambda: {  # noqa: E731
    "load_balance": jnp.zeros((), jnp.float32),
    "router_z": jnp.zeros((), jnp.float32),
    "dropped": jnp.zeros((), jnp.float32),
}


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _init_attn(key: jax.Array, cfg: ArchConfig) -> dict[str, jax.Array]:
    ks = jax.random.split(key, 4)
    d, ad, kd = cfg.d_model, cfg.attn_dim, cfg.n_kv_heads * cfg.head_dim
    dt = cfg.param_dtype
    p = {
        "wq": normal_init(ks[0], (d, ad), dt),
        "wk": normal_init(ks[1], (d, kd), dt),
        "wv": normal_init(ks[2], (d, kd), dt),
        "wo": normal_init(ks[3], (ad, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), dt)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), dt)
    return p


def _init_mlp(key: jax.Array, cfg: ArchConfig) -> dict[str, jax.Array]:
    ks = jax.random.split(key, 3)
    d, ff, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    if cfg.mlp_act == "gelu_nogate":
        return {
            "wi": normal_init(ks[0], (d, ff), dt),
            "bi": jnp.zeros((ff,), dt),
            "wo": normal_init(ks[1], (ff, d), dt),
            "bo": jnp.zeros((d,), dt),
        }
    return {
        "wi_gate": normal_init(ks[0], (d, ff), dt),
        "wi_up": normal_init(ks[1], (d, ff), dt),
        "wo": normal_init(ks[2], (ff, d), dt),
    }


def _init_moe(key: jax.Array, cfg: ArchConfig) -> dict[str, Any]:
    ks = jax.random.split(key, 8)
    d, ffm, e, dt = cfg.d_model, cfg.moe_d_ff, cfg.n_experts, cfg.param_dtype
    p: dict[str, Any] = {
        "router": normal_init(ks[0], (d, e), dt),
        "wi_gate": normal_init(ks[1], (e, d, ffm), dt),
        "wi_up": normal_init(ks[2], (e, d, ffm), dt),
        "wo": normal_init(ks[3], (e, ffm, d), dt),
    }
    if cfg.n_shared_experts:
        ffs = cfg.d_ff
        p["shared"] = {
            "wi_gate": normal_init(ks[4], (d, ffs), dt),
            "wi_up": normal_init(ks[5], (d, ffs), dt),
            "wo": normal_init(ks[6], (ffs, d), dt),
            "gate": normal_init(ks[7], (d, 1), dt),
        }
    return p


def _init_ssm(key: jax.Array, cfg: ArchConfig) -> dict[str, jax.Array]:
    ks = jax.random.split(key, 3)
    d, di, dt = cfg.d_model, cfg.d_inner, cfg.param_dtype
    h = cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    conv_ch = di + 2 * gn
    a_init = jnp.linspace(1.0, 16.0, h)
    return {
        "in_proj": normal_init(ks[0], (d, 2 * di + 2 * gn + h), dt),
        "conv_w": normal_init(ks[1], (cfg.ssm_conv, conv_ch), dt, 0.2),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((h,), dt),
        "A_log": jnp.log(a_init).astype(dt),
        "D": jnp.ones((h,), dt),
        "norm": jnp.zeros((di,), dt),
        "out_proj": normal_init(ks[2], (di, d), dt),
    }


def _init_layer(key: jax.Array, cfg: ArchConfig, kind: str) -> dict[str, Any]:
    ks = jax.random.split(key, 4)
    d, dt = cfg.d_model, cfg.param_dtype
    p: dict[str, Any] = {}
    if kind == "enc":
        p["norm1"] = {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}
        p["norm2"] = {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}
    else:
        p["norm1"] = jnp.zeros((d,), dt)
        if kind != "ssm":
            p["norm2"] = jnp.zeros((d,), dt)
    if kind in ("attn", "swa", "enc", "moe", "hyb_g", "hyb_l"):
        p["attn"] = _init_attn(ks[0], cfg)
    if kind in ("ssm", "hyb_g", "hyb_l"):
        p["ssm"] = _init_ssm(ks[1], cfg)
    if kind in ("hyb_g", "hyb_l"):
        p["branch_norm_attn"] = jnp.zeros((d,), dt)
        p["branch_norm_ssm"] = jnp.zeros((d,), dt)
    if kind == "moe":
        p["moe"] = _init_moe(ks[2], cfg)
    elif kind in ("attn", "swa", "enc", "hyb_g", "hyb_l") and cfg.d_ff:
        p["mlp"] = _init_mlp(ks[3], cfg)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 4)
    dt = cfg.param_dtype
    params: dict[str, Any] = {}
    params["embed"] = normal_init(keys[0], (cfg.padded_vocab, cfg.d_model), dt)
    if cfg.input_mode in ("embeds", "mixed"):
        params["frontend_proj"] = normal_init(
            keys[1], (cfg.d_model, cfg.d_model), dt)
    # segments: stack per-layer params along a new leading axis
    segments: list[dict[str, Any]] = []
    li = 0
    for kind, count in cfg.segments():
        layers = [_init_layer(keys[2 + li + i], cfg, kind) for i in range(count)]
        li += count
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *layers)
        segments.append(stacked)
    params["segments"] = segments
    if cfg.layer_types and cfg.layer_types[0] == "enc":
        params["final_norm"] = {"scale": jnp.ones((cfg.d_model,), dt),
                                "bias": jnp.zeros((cfg.d_model,), dt)}
    else:
        params["final_norm"] = jnp.zeros((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(
            keys[2 + cfg.n_layers], (cfg.d_model, cfg.padded_vocab), dt)
    return params


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _norm(x: jax.Array, p: Any, eps: float) -> jax.Array:
    if isinstance(p, dict):
        return layer_norm(x, p["scale"], p["bias"], eps)
    return rms_norm(x, p, eps)


def _attn_sublayer(cfg: ArchConfig, kind: str, x: jax.Array,
                   lp: dict[str, Any], positions: jax.Array,
                   cache: dict[str, jax.Array] | None
                   ) -> tuple[jax.Array, Any]:
    attn_kind = {"moe": "attn", "hyb_g": "attn", "hyb_l": "swa"}.get(kind, kind)
    theta = (cfg.rope_theta_global if attn_kind == "attn"
             else cfg.rope_theta)
    return attn_block(
        x, lp["attn"],
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, kind=attn_kind, window=cfg.window,
        positions=positions, rope_theta=theta,
        q_chunk=cfg.attn_q_chunk,
        softcap=cfg.logit_softcap, qk_norm=cfg.qk_norm,
        norm_eps=cfg.norm_eps, compute_dtype=cfg.compute_dtype,
        use_kernels=cfg.use_kernels, cache=cache)


def _ffn_sublayer(cfg: ArchConfig, kind: str, x: jax.Array,
                  lp: dict[str, Any], moe_groups: int
                  ) -> tuple[jax.Array, dict[str, jax.Array]]:
    if kind == "moe":
        return moe_block(
            x, lp["moe"],
            n_experts=cfg.n_experts, n_shared=cfg.n_shared_experts,
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            act=cfg.mlp_act, router_renorm=cfg.router_renorm,
            dispatch=cfg.moe_dispatch, groups=moe_groups,
            compute_dtype=cfg.compute_dtype)
    return mlp(x, lp["mlp"], cfg.mlp_act, cfg.compute_dtype), ZERO_AUX()


def layer_body(
    cfg: ArchConfig,
    kind: str,
    x: jax.Array,
    lp: dict[str, Any],
    positions: jax.Array,
    moe_groups: int,
    cache: dict[str, jax.Array] | None = None,
) -> tuple[jax.Array, dict[str, jax.Array], Any]:
    """One layer: returns (x, aux, new_cache)."""
    eps = cfg.norm_eps
    aux = ZERO_AUX()
    new_cache = None
    h = _norm(x, lp["norm1"], eps)

    if kind == "ssm":
        y, new_cache = mamba2_block(
            h, lp["ssm"], d_inner=cfg.d_inner, state_dim=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
            conv_width=cfg.ssm_conv, chunk=cfg.ssm_chunk,
            compute_dtype=cfg.compute_dtype,
            cache=cache, use_kernels=cfg.use_kernels)
        return x + y.astype(x.dtype), aux, new_cache

    if kind in ("hyb_g", "hyb_l"):
        attn_cache = cache["attn"] if cache is not None else None
        ssm_cache = cache["ssm"] if cache is not None else None
        a_out, new_attn_cache = _attn_sublayer(cfg, kind, h, lp, positions,
                                               attn_cache)
        s_out, new_ssm_cache = mamba2_block(
            h, lp["ssm"], d_inner=cfg.d_inner, state_dim=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
            conv_width=cfg.ssm_conv, chunk=cfg.ssm_chunk,
            compute_dtype=cfg.compute_dtype,
            cache=ssm_cache, use_kernels=cfg.use_kernels)
        # Hymba output fusion: mean of per-branch normalized outputs
        y = 0.5 * (rms_norm(a_out, lp["branch_norm_attn"], eps)
                   + rms_norm(s_out.astype(a_out.dtype),
                              lp["branch_norm_ssm"], eps))
        x = x + y.astype(x.dtype)
        if cache is not None:
            new_cache = {"attn": new_attn_cache, "ssm": new_ssm_cache}
    else:
        a_out, new_cache = _attn_sublayer(cfg, kind, h, lp, positions, cache)
        x = x + a_out.astype(x.dtype)

    h2 = _norm(x, lp["norm2"], eps)
    f_out, aux = _ffn_sublayer(cfg, kind, h2, lp, moe_groups)
    return x + f_out.astype(x.dtype), aux, new_cache


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ArchConfig, params: dict[str, Any],
                  batch: dict[str, jax.Array]) -> jax.Array:
    cd = cfg.compute_dtype
    if cfg.input_mode == "tokens":
        return embed_tokens(batch["tokens"], params["embed"],
                            cfg.embed_scale, cd)
    if cfg.input_mode == "embeds":
        return cast(batch["embeds"], cd) @ cast(params["frontend_proj"], cd)
    # mixed (vlm): projected patch embeddings then token embeddings
    patches = cast(batch["patch_embeds"], cd) @ cast(params["frontend_proj"], cd)
    tokens = embed_tokens(batch["tokens"], params["embed"],
                          cfg.embed_scale, cd)
    return jnp.concatenate([patches, tokens], axis=1)


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def backbone(
    cfg: ArchConfig,
    params: dict[str, Any],
    batch: dict[str, jax.Array],
    moe_groups: int = 1,
    seq_spec: Any = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Embeddings → layers → final norm.  Returns (x (B,S,d), aux).

    ``seq_spec`` (a sharding for (B,S,d) activations) enables
    sequence-parallel residual-stream sharding: the constraint is applied
    inside each scan body so the remat-saved carry is stored sharded —
    the memory lever that fits 26B-scale activations per chip.
    """
    def _constrain(t: jax.Array) -> jax.Array:
        if seq_spec is None:
            return t
        return jax.lax.with_sharding_constraint(t, seq_spec)

    x = _constrain(_embed_inputs(cfg, params, batch))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux_total = ZERO_AUX()

    for (kind, count), seg_params in zip(cfg.segments(), params["segments"]):
        def seg_body(carry, lp, _kind=kind):
            xc, aux_acc = carry
            xn, aux, _ = layer_body(cfg, _kind, xc, lp, positions, moe_groups)
            aux_acc = jax.tree.map(jnp.add, aux_acc, aux)
            return (_constrain(xn), aux_acc), None

        body = _remat(cfg, seg_body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg_params)

    x = _norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def _head(cfg: ArchConfig, params: dict[str, Any]) -> jax.Array:
    return (params["lm_head"] if not cfg.tie_embeddings
            else params["embed"].T)


def forward(
    cfg: ArchConfig,
    params: dict[str, Any],
    batch: dict[str, jax.Array],
    moe_groups: int = 1,
    seq_spec: Any = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Full forward pass → (logits (B,S,V), aux losses)."""
    x, aux_total = backbone(cfg, params, batch, moe_groups, seq_spec)
    logits = unembed(x, _head(cfg, params), cfg.compute_dtype)
    return logits[..., :cfg.vocab_size], aux_total


def _ce_terms(x: jax.Array, head: jax.Array, labels: jax.Array,
              compute_dtype: Any, vocab_size: int) -> jax.Array:
    """Summed masked NLL for one (B,C,d) slice (logits never escape).
    Pad-vocab columns (>= vocab_size) are masked out of the softmax."""
    logits = unembed(x, head, compute_dtype).astype(jnp.float32)
    if logits.shape[-1] > vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(col < vocab_size, logits, -1e30)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    return jnp.sum((lse - gold) * mask)


def loss_fn(
    cfg: ArchConfig,
    params: dict[str, Any],
    batch: dict[str, jax.Array],
    moe_groups: int = 1,
    seq_spec: Any = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Masked causal-LM cross entropy (+ MoE aux).  labels < 0 ignored.

    With ``cfg.loss_chunk`` the CE is computed over sequence chunks
    (unrolled + rematerialized) so the (B,S,V) logits are never resident
    — the standard big-vocab memory fix."""
    x, aux = backbone(cfg, params, batch, moe_groups, seq_spec)
    labels = batch["labels"]
    head = _head(cfg, params)
    if seq_spec is not None and hasattr(seq_spec, "mesh"):
        # pin the (d, V) head so the CE-scan grad accumulator stays
        # vocab-sharded (GSPMD loses it through the tied-embed transpose)
        from jax.sharding import NamedSharding, PartitionSpec
        head = jax.lax.with_sharding_constraint(
            head, NamedSharding(seq_spec.mesh, PartitionSpec(None, "model")))
    b, s, d = x.shape
    chunk = cfg.loss_chunk
    if chunk and s > chunk and s % chunk == 0:
        nc = s // chunk
        xs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
        ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

        def ce_body(acc, inp):
            xc, lc = inp
            return acc + _ce_terms(xc, head, lc, cfg.compute_dtype,
                                   cfg.vocab_size), None

        nll_sum, _ = jax.lax.scan(jax.checkpoint(ce_body),
                                  jnp.zeros((), jnp.float32), (xs, ls))
    else:
        nll_sum = _ce_terms(x, head, labels, cfg.compute_dtype,
                            cfg.vocab_size)
    denom = jnp.maximum((labels >= 0).sum(), 1).astype(jnp.float32)
    ce = nll_sum / denom
    loss = (ce
            + 0.01 * aux["load_balance"]
            + 0.001 * aux["router_z"])
    metrics = {"ce": ce, "loss": loss, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode (serve) path
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype: Any = jnp.bfloat16) -> dict[str, Any]:
    """Per-segment stacked decode caches."""
    segments = []
    for kind, count in cfg.segments():
        def one(kind=kind):
            c: dict[str, Any] = {}
            if kind in ("attn", "moe", "enc", "hyb_g"):
                t = max_len
            elif kind in ("swa", "hyb_l"):
                t = min(cfg.window, max_len) if cfg.window else max_len
            if kind in ("attn", "swa", "moe", "enc"):
                c = {"k": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), dtype),
                     "v": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), dtype)}
            elif kind == "ssm":
                c = init_ssm_cache(batch, cfg.d_inner, cfg.ssm_state,
                                   cfg.ssm_head_dim, cfg.ssm_groups,
                                   cfg.ssm_conv, dtype)
                c.pop("pos")
            elif kind in ("hyb_g", "hyb_l"):
                sc = init_ssm_cache(batch, cfg.d_inner, cfg.ssm_state,
                                    cfg.ssm_head_dim, cfg.ssm_groups,
                                    cfg.ssm_conv, dtype)
                sc.pop("pos")
                c = {"attn": {"k": jnp.zeros((batch, t, cfg.n_kv_heads,
                                              cfg.head_dim), dtype),
                              "v": jnp.zeros((batch, t, cfg.n_kv_heads,
                                              cfg.head_dim), dtype)},
                     "ssm": sc}
            return c
        layers = [one() for _ in range(count)]
        segments.append(jax.tree.map(lambda *xs: jnp.stack(xs, 0), *layers))
    return {"pos": jnp.zeros((), jnp.int32), "segments": segments}


def decode_step(
    cfg: ArchConfig,
    params: dict[str, Any],
    cache: dict[str, Any],
    token: jax.Array,          # (B, 1) int32
) -> tuple[jax.Array, dict[str, Any]]:
    """One autoregressive step → (logits (B,V), new cache)."""
    if not cfg.has_decode():
        raise ValueError(f"{cfg.name} is encoder-only; no decode step")
    pos = cache["pos"]
    b = token.shape[0]
    x = embed_tokens(token, params["embed"], cfg.embed_scale, cfg.compute_dtype)
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)

    new_segments = []
    for (kind, count), seg_params, seg_cache in zip(
            cfg.segments(), params["segments"], cache["segments"]):

        def seg_body(xc, inp, _kind=kind):
            lp, lc = inp
            if _kind in ("attn", "swa", "moe", "enc"):
                lc = {**lc, "pos": pos}
            elif _kind in ("hyb_g", "hyb_l"):
                lc = {"attn": {**lc["attn"], "pos": pos},
                      "ssm": {**lc["ssm"], "pos": pos}}
            else:
                lc = {**lc, "pos": pos}
            xn, _, nc = layer_body(cfg, _kind, xc, lp, positions, 1, cache=lc)
            # strip pos scalars so the stacked ys stay uniform
            if _kind in ("attn", "swa", "moe", "enc", "ssm"):
                nc = {k: v for k, v in nc.items() if k != "pos"}
            else:
                nc = {"attn": {k: v for k, v in nc["attn"].items() if k != "pos"},
                      "ssm": {k: v for k, v in nc["ssm"].items() if k != "pos"}}
            return xn, nc

        x, new_seg_cache = jax.lax.scan(seg_body, x, (seg_params, seg_cache))
        new_segments.append(new_seg_cache)

    x = _norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, _head(cfg, params), cfg.compute_dtype)[:, 0]
    return (logits[..., :cfg.vocab_size],
            {"pos": pos + 1, "segments": new_segments})
