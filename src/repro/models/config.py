"""Architecture configuration schema.

One dataclass describes every assigned architecture; per-arch modules in
``repro.configs`` instantiate it with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int
    head_dim: int
    d_ff: int                        # dense-MLP hidden (shared-expert size for qwen2-moe)
    vocab_size: int
    #: per-layer kinds, len == n_layers.  Kinds:
    #:   attn  — full causal attention + MLP
    #:   swa   — sliding-window attention + MLP
    #:   enc   — bidirectional attention + MLP (encoder-only)
    #:   moe   — full attention + mixture-of-experts FFN
    #:   ssm   — Mamba2 SSD block (attention-free)
    #:   hyb_g — parallel full-attn + SSM heads, then MLP (Hymba global)
    #:   hyb_l — parallel SWA + SSM heads, then MLP (Hymba local)
    layer_types: tuple[str, ...] = ()
    window: int = 0                  # SWA window
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU) | gelu_nogate
    # -- MoE --
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-routed-expert hidden
    capacity_factor: float = 1.25
    router_renorm: bool = False      # renormalize top-k probs
    moe_dispatch: str = "einsum"     # einsum (GShard) | ragged (dropless sort)
    # -- SSM (Mamba2 SSD) --
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # -- attention details --
    rope_theta: float = 10_000.0
    rope_theta_global: float = 10_000.0
    causal: bool = True
    logit_softcap: float = 0.0
    embed_scale: bool = False        # gemma: embeddings × sqrt(d_model)
    tie_embeddings: bool = True
    qk_norm: bool = False
    input_mode: str = "tokens"       # tokens | embeds | mixed
    n_patches: int = 256             # vlm stub: patch positions at seq start
    norm_eps: float = 1e-6
    # -- runtime --
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots
    use_kernels: bool = False        # Pallas path (TPU); XLA reference otherwise
    seq_shard: bool = False          # sequence-parallel activations between blocks
    loss_chunk: int = 0              # sequence-chunked CE (0 = full logits)
    vocab_pad: int = 0               # pad embed/logit tables to a multiple
                                     # (runtime shardability; pad logits masked)
    attn_q_chunk: int = 0            # stream attention query blocks via
                                     # lax.map (XLA stand-in for flash)

    def __post_init__(self) -> None:
        if self.layer_types and len(self.layer_types) != self.n_layers:
            raise ValueError(
                f"{self.name}: layer_types has {len(self.layer_types)} entries "
                f"for {self.n_layers} layers")

    # -- derived ----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        if not self.vocab_pad:
            return self.vocab_size
        p = self.vocab_pad
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.head_dim

    def segments(self) -> list[tuple[str, int]]:
        """Consecutive same-kind runs → (kind, count) scan segments."""
        segs: list[tuple[str, int]] = []
        for kind in self.layer_types:
            if segs and segs[-1][0] == kind:
                segs[-1] = (kind, segs[-1][1] + 1)
            else:
                segs.append((kind, 1))
        return segs

    def has_decode(self) -> bool:
        """Encoder-only architectures have no autoregressive step."""
        return self.causal

    def subquadratic(self) -> bool:
        """True when the arch has at least one sub-quadratic sequence
        mechanism (SSM state or sliding window) — gates the long_500k
        cell.  Pure full-attention archs are skipped per the assignment."""
        kinds = set(self.layer_types)
        return bool(kinds & {"swa", "ssm", "hyb_l"})

    def param_count(self) -> int:
        """Exact parameter count from the config (embedding included)."""
        d = self.d_model
        n = self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += d * self.vocab_size
        if self.input_mode in ("embeds", "mixed"):
            n += d * d                                # frontend stub proj
        for kind in self.layer_types:
            n += d  # norm1
            if kind == "enc":
                n += d                                     # norm1 bias
            if kind in ("hyb_g", "hyb_l"):
                n += 2 * d                                 # branch norms
            if kind in ("attn", "swa", "enc", "moe", "hyb_g", "hyb_l"):
                n += d * self.n_heads * self.head_dim          # wq
                n += 2 * d * self.n_kv_heads * self.head_dim   # wk, wv
                n += self.n_heads * self.head_dim * d          # wo
                if self.qk_norm:
                    n += 2 * self.head_dim
            if kind in ("ssm", "hyb_g", "hyb_l"):
                di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                conv_ch = di + 2 * self.ssm_groups * N
                n += d * (2 * di + 2 * self.ssm_groups * N + H)  # in_proj
                n += self.ssm_conv * conv_ch + conv_ch           # conv + bias
                n += 3 * H                                       # A_log, D, dt_bias
                n += di                                          # gated norm
                n += di * d                                      # out_proj
            if kind == "moe":
                n += d * self.n_experts                          # router
                n += self.n_experts * 3 * d * self.moe_d_ff      # routed experts
                if self.n_shared_experts:
                    n += 3 * d * self.d_ff + d                   # shared expert (+gate)
                n += d                                           # norm2
            elif kind in ("attn", "swa", "enc", "hyb_g", "hyb_l"):
                if self.d_ff:
                    if self.mlp_act == "gelu_nogate":
                        n += 2 * d * self.d_ff + self.d_ff + d   # wi+wo+biases
                    else:
                        n += 3 * d * self.d_ff
                    n += d                                       # norm2
                    if kind == "enc":
                        n += d                                   # norm2 bias
        n += d                                                   # final norm
        if self.layer_types and self.layer_types[0] == "enc":
            n += d                                               # final bias
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        inactive = 0
        n_moe_layers = sum(1 for k in self.layer_types if k == "moe")
        inactive += n_moe_layers * (self.n_experts - self.top_k) * 3 * d * self.moe_d_ff
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) cell runs; returns (ok, reason-if-not)."""
    if shape.kind == "decode" and not cfg.has_decode():
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.subquadratic():
        return False, "pure full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""
