"""Deterministic synthetic data pipeline with host sharding.

Real deployments plug a tokenized corpus in here; the framework's
contract is only the iterator protocol + deterministic resume.  Each
host materializes exactly its shard of the global batch
(``process_index``-sliced), and the stream is reproducible from
(seed, step) alone — which is what makes checkpoint/restart of a study
deterministic (the journal stores the step, not the data).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import jax
import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass
class SyntheticStream:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    start_step: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self) -> None:
        if self.global_batch % self.n_hosts:
            raise ValueError("global batch must divide across hosts")
        self.local_batch = self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """The host-local batch for a given global step (stateless)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        b, s, cfg = self.local_batch, self.seq_len, self.cfg
        if cfg.input_mode == "tokens":
            toks = rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32)
            return {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}
        if cfg.input_mode == "embeds":
            emb = rng.standard_normal((b, s, cfg.d_model),
                                      dtype=np.float32) * 0.1
            labels = rng.integers(0, cfg.vocab_size, (b, s), dtype=np.int32)
            return {"embeds": emb, "labels": labels}
        npatch = min(cfg.n_patches, s // 2)
        st = s - npatch
        toks = rng.integers(0, cfg.vocab_size, (b, st), dtype=np.int32)
        patches = rng.standard_normal((b, npatch, cfg.d_model),
                                      dtype=np.float32) * 0.1
        labels = np.concatenate(
            [np.full((b, npatch), -100, np.int32),
             rng.integers(0, cfg.vocab_size, (b, st), dtype=np.int32)],
            axis=1)
        return {"tokens": toks, "patch_embeds": patches, "labels": labels}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = self.start_step
        while True:
            yield self.batch_at(step)
            step += 1


def make_stream(cfg: ArchConfig, global_batch: int, seq_len: int,
                seed: int = 0, start_step: int = 0) -> SyntheticStream:
    return SyntheticStream(
        cfg=cfg, global_batch=global_batch, seq_len=seq_len, seed=seed,
        start_step=start_step,
        n_hosts=jax.process_count(), host_id=jax.process_index())
