"""repro.data"""
