"""Gemma-7B [arXiv:2403.08295] — GeGLU, head_dim=256, 16 heads/16 kv."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    layer_types=("attn",) * 28,
    mlp_act="gelu", embed_scale=True, tie_embeddings=True,
    rope_theta=10_000.0, rope_theta_global=10_000.0,
)

SMOKE = ArchConfig(
    name="gemma-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=256,
    layer_types=("attn",) * 2,
    mlp_act="gelu", embed_scale=True, tie_embeddings=True,
)
