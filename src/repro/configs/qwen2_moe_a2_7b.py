"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts
top-4 (d_ff 1408 each) + 4 shared experts fused as one 5632-wide shared
expert with a sigmoid token gate."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=5632,                      # fused shared-expert width (4 x 1408)
    vocab_size=151936,
    layer_types=("moe",) * 24,
    n_experts=60, n_shared_experts=4, top_k=4, moe_d_ff=1408,
    router_renorm=False, mlp_act="silu", tie_embeddings=False,
    rope_theta=1_000_000.0, rope_theta_global=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=96, vocab_size=256,
    layer_types=("moe",) * 2,
    n_experts=8, n_shared_experts=1, top_k=2, moe_d_ff=32,
    router_renorm=False, mlp_act="silu", tie_embeddings=False,
)
