"""DeepSeek-7B [arXiv:2401.02954] — llama-arch, MHA (kv=32)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400,
    layer_types=("attn",) * 30,
    mlp_act="silu", tie_embeddings=False,
    rope_theta=10_000.0, rope_theta_global=10_000.0,
)

SMOKE = ArchConfig(
    name="deepseek-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    layer_types=("attn",) * 2,
    mlp_act="silu", tie_embeddings=False,
)
