"""Assigned architecture registry: ``get(arch_id)`` / ``get_smoke(arch_id)``.

Each module defines ``CONFIG`` (exact published numbers) and ``SMOKE``
(same family, reduced dimensions — runs a CPU train step in tests).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "internvl2_26b",
    "gemma_7b",
    "h2o_danube_1_8b",
    "deepseek_7b",
    "gemma3_1b",
    "hubert_xlarge",
    "qwen2_moe_a2_7b",
    "olmoe_1b_7b",
    "mamba2_780m",
    "hymba_1_5b",
]

#: CLI ids (dashes) → module names
ALIASES = {
    "internvl2-26b": "internvl2_26b",
    "gemma-7b": "gemma_7b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "deepseek-7b": "deepseek_7b",
    "gemma3-1b": "gemma3_1b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mamba2-780m": "mamba2_780m",
    "hymba-1.5b": "hymba_1_5b",
}


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    if name not in ARCH_IDS:
        raise KeyError(f"unknown architecture {arch_id!r}; known: "
                       f"{sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get(arch_id: str, **overrides):
    import dataclasses
    cfg = _module(arch_id).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke(arch_id: str, **overrides):
    import dataclasses
    cfg = _module(arch_id).SMOKE
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def all_archs() -> list[str]:
    return list(ALIASES)
