"""Hymba-1.5B [arXiv:2411.13676] — hybrid heads: parallel attention +
mamba(SSD) branches in every layer; full attention on layers {0, mid,
last}, SWA elsewhere; 25 query heads (head_dim 64), kv=5, ssm_state=16."""
from repro.models.config import ArchConfig

_TYPES = tuple(
    "hyb_g" if i in (0, 15, 31) else "hyb_l" for i in range(32)
)

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    layer_types=_TYPES, window=1024,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    ssm_conv=4, ssm_chunk=256,
    mlp_act="silu", tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    layer_types=("hyb_g", "hyb_l", "hyb_g"), window=16,
    ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_groups=1,
    ssm_conv=4, ssm_chunk=16,
    mlp_act="silu", tie_embeddings=True,
)
