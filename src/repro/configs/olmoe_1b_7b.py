"""OLMoE-1B-7B [arXiv:2409.02060] — 64 routed experts top-8, qk-norm,
no shared experts."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304,
    layer_types=("moe",) * 16,
    n_experts=64, n_shared_experts=0, top_k=8, moe_d_ff=1024,
    router_renorm=False, mlp_act="silu", qk_norm=True, tie_embeddings=False,
    rope_theta=10_000.0, rope_theta_global=10_000.0,
)

SMOKE = ArchConfig(
    name="olmoe-1b-7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=256,
    layer_types=("moe",) * 2,
    n_experts=8, n_shared_experts=0, top_k=2, moe_d_ff=32,
    router_renorm=False, mlp_act="silu", qk_norm=True, tie_embeddings=False,
)
