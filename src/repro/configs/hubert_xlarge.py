"""HuBERT-XLarge [arXiv:2106.07447] — encoder-only (bidirectional),
LayerNorm + non-gated GELU FFN, 504-class target vocabulary.  The audio
frontend (conv feature extractor) is a stub: input_specs provides
precomputed frame embeddings."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504,
    layer_types=("enc",) * 48,
    mlp_act="gelu_nogate", causal=False, tie_embeddings=False,
    input_mode="embeds",
)

SMOKE = ArchConfig(
    name="hubert-xlarge-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64,
    layer_types=("enc",) * 2,
    mlp_act="gelu_nogate", causal=False, tie_embeddings=False,
    input_mode="embeds",
)
