"""H2O-Danube-1.8B [arXiv:2401.16818] — llama+mistral mix, sliding-window
attention (4096 window) on all layers."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000,
    layer_types=("swa",) * 24, window=4096,
    mlp_act="silu", tie_embeddings=False,
    rope_theta=10_000.0, rope_theta_global=10_000.0,
)

SMOKE = ArchConfig(
    name="h2o-danube-1.8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    layer_types=("swa",) * 2, window=16,
    mlp_act="silu", tie_embeddings=False,
)
