"""Gemma3-1B [hf:google/gemma-3-1b-pt] — 5:1 local(512):global layer
pattern, MQA (kv=1), head_dim=256, 262k vocab, qk-norm, dual rope theta
(local 10k / global 1M)."""
from repro.models.config import ArchConfig

# 26 layers: (5 local + 1 global) x 4 + 2 local
_PATTERN = (("swa",) * 5 + ("attn",)) * 4 + ("swa",) * 2

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    layer_types=_PATTERN, window=512,
    mlp_act="gelu", embed_scale=True, tie_embeddings=True, qk_norm=True,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
)

SMOKE = ArchConfig(
    name="gemma3-1b-smoke", family="dense",
    n_layers=6, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=256,
    layer_types=("swa",) * 5 + ("attn",), window=16,
    mlp_act="gelu", embed_scale=True, tie_embeddings=True, qk_norm=True,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
)
