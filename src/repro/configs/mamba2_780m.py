"""Mamba2-780M [arXiv:2405.21060] — attention-free SSD (state-space
duality), d_state=128, head_dim=64, expand=2."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    layer_types=("ssm",) * 48,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2-780m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=256,
    layer_types=("ssm",) * 2,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_groups=1,
    ssm_conv=4, ssm_chunk=16,
    tie_embeddings=True,
)
