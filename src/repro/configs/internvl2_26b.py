"""InternVL2-26B — InternViT-6B + InternLM2-20B backbone [arXiv:2404.16821].

The transformer BACKBONE only (48L, d=6144, 48H GQA kv=8, ff=16384,
vocab=92553); the vision frontend is a stub providing precomputed patch
embeddings (input_mode="mixed")."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    layer_types=("attn",) * 48,
    mlp_act="silu", rope_theta=1_000_000.0, rope_theta_global=1_000_000.0,
    tie_embeddings=False, input_mode="mixed", n_patches=256,
)

SMOKE = ArchConfig(
    name="internvl2-26b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
    layer_types=("attn",) * 2,
    mlp_act="silu", tie_embeddings=False, input_mode="mixed", n_patches=4,
)
