"""repro.distributed"""
