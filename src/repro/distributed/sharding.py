"""Per-architecture sharding rules (DP/TP/EP + ZeRO-1 + pod axis).

Layout summary (baseline):
* batch dims           → ("pod", "data")          [DP across pods too]
* TP over "model": attention heads (wq/wk/wv out-dim, wo in-dim), MLP
  hidden, MoE expert hidden (TP *within* every expert — no all_to_all),
  SSD d_inner, vocab (embedding rows + logits).
* stacked-segment leading axes are never sharded.
* ZeRO-1: optimizer moments additionally shard their largest free axis
  over "data" (param size threshold 1 MiB) — the memory saver that fits
  26B fp32 Adam state on 16 GB chips.

Everything returns jax.sharding.NamedSharding against the given mesh so
jit in/out_shardings can consume it directly.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

ZERO1_MIN_BYTES = 1 << 20


def _path_names(path: tuple) -> list[str]:
    names = []
    for e in path:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
    return names


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def param_spec(path: tuple, leaf: Any) -> P:
    """PartitionSpec for one parameter leaf, by name + rank."""
    names = _path_names(path)
    name = names[-1]
    parents = set(names[:-1])
    ndim = leaf.ndim
    lead = lambda base: (None,) * (ndim - base)  # noqa: E731 segment axes

    if name == "embed":
        return P("model", None)
    if name == "lm_head":
        return P(None, "model")
    if name == "frontend_proj":
        return P(None, None)
    if name in ("wq", "wk", "wv", "in_proj"):
        return P(*lead(2), None, "model")
    if name in ("wi", "wi_gate", "wi_up"):
        if "moe" in parents and "shared" not in parents:
            return P(*lead(3), None, None, "model")   # (E, d, ffm)
        return P(*lead(2), None, "model")
    if name == "wo":
        if "moe" in parents and "shared" not in parents:
            return P(*lead(3), None, "model", None)   # (E, ffm, d)
        return P(*lead(2), "model", None)
    if name == "out_proj":
        return P(*lead(2), "model", None)
    if name == "conv_w":
        return P(*lead(2), None, "model")
    if name in ("conv_b", "bi"):
        return P(*lead(1), "model")
    if name == "norm" and "ssm" in parents:           # (d_inner,) gated norm
        return P(*lead(1), "model")
    if name == "router":
        return P(*lead(2), None, None)
    if name == "gate":
        return P(*lead(2), None, None)
    # norms, biases, A_log, D, dt_bias, q_norm/k_norm, scalars
    return P(*([None] * ndim))


def zero1_spec(spec: P, leaf: Any, mesh: Mesh) -> P:
    """Add "data" sharding on the largest unsharded axis (ZeRO-1)."""
    if leaf.size * 4 < ZERO1_MIN_BYTES:
        return spec
    dp = _dp_axes(mesh)
    if not dp:
        return spec
    entries = list(spec) + [None] * (leaf.ndim - len(spec))
    # pick the largest axis currently unsharded (skip tiny axes)
    best, best_size = -1, 0
    for i, (e, size) in enumerate(zip(entries, leaf.shape)):
        if e is None and size > best_size and size >= np.prod(
                [mesh.shape[a] for a in dp]):
            best, best_size = i, size
    if best < 0:
        return spec
    entries[best] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def _dp_entry(mesh: Mesh):
    """PartitionSpec entry for the batch dim: ("pod","data") or "data"."""
    dp = _dp_axes(mesh)
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def batch_spec(mesh: Mesh) -> P:
    return P(_dp_entry(mesh))


def _axes_size(mesh: Mesh, entry: Any) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on dims the global shape can't divide (jit input
    shardings require exact divisibility); odd-vocab embeddings fall back
    to sharding d_model instead."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is not None and dim % _axes_size(mesh, entry) != 0:
            entry = None
        out.append(entry)
    # fallback: 2-D (V, d) with dropped dim-0 sharding → shard dim 1
    if (len(shape) == 2 and out[0] is None and out[1] is None
            and spec and spec[0] == "model"
            and shape[1] % _axes_size(mesh, "model") == 0):
        out[1] = "model"
    return P(*out)


def _named(mesh: Mesh, spec: P, shape: tuple[int, ...] | None = None
           ) -> NamedSharding:
    if shape is not None:
        spec = fit_spec(spec, shape, mesh)
    return NamedSharding(mesh, spec)


def params_shardings(abstract_params: Any, mesh: Mesh) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = [_named(mesh, param_spec(path, leaf), leaf.shape)
             for path, leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_shardings(abstract_opt: Any, mesh: Mesh, zero1: bool = True) -> Any:
    """Moments follow the params (+ZeRO-1); count replicated."""
    def one(path, leaf):
        names = _path_names(path)
        if names and names[0] == "count":
            return _named(mesh, P())
        # strip the leading "m"/"v" for rule lookup
        spec = fit_spec(param_spec(tuple(path[1:]), leaf), leaf.shape, mesh)
        if zero1:
            spec = zero1_spec(spec, leaf, mesh)
        return _named(mesh, spec, leaf.shape)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_opt)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in leaves])


def state_shardings(abstract_state: Any, mesh: Mesh, zero1: bool = True
                    ) -> dict[str, Any]:
    return {
        "params": params_shardings(abstract_state["params"], mesh),
        "opt": opt_shardings(abstract_state["opt"], mesh, zero1),
        "step": _named(mesh, P()),
    }


def batch_shardings(abstract_batch: Any, mesh: Mesh) -> Any:
    dp = _dp_entry(mesh)

    def one(leaf):
        extra = (None,) * (leaf.ndim - 1)
        return _named(mesh, P(dp, *extra), leaf.shape)
    return jax.tree.map(one, abstract_batch)


def cache_shardings(abstract_cache: Any, mesh: Mesh) -> Any:
    """Decode caches: batch over DP; kv-heads (or head_dim when kv-heads
    don't divide) over model; SSM state heads over model."""
    msize = mesh.shape.get("model", 1)
    dp = _dp_entry(mesh)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name == "pos":
            return _named(mesh, P())
        nd = leaf.ndim
        if name in ("k", "v"):
            # (R, B, T, Hkv, D) or (B, T, Hkv, D)
            lead = (None,) * (nd - 4)
            hkv = leaf.shape[-2]
            if hkv % msize == 0:
                return _named(mesh, P(*lead, dp, None, "model", None),
                              leaf.shape)
            return _named(mesh, P(*lead, dp, None, None, "model"),
                          leaf.shape)
        if name == "ssm":
            # (R, B, H, P, N) or (B, H, P, N)
            lead = (None,) * (nd - 4)
            return _named(mesh, P(*lead, dp, "model", None, None),
                          leaf.shape)
        if name == "conv":
            # (R, B, K-1, C) or (B, K-1, C)
            lead = (None,) * (nd - 3)
            return _named(mesh, P(*lead, dp, None, "model"), leaf.shape)
        return _named(mesh, P(*([None] * nd)))

    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in leaves])
