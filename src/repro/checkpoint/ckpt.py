"""Sharding-aware model/optimizer checkpointing with elastic restore.

Design (fault tolerance at 1000+ nodes):
* atomic step directories (write to ``step_N.tmp`` → rename) — a crash
  mid-save never corrupts the latest checkpoint;
* leaves stored as .npy files keyed by pytree path + a JSON manifest;
* ``restore`` takes the TARGET sharding tree: arrays are placed directly
  onto the current mesh, so a run checkpointed on one topology restarts
  on a different one (elastic re-shard) — the model-state counterpart of
  the PaPaS study journal;
* ``keep`` bounds retained checkpoints (oldest pruned after a
  successful save).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from pathlib import Path
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(entry: Any) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return f"[{entry.idx}]"
    return str(entry)


def save(state: Any, directory: str | Path, step: int, keep: int = 3) -> Path:
    """Atomically persist a pytree under ``directory/step_<N>/``."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(state)
    manifest: dict[str, Any] = {"step": step, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # prune old checkpoints
    steps = sorted(all_steps(directory))
    for old in steps[:-keep] if keep else []:
        shutil.rmtree(directory / f"step_{old:08d}", ignore_errors=True)
    return final


def all_steps(directory: str | Path) -> list[int]:
    directory = Path(directory)
    out = []
    if not directory.exists():
        return out
    for p in directory.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str | Path) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(target: Any, directory: str | Path, step: int | None = None,
            shardings: Any = None) -> Any:
    """Load into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) device_puts each
    leaf onto the current mesh — elastic restore."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    cdir = directory / f"step_{step:08d}"
    manifest = json.loads((cdir / "manifest.json").read_text())

    flat_target = _flatten(target)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    missing = set(flat_target) - set(manifest["leaves"])
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")

    loaded: dict[str, Any] = {}
    for key, meta in manifest["leaves"].items():
        if key not in flat_target:
            continue
        arr = np.load(cdir / meta["file"])
        want = flat_target[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target {want.shape}")
        if key in flat_shard and flat_shard[key] is not None:
            loaded[key] = jax.device_put(arr, flat_shard[key])
        else:
            loaded[key] = jax.device_put(arr.astype(want.dtype))
    # rebuild the tree in target order
    treedef = jax.tree_util.tree_structure(target)
    keys = [SEP.join(_path_str(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(target)[0]]
    return jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in keys])
