"""repro.checkpoint"""
