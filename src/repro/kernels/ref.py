"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Dense masked attention oracle. q (B,S,Hq,D); k,v (B,S,Hkv,D)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, s, hkv, hq // hkv, d).astype(jnp.float32) * d ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), jnp.bool_)
    if causal:
        mask = i >= j
    if window > 0:
        mask = mask & (i - j < window)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, hq, d).astype(q.dtype)


def ssd_scan_ref(x, log_a, b_mat, c_mat, initial_state=None):
    """Exact sequential SSD recurrence (lax.scan over time)."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(c_mat, rep, axis=2).astype(jnp.float32)
    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((bsz, h, p, n), jnp.float32))

    def step(state, inp):
        x_t, la_t, b_t, c_t = inp
        a = jnp.exp(la_t.astype(jnp.float32))[..., None, None]
        state = state * a + jnp.einsum(
            "bhp,bhn->bhpn", x_t.astype(jnp.float32), b_t)
        y = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y

    xs = (x.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2),
          bh.transpose(1, 0, 2, 3), ch.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final.astype(x.dtype)


def grouped_matmul_ref(x, w, group_sizes):
    """Per-row expert matmul oracle. x (T,d); w (E,d,f)."""
    t = x.shape[0]
    bounds = jnp.cumsum(group_sizes)
    expert_of = jnp.searchsorted(bounds, jnp.arange(t), side="right")
    w_rows = jnp.take(w, expert_of, axis=0)              # (T, d, f)
    return jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                      w_rows.astype(jnp.float32)).astype(x.dtype)
