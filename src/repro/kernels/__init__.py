"""Pallas TPU kernels for perf-critical hot spots (+ jnp oracles).

flash_attention — blockwise GQA attention (causal / SWA / bidirectional)
ssd_scan        — Mamba2 SSD chunked scan
grouped_matmul  — megablox-style ragged expert GEMM
"""
from . import ops, ref
from .flash_attention import flash_attention as flash_attention_kernel
from .moe_gmm import grouped_matmul as grouped_matmul_kernel
from .ssd_scan import ssd_scan as ssd_scan_kernel

__all__ = ["ops", "ref", "flash_attention_kernel", "grouped_matmul_kernel",
           "ssd_scan_kernel"]
