"""Pallas TPU grouped (ragged) expert matmul — megablox-style.

``grouped_matmul(x, w, group_sizes)`` computes, for tokens sorted by
expert id, ``y[t] = x[t] @ w[expert_of(t)]`` without densifying the
expert dimension.

TPU adaptation: rows are re-packed so every expert's segment occupies
whole (BT)-row blocks (static worst-case padding of E·BT rows keeps the
shape jittable).  A per-block expert-id array is passed through
*scalar prefetch* (``pltpu.PrefetchScalarGridSpec``) so the weight
BlockSpec's index map can select the right expert slab — the TPU
equivalent of megablocks' block-sparse GEMM descriptor.  Each program
runs one (BT×d)·(d×BF) MXU matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack(x: jax.Array, group_sizes: jax.Array, block_rows: int
          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pack sorted rows so each group starts on a block boundary.

    Returns (x_packed (Tp, d), block_expert (Tp/BT,), row_map (T,))
    where row_map gives each original row's position in the packed
    buffer.  Tp = T + E·BT is static worst case.
    """
    t, d = x.shape
    e = group_sizes.shape[0]
    tp = t + e * block_rows

    padded = ((group_sizes + block_rows - 1) // block_rows) * block_rows
    pad_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    raw_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])

    rows = jnp.arange(t, dtype=jnp.int32)
    expert_of = jnp.searchsorted(jnp.cumsum(group_sizes), rows, side="right"
                                 ).astype(jnp.int32)
    row_map = pad_off[expert_of] + (rows - raw_off[expert_of])

    x_packed = jnp.zeros((tp, d), x.dtype).at[row_map].set(x)
    nblocks = tp // block_rows
    block_start = jnp.arange(nblocks, dtype=jnp.int32) * block_rows
    block_expert = jnp.searchsorted(
        jnp.cumsum(padded), block_start, side="right").astype(jnp.int32)
    block_expert = jnp.minimum(block_expert, e - 1)
    return x_packed, block_expert, row_map


def _gmm_kernel(block_expert_ref, x_ref, w_ref, o_ref):
    del block_expert_ref  # consumed by the index maps
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def grouped_matmul(
    x: jax.Array,             # (T, d) rows sorted by expert
    w: jax.Array,             # (E, d, f)
    group_sizes: jax.Array,   # (E,) int32, sums to T
    *,
    block_rows: int = 128,
    block_cols: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Ragged grouped matmul → (T, f)."""
    t, d = x.shape
    e, _, f = w.shape
    assert f % block_cols == 0, (f, block_cols)
    x_packed, block_expert, row_map = _pack(x, group_sizes, block_rows)
    nblocks = x_packed.shape[0] // block_rows

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks, f // block_cols),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, d, block_cols), lambda i, j, be: (be[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols),
                               lambda i, j, be: (i, j)),
    )
    out_packed = pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((x_packed.shape[0], f), x.dtype),
        interpret=interpret,
    )(block_expert, x_packed, w)
    return jnp.take(out_packed, row_map, axis=0)
