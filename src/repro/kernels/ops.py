"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this CPU container) the kernels execute in
``interpret=True`` mode — the kernel body runs as plain JAX ops, which
validates correctness; TPU compiles the real Mosaic kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import moe_gmm as _gmm
from . import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """Flash attention with automatic padding to block multiples."""
    b, s, hq, d = q.shape
    bq = min(block_q, max(16, s))
    bk = min(block_k, max(16, s))
    pad = (-s) % max(bq, bk)
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    out = _fa.flash_attention(
        qp, kp, vp, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=_interpret_default(),
        valid_len=s)
    return out[:, :s] if pad else out


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, log_a, b_mat, c_mat, *, chunk: int = 256,
             initial_state=None):
    return _ssd.ssd_scan(x, log_a, b_mat, c_mat, chunk=chunk,
                         initial_state=initial_state,
                         interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def grouped_matmul(x, w, group_sizes, *, block_rows: int = 128,
                   block_cols: int = 128):
    f = w.shape[-1]
    bc = min(block_cols, f)
    while f % bc:
        bc -= 1
    return _gmm.grouped_matmul(x, w, group_sizes,
                               block_rows=block_rows, block_cols=bc,
                               interpret=_interpret_default())
