"""Pallas TPU flash attention (GQA, causal, sliding-window).

TPU-native design notes (hardware adaptation, see DESIGN.md):
* grid = (B·Hq, S/BQ, S/BK); the KV dimension is the innermost grid axis
  so the online-softmax running state (m, l, acc) lives in VMEM scratch
  across KV iterations (TPU grids execute sequentially per core — the
  idiomatic TPU analogue of a CUDA persistent-CTA loop).
* BlockSpecs tile Q/K/V into (BQ, D)/(BK, D) VMEM blocks; D ≤ 256 keeps
  the MXU matmuls (BQ×D)·(D×BK) and (BQ×BK)·(BK×D) hardware-aligned
  (block sizes are multiples of 128).
* GQA is resolved in the index maps: query head h reads KV head
  h // (Hq/Hkv) — no KV replication in HBM.
* Causal/sliding-window masking is applied in-kernel per (BQ, BK) tile;
  fully-masked tiles short-circuit via ``pl.when`` (no MXU work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 *, scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, n_kv_blocks: int,
                 valid_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tile-level reachability: skip tiles that are fully masked
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    if causal:
        reachable = k_start <= q_start + block_q - 1
    else:
        reachable = True
    if window > 0:
        # need k_pos >= q_pos - window + 1 for some pair in tile
        reachable = jnp.logical_and(
            reachable, k_start + block_k - 1 >= q_start - window + 1) \
            if causal else reachable

    @pl.when(reachable if isinstance(reachable, jax.Array) else True)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, D)
        k = k_ref[0].astype(jnp.float32)                  # (BK, D)
        v = v_ref[0].astype(jnp.float32)                  # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (BQ, BK)
        mask = k_pos < valid_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (BQ,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (B, S, Hq, D)
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    valid_len: int | None = None,
) -> jax.Array:
    """Blockwise attention; exact (online softmax).  S must be divisible
    by the block sizes (the ops wrapper pads)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)

    # (B, S, H, D) → (B·H, S, D)
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)

    n_q = s // block_q
    n_k = s // block_k

    def kv_index(bh, qi, ki):
        bb = bh // hq
        hh = bh % hq
        return (bb * hkv + hh // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=d ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_k,
        valid_len=valid_len if valid_len is not None else s)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
