"""Pallas TPU kernels for the Mamba2 SSD chunked scan.

Decomposition (mirrors the reference ``ssd_chunked``):

1. ``_intra_kernel`` — grid (B, H, C): per chunk computes the
   intra-chunk output Y_intra (decay-masked C·Bᵀ "attention" — two MXU
   matmuls of (Q,N)·(N,Q) and (Q,Q)·(Q,P)) and the end-of-chunk state
   contribution (P,N).
2. host: tiny ``jax.lax.associative_scan`` across the C chunk states
   (O(C·H·P·N) — negligible).
3. ``_inter_kernel`` — grid (B, H, C): adds the inter-chunk term
   C·state_prev scaled by the within-chunk decay (one (Q,N)·(N,P) MXU
   matmul per chunk).

VMEM per program: Q·N + Q·P + Q·Q + P·N fp32 ≈ 0.9 MB for
(Q,P,N)=(256,64,128) — comfortably under the ~16 MB/core budget, and
every matmul dimension is a multiple of 64/128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _intra_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, st_ref, seg_ref,
                  *, chunk: int):
    x = x_ref[0, 0, 0].astype(jnp.float32)      # (Q, P)
    la = la_ref[0, 0, 0].astype(jnp.float32)    # (Q,)
    bm = b_ref[0, 0, 0].astype(jnp.float32)     # (Q, N)
    cm = c_ref[0, 0, 0].astype(jnp.float32)     # (Q, N)

    cum = jnp.cumsum(la)                     # (Q,) inclusive
    total = cum[-1]

    # intra-chunk decay-masked scores
    li = cum[:, None]
    lj = cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    delta = jnp.where(mask, li - lj, 0.0)   # mask BEFORE exp (overflow)
    decay = jnp.where(mask, jnp.exp(delta), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * decay        # (Q, Q)
    y_ref[0, 0, 0] = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # chunk state: Σ_j exp(total - cum_j) x_j ⊗ B_j   → (P, N)
    w = jnp.exp(total - cum)                               # (Q,)
    xw = x * w[:, None]                                    # (Q, P)
    st_ref[0, 0, 0] = jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(st_ref.dtype)
    seg_ref[0, 0, 0] = jnp.exp(total)[None]


def _inter_kernel(c_ref, prev_ref, la_ref, yin_ref, y_ref):
    cm = c_ref[0, 0, 0].astype(jnp.float32)      # (Q, N)
    prev = prev_ref[0, 0, 0].astype(jnp.float32) # (P, N)
    la = la_ref[0, 0, 0].astype(jnp.float32)     # (Q,)
    dec = jnp.exp(jnp.cumsum(la))[:, None]    # decay from chunk start
    y_inter = jax.lax.dot_general(
        cm, prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * dec          # (Q, P)
    y_ref[0, 0, 0] = (yin_ref[0, 0, 0].astype(jnp.float32) + y_inter
                   ).astype(y_ref.dtype)


def ssd_scan(
    x: jax.Array,      # (B, S, H, P) — dt-scaled inputs
    log_a: jax.Array,  # (B, S, H)
    b_mat: jax.Array,  # (B, S, G, N)
    c_mat: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Full SSD scan via two Pallas kernels + a host associative scan.

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    c = s // q
    rep = h // g

    # layout: (B, H, C, Q, ·) so the grid walks contiguous VMEM blocks
    xr = x.transpose(0, 2, 1, 3).reshape(bsz, h, c, q, p)
    lar = log_a.transpose(0, 2, 1).reshape(bsz, h, c, q)
    bh = jnp.repeat(b_mat, rep, axis=2)
    ch = jnp.repeat(c_mat, rep, axis=2)
    bhr = bh.transpose(0, 2, 1, 3).reshape(bsz, h, c, q, n)
    chr_ = ch.transpose(0, 2, 1, 3).reshape(bsz, h, c, q, n)

    grid = (bsz, h, c)
    bspec = lambda *blk: pl.BlockSpec(  # noqa: E731
        (1, 1, 1) + blk, lambda bb, hh, cc: (bb, hh, cc) + (0,) * len(blk))

    y_intra, states, seg = pl.pallas_call(
        functools.partial(_intra_kernel, chunk=q),
        grid=grid,
        in_specs=[bspec(q, p), bspec(q), bspec(q, n), bspec(q, n)],
        out_specs=[bspec(q, p), bspec(p, n), bspec(1)],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, c, q, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, c, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, c, 1), jnp.float32),
        ],
        interpret=interpret,
    )(xr, lar, bhr, chr_)
    seg = seg[..., 0]                                      # (B,H,C)

    # ---- host: inter-chunk associative scan (tiny) --------------------
    def combine(left, right):
        a_l, s_l = left
        a_r, s_r = right
        return a_l * a_r, s_l * a_r[..., None, None] + s_r

    a_scan, s_scan = jax.lax.associative_scan(combine, (seg, states), axis=2)
    if initial_state is not None:
        init = initial_state.astype(jnp.float32)[:, :, None]
        prev = jnp.concatenate(
            [init, s_scan[:, :, :-1]
             + init * a_scan[:, :, :-1, None, None]], axis=2)
        final = s_scan[:, :, -1] + init[:, :, 0] * a_scan[:, :, -1, None, None]
    else:
        prev = jnp.concatenate(
            [jnp.zeros_like(s_scan[:, :, :1]), s_scan[:, :, :-1]], axis=2)
        final = s_scan[:, :, -1]

    y = pl.pallas_call(
        _inter_kernel,
        grid=grid,
        in_specs=[bspec(q, n), bspec(p, n), bspec(q), bspec(q, p)],
        out_specs=bspec(q, p),
        out_shape=jax.ShapeDtypeStruct((bsz, h, c, q, p), x.dtype),
        interpret=interpret,
    )(chr_, prev, lar, y_intra)

    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)      # (B,S,H,P)
    return y, final.astype(x.dtype)
