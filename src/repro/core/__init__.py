"""PaPaS core: parameter-study, workflow, cluster, visualization engines."""
from .chaos import (
    ChaosController,
    FaultEvent,
    FaultLedger,
    FaultPlan,
    record_fingerprint,
    truncate_tail,
)
from .dag import DAGError, TaskDAG, TaskNode
from .executors import (
    CompletionEvent,
    GangExecutor,
    GangPool,
    GangStats,
    InlinePool,
    LaneStats,
    LaneWorkerPool,
    ProcessWorkerPool,
    ShellResult,
    ThreadWorkerPool,
    WorkerPool,
    make_pool,
    merged_env,
    run_subprocess,
    stackable_key,
)
from .interpolate import (
    CompiledEnviron,
    CompiledTemplate,
    InterpolationError,
    classify_reference,
    compile_environ,
    compile_template,
    interpolate,
    render_command,
    render_environ,
    substitute_content,
)
from .lint import Finding, LintReport, Rule, RULES, lint
from .locklint import (
    InstrumentedLock,
    LockOrderAuditor,
    LockOrderError,
    get_auditor,
    make_lock,
)
from .paramspace import ParameterSpace, combo_id, from_task
from .provenance import StudyDB, config_hash
from .results import (
    BUILTIN_CAPTURES,
    CaptureError,
    CaptureSet,
    CaptureSpec,
    KeyResolutionError,
    MetricStats,
    ResultsAggregator,
    build_capture_sets,
    infer_scalar,
    parse_capture,
    parse_captures,
    resolve_key,
)
from .remote import (
    AllHostsQuarantinedError,
    BatchWorkerPool,
    LocalSubmitter,
    LocalTransport,
    SchedulerSubmitter,
    SSHTransport,
    SSHWorkerPool,
    Transport,
    TransportError,
    parse_hosts,
    render_batch_script,
)
from .scheduler import (
    RetryPolicy,
    ScheduleEvent,
    Scheduler,
    TaskResult,
    VirtualClock,
    VirtualPool,
    classify_failure,
    dispatch_count,
    makespan,
)
from .staging import collect_outputs, stage_instance
from .state import JournalState, StudyJournal, compress_ranges, expand_ranges
from .study import InstanceWindow, ParameterStudy, load_study
from .telemetry import MetricsRegistry, Telemetry, TraceCollector
from .viz import to_ascii, to_dot
from .wdl import (
    RESERVED_KEYWORDS,
    StudySpec,
    TaskSpec,
    WDLError,
    merge,
    parse_dict,
    parse_file,
    parse_ini,
    parse_json,
    parse_range,
    parse_yaml,
)

__all__ = [
    "ChaosController", "FaultEvent", "FaultLedger", "FaultPlan",
    "record_fingerprint", "truncate_tail",
    "DAGError", "TaskDAG", "TaskNode",
    "CompletionEvent", "GangExecutor", "GangPool", "GangStats", "InlinePool",
    "LaneStats", "LaneWorkerPool", "ProcessWorkerPool", "ShellResult",
    "ThreadWorkerPool", "WorkerPool", "make_pool", "merged_env",
    "run_subprocess", "stackable_key",
    "AllHostsQuarantinedError", "BatchWorkerPool", "LocalSubmitter",
    "LocalTransport", "SchedulerSubmitter", "SSHTransport", "SSHWorkerPool",
    "Transport", "TransportError", "parse_hosts", "render_batch_script",
    "CompiledEnviron", "CompiledTemplate", "InterpolationError",
    "classify_reference",
    "compile_environ", "compile_template", "interpolate", "render_command",
    "render_environ", "substitute_content",
    "Finding", "LintReport", "Rule", "RULES", "lint",
    "InstrumentedLock", "LockOrderAuditor", "LockOrderError",
    "get_auditor", "make_lock",
    "ParameterSpace", "combo_id", "from_task",
    "StudyDB", "config_hash",
    "BUILTIN_CAPTURES", "CaptureError", "CaptureSet", "CaptureSpec",
    "KeyResolutionError", "MetricStats", "ResultsAggregator",
    "build_capture_sets", "infer_scalar", "parse_capture", "parse_captures",
    "resolve_key",
    "RetryPolicy", "ScheduleEvent", "Scheduler", "TaskResult",
    "VirtualClock", "VirtualPool", "classify_failure", "dispatch_count",
    "makespan",
    "JournalState", "StudyJournal", "compress_ranges", "expand_ranges",
    "collect_outputs", "stage_instance",
    "InstanceWindow", "ParameterStudy", "load_study",
    "MetricsRegistry", "Telemetry", "TraceCollector",
    "to_ascii", "to_dot",
    "RESERVED_KEYWORDS", "StudySpec", "TaskSpec", "WDLError", "merge",
    "parse_dict", "parse_file", "parse_ini", "parse_json", "parse_range",
    "parse_yaml",
]
