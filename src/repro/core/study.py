"""The Parameter Study engine (paper §4.1) — the top of the stack.

A study is parsed from WDL (or built via the Python API), expanded into
workflow instances (one per unique parameter combination, §5.1), compiled
into a task DAG (tasks × instances), and executed through a chosen
backend with provenance + checkpoint/restart.

Semantics: the global parameter space is the product of every task's
parameter space (parameters are task-namespaced as ``task/param``); a
*workflow instance* is one combination applied across the whole task DAG,
exactly the paper's "a workflow corresponds to an instance having a
unique parameter combination".
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

from .interpolate import render_command, render_environ
from .dag import TaskDAG, TaskNode
from .executors import (
    GangExecutor, GangPool, WorkerPool, make_pool, run_subprocess,
    stackable_key,
)
from .paramspace import ParameterSpace, combo_id, from_task
from .provenance import StudyDB
from .scheduler import Scheduler, TaskResult
from .state import StudyJournal
from .wdl import StudySpec, TaskSpec, parse_file
from .viz import to_ascii, to_dot

#: registry type: task name → callable(combo: dict) -> Any
TaskRegistry = Mapping[str, Callable[[dict[str, Any]], Any]]


def _ns(task: str, pname: str) -> str:
    return f"{task}/{pname}"


def _strip_ns(combo: Mapping[str, Any], task: str) -> dict[str, Any]:
    """Project the global combo onto one task's local parameter names."""
    local: dict[str, Any] = {}
    prefix = f"{task}/"
    for key, value in combo.items():
        if key.startswith(prefix):
            local[key[len(prefix):]] = value
    return local


class ParameterStudy:
    """Orchestrates expansion → DAG → scheduling → provenance."""

    def __init__(
        self,
        spec: StudySpec,
        registry: TaskRegistry | None = None,
        root: str | Path = ".papas",
        name: str | None = None,
    ) -> None:
        self.spec = spec
        self.registry = dict(registry or {})
        self.name = name or "_".join(spec.tasks)[:48]
        self.db = StudyDB(root, self.name)
        self.journal = StudyJournal(self.db.dir / "journal.json")

    # -- expansion --------------------------------------------------------
    def space(self) -> ParameterSpace:
        params: dict[str, list[Any]] = {}
        fixed: list[list[str]] = []
        sampling: dict[str, Any] | None = None
        for tname, task in self.spec.tasks.items():
            tparams = task.parameters()
            tspace = from_task(tparams, task.fixed, task.sampling)
            for pname, values in tspace.params.items():
                params[_ns(tname, pname)] = values
            for group in tspace.fixed:
                fixed.append([_ns(tname, p) for p in group])
            if task.sampling and sampling is None:
                sampling = dict(task.sampling)
        return ParameterSpace(params=params, fixed=fixed, sampling=sampling)

    def instances(self) -> list[dict[str, Any]]:
        """All workflow instances (post-sampling), deterministic order."""
        return self.space().sample()

    # -- DAG construction ---------------------------------------------------
    def build_dag(self, instances: Sequence[Mapping[str, Any]] | None = None
                  ) -> TaskDAG:
        dag = TaskDAG()
        combos = list(instances) if instances is not None else self.instances()
        for combo in combos:
            cid = combo_id(combo)
            for tname, task in self.spec.tasks.items():
                node_id = f"{tname}@{cid}"
                deps = [f"{d}@{cid}" for d in task.after]
                local = _strip_ns(combo, tname)
                dag.add(TaskNode(
                    id=node_id, task=tname, combo=local, deps=deps,
                    payload={"global_combo": dict(combo),
                             "timeout": task.timeout,
                             "allow_nonzero": task.allow_nonzero}))
        dag.validate()
        return dag

    # -- rendering ----------------------------------------------------------
    def render_node(self, node: TaskNode) -> tuple[str | None, dict[str, str]]:
        """Interpolate the command line and environment for one node."""
        task = self.spec.tasks[node.task]
        studies = {
            other: _strip_ns(node.payload["global_combo"], other)
            for other in self.spec.tasks
        }
        cmd = None
        if task.command:
            cmd = render_command(task.command, node.combo, node.task, studies)
        env = render_environ(task.environ, node.combo)
        return cmd, env

    def visualize(self, fmt: str = "ascii",
                  states: Mapping[str, str] | None = None) -> str:
        dag = self.build_dag()
        return to_dot(dag, states, self.name) if fmt == "dot" else to_ascii(dag, states)

    # -- execution ------------------------------------------------------------
    def _default_runner(self, node: TaskNode) -> Any:
        if node.task in self.registry:
            return self.registry[node.task](dict(node.combo))
        cmd, env = self.render_node(node)
        if cmd is None:
            raise RuntimeError(
                f"task {node.task!r} has no command and no registered callable")
        timeout = None
        if isinstance(node.payload, Mapping):
            timeout = node.payload.get("timeout")
        return run_subprocess(cmd, env=env, timeout=timeout)

    def _remote_spec_defaults(self) -> dict[str, Any]:
        """Remote-execution keywords from the WDL: first task that sets
        ``hosts`` / ``batch`` / ``nnodes`` / ``ppnode`` wins."""
        out: dict[str, Any] = {"hosts": None, "batch": None,
                               "nnodes": None, "ppnode": None}
        for task in self.spec.tasks.values():
            out["hosts"] = out["hosts"] or (task.hosts or None)
            out["batch"] = out["batch"] or task.batch
            out["nnodes"] = out["nnodes"] or task.nnodes
            out["ppnode"] = out["ppnode"] or task.ppnode
        return out

    def run(
        self,
        slots: int = 1,
        resume: bool = False,
        runner: Callable[[TaskNode], Any] | None = None,
        gang: GangExecutor | None = None,
        max_retries: int = 1,
        pool: str | WorkerPool = "inline",
        speculate: bool = False,
        hosts: Sequence[str] | None = None,
        ppnode: int | None = None,
        nnodes: int | None = None,
        transport: Any = None,
        submitter: Any = None,
    ) -> dict[str, TaskResult]:
        """Execute the study through the unified event engine.

        ``resume=True`` reloads the journal and skips completed nodes
        (checkpoint/restart).  ``pool`` selects the execution backend:
        ``"inline"`` (deterministic, serial), ``"thread"`` / ``"process"``
        (real parallelism across ``slots`` workers), ``"ssh"`` /
        ``"slurm"`` / ``"pbs"`` (remote dispatch of rendered commands —
        slot count comes from ``hosts × ppnode`` / ``nnodes × ppnode``,
        defaulting to the WDL ``hosts:``/``batch:``/``nnodes``/``ppnode``
        keywords; ``transport`` / ``submitter`` inject the network seam,
        e.g. the no-network ``LocalTransport``/``LocalSubmitter`` fakes),
        or any ``WorkerPool`` instance.  ``gang`` switches to batched
        dispatch — stackable ready groups launched as single programs,
        the paper's single-cluster-job technique — implemented as a pool
        policy on the same engine, so retries, failure closure, and
        journaling apply there too.  ``speculate`` enables straggler
        duplication (idempotent runners only).
        """
        instances = self.instances()
        completed: set[str] = set()
        if resume and self.journal.exists():
            saved_instances, completed, _ = self.journal.load()
            if saved_instances:
                instances = saved_instances
        dag = self.build_dag(instances)
        self.db.write_meta({
            "name": self.name,
            "n_instances": len(instances),
            "n_tasks": len(self.spec.tasks),
            "n_nodes": len(dag.nodes),
            "started": time.time(),
        })
        run_fn = runner or self._default_runner
        host_map: dict[str, str] = {}
        if resume:
            host_map.update(self.journal.hosts())
        self.journal.save(instances, completed, {"name": self.name},
                          hosts=host_map)

        def _on_result(res: TaskResult) -> None:
            node = dag.nodes[res.id]
            self.db.record(res.id, res.status, res.runtime, combo=node.combo,
                           error=res.error, attempts=res.attempts,
                           slot=res.slot, host=res.host)
            if res.status == "ok":
                completed.add(res.id)
                if res.host:
                    host_map[res.id] = res.host
                self.journal.mark_complete(res.id, host=res.host)

        if gang is not None:
            worker: WorkerPool = GangPool(gang)
        elif isinstance(pool, WorkerPool):
            worker = pool
        else:
            if pool in ("ssh", "slurm", "pbs", "batch"):
                d = self._remote_spec_defaults()
                kind = pool if pool != "batch" else (d["batch"] or "slurm")
                worker = make_pool(
                    kind, slots,
                    hosts=list(hosts) if hosts else d["hosts"],
                    ppnode=ppnode or d["ppnode"],
                    nnodes=nnodes or d["nnodes"],
                    render=self.render_node, transport=transport,
                    submitter=submitter,
                    spool_root=self.db.dir / "batch")
            else:
                worker = make_pool(pool, slots)
        # remote pools derive their capacity from hosts/nnodes × ppnode;
        # the scheduler must drive every dispatch lane the pool offers
        # (for batch pools that is the allocation count, not the group
        # size — one dispatch already hosts a whole group)
        slots = max(slots, getattr(worker, "dispatch_slots", slots) or slots)
        sched = Scheduler(slots=slots, max_retries=max_retries,
                          speculate=speculate)
        try:
            results = sched.execute(dag, run_fn, completed=completed,
                                    on_result=_on_result, pool=worker)
        finally:
            if not isinstance(pool, WorkerPool):
                worker.shutdown()
        # compact the journal: fold the append log back into the base
        self.journal.save(instances, completed, {"name": self.name},
                          hosts=host_map)
        return results


def load_study(
    *paths: str | Path,
    registry: TaskRegistry | None = None,
    root: str | Path = ".papas",
    name: str | None = None,
) -> ParameterStudy:
    """Parse one or more parameter files into a runnable study."""
    from .wdl import merge

    specs = [parse_file(p) for p in paths]
    spec = specs[0] if len(specs) == 1 else merge(*specs)
    return ParameterStudy(spec, registry=registry, root=root, name=name)
