"""The Parameter Study engine (paper §4.1) — the top of the stack.

A study is parsed from WDL (or built via the Python API), expanded into
workflow instances (one per unique parameter combination, §5.1), compiled
into a task DAG (tasks × instances), and executed through a chosen
backend with provenance + checkpoint/restart.

Semantics: the global parameter space is the product of every task's
parameter space (parameters are task-namespaced as ``task/param``); a
*workflow instance* is one combination applied across the whole task DAG,
exactly the paper's "a workflow corresponds to an instance having a
unique parameter combination".

Two execution shapes share every backend, retry, and journal semantic:

* **Eager** (``run()``) — materialize all instances, build the full
  tasks × instances DAG up front, journal v1.  Right for small studies
  and for gang policies that want the whole ready set visible.
* **Streaming** (``run(window=N)``) — instances are *addressed, never
  enumerated*: ``iter_instances()`` streams ``(space index, combo)``
  pairs via the space's O(1) mixed-radix ``combo_at``, an
  ``InstanceWindow`` stamps out each instance's task sub-DAG only when
  the scheduler's bounded frontier has room, resolved nodes retire
  immediately, and the journal is compact v2 (space hash + completed
  instance indices, range-compressed).  Startup cost and live state are
  O(slots + window) — independent of N_W — which is what makes
  million-combination studies (§5.1 "large parameter spaces") tractable.
"""
from __future__ import annotations

import dataclasses
import os
import time
from pathlib import Path
from typing import Any, Callable, Iterator, Mapping, Sequence

from . import chaos as chaos_mod
from . import telemetry as telemetry_mod
from .interpolate import compile_environ, compile_template
from .dag import TaskDAG, TaskNode
from .executors import (
    GangExecutor, GangPool, WorkerPool, make_pool, payload_timeout,
    run_subprocess, stackable_key,
)
from .paramspace import ParameterSpace, combo_id, from_task
from .provenance import StudyDB
from .results import build_capture_sets
from .scheduler import AdaptiveWindow, Scheduler, TaskResult
from .state import StudyJournal
from .wdl import StudySpec, TaskSpec, parse_file
from .viz import to_ascii, to_dot

#: registry type: task name → callable(combo: dict) -> Any
TaskRegistry = Mapping[str, Callable[[dict[str, Any]], Any]]


def _ns(task: str, pname: str) -> str:
    return f"{task}/{pname}"


def _strip_ns(combo: Mapping[str, Any], task: str) -> dict[str, Any]:
    """Project the global combo onto one task's local parameter names."""
    local: dict[str, Any] = {}
    prefix = f"{task}/"
    for key, value in combo.items():
        if key.startswith(prefix):
            local[key[len(prefix):]] = value
    return local


class _LazyStudies(Mapping):
    """Per-task combo projections for inter-task ``${task:...}``
    references, materialized only if a reference actually resolves
    through them — rendering a node with no inter-task refs never pays
    the O(tasks × combo) projection the eager dict paid per node."""

    __slots__ = ("_tasks", "_combo", "_cache")

    def __init__(self, tasks: Mapping[str, Any],
                 combo: Mapping[str, Any]) -> None:
        self._tasks = tasks
        self._combo = combo
        self._cache: dict[str, dict[str, Any]] = {}

    def __getitem__(self, key: str) -> dict[str, Any]:
        if key not in self._tasks:
            raise KeyError(key)
        hit = self._cache.get(key)
        if hit is None:
            hit = self._cache[key] = _strip_ns(self._combo, key)
        return hit

    def __iter__(self) -> Iterator[str]:
        return iter(self._tasks)

    def __len__(self) -> int:
        return len(self._tasks)


class ParameterStudy:
    """Orchestrates expansion → DAG → scheduling → provenance."""

    def __init__(
        self,
        spec: StudySpec,
        registry: TaskRegistry | None = None,
        root: str | Path = ".papas",
        name: str | None = None,
        flush_count: int = 64,
        flush_interval: float | None = 0.2,
    ) -> None:
        """``flush_count``/``flush_interval`` set the group-commit policy
        ``run()`` applies to the journal and provenance DB for the
        duration of a run (see ``StudyJournal.group_commit``): records
        buffer and flush per batch instead of per task, and are always
        flushed before ``run()`` returns or raises.  Outside a run both
        stores keep their durable-per-write default."""
        self.spec = spec
        self.registry = dict(registry or {})
        self.name = name or "_".join(spec.tasks)[:48]
        self.flush_count = flush_count
        self.flush_interval = flush_interval
        self.db = StudyDB(root, self.name)
        self.journal = StudyJournal(self.db.dir / "journal.json")
        #: task → compiled ``capture:`` extractors (results subsystem)
        self.captures = build_capture_sets(spec)

    # -- expansion --------------------------------------------------------
    def space(self) -> ParameterSpace:
        """The global parameter space (task-namespaced product).

        ``sampling`` applies to the *global* combination space, so at
        most one distinct sampling block may appear across tasks —
        conflicting blocks raise ``ValueError`` instead of silently
        letting the first task win."""
        params: dict[str, list[Any]] = {}
        fixed: list[list[str]] = []
        sampling: dict[str, Any] | None = None
        sampling_owner: str | None = None
        for tname, task in self.spec.tasks.items():
            tparams = task.parameters()
            tspace = from_task(tparams, task.fixed, task.sampling)
            for pname, values in tspace.params.items():
                params[_ns(tname, pname)] = values
            for group in tspace.fixed:
                fixed.append([_ns(tname, p) for p in group])
            if task.sampling:
                block = dict(task.sampling)
                if sampling is None:
                    sampling, sampling_owner = block, tname
                elif block != sampling:
                    raise ValueError(
                        f"conflicting sampling blocks: task "
                        f"{sampling_owner!r} declares {sampling!r} but "
                        f"task {tname!r} declares {block!r} (sampling is "
                        f"global to the study — declare it once, or "
                        f"identically)")
        return ParameterSpace(params=params, fixed=fixed, sampling=sampling)

    def instance_count(self) -> int:
        """Post-sampling instance count, without enumerating the space."""
        return self.space().sample_count()

    def iter_instances(self) -> Iterator[tuple[int, dict[str, Any]]]:
        """Stream ``(space index, combo)`` pairs in deterministic
        sampling order — O(1) memory regardless of space size."""
        space = self.space()
        for i in space.iter_sample():
            yield i, space.combo_at(i)

    def instances(self) -> list[dict[str, Any]]:
        """All workflow instances (post-sampling), deterministic order —
        materialized; prefer ``iter_instances`` for large spaces."""
        return self.space().sample()

    # -- static analysis ---------------------------------------------------
    def lint(self, slots: int | None = None,
             max_runtime_days: float | None = None) -> Any:
        """Pre-flight static analysis (``repro.core.lint`` rule pack).

        Cost-estimator priors are this study's own observed median
        runtimes per task (from provenance records of earlier runs),
        falling back to each task's declared ``timeout:`` — so a
        re-lint after a partial run prices the sweep from real data.
        Index math only; never materializes an instance."""
        from .lint import lint as lint_spec

        samples: dict[str, list[float]] = {}
        try:
            for rec in self.db.records():
                if rec.get("status") != "ok":
                    continue
                tname = str(rec.get("task_id", "")).split("@", 1)[0]
                rt = rec.get("runtime")
                if tname and isinstance(rt, (int, float)):
                    samples.setdefault(tname, []).append(float(rt))
        except Exception:        # unreadable records never block linting
            samples = {}
        priors = {t: sorted(v)[len(v) // 2] for t, v in samples.items()}
        return lint_spec(self.spec, slots=slots, priors=priors,
                         max_runtime_days=max_runtime_days)

    # -- DAG construction ---------------------------------------------------
    def _instance_nodes(self, combo: Mapping[str, Any],
                        index: int | None = None) -> list[TaskNode]:
        """One instance's task sub-DAG (self-contained: deps stay inside
        the instance).  ``index`` is the combo's space index, carried in
        the payload for journal v2 / provenance."""
        cid = combo_id(combo)
        nodes: list[TaskNode] = []
        for tname, task in self.spec.tasks.items():
            payload: dict[str, Any] = {"global_combo": dict(combo),
                                       "timeout": task.timeout,
                                       "allow_nonzero": task.allow_nonzero}
            if task.retry:
                payload["retry"] = task.retry
            if index is not None:
                payload["index"] = index
            nodes.append(TaskNode(
                id=f"{tname}@{cid}", task=tname,
                combo=_strip_ns(combo, tname),
                deps=[f"{d}@{cid}" for d in task.after],
                payload=payload))
        return nodes

    def build_dag(self, instances: Sequence[Mapping[str, Any]] | None = None
                  ) -> TaskDAG:
        dag = TaskDAG()
        combos = list(instances) if instances is not None else self.instances()
        for combo in combos:
            for node in self._instance_nodes(combo):
                dag.add(node)
        dag.validate()
        return dag

    # -- rendering ----------------------------------------------------------
    def render_node(self, node: TaskNode) -> tuple[str | None, dict[str, str]]:
        """Render the command line and environment for one node.

        Uses compiled instance templates: each distinct command/environ
        template parses once per process (``interpolate.compile_template``)
        and every instance render is a list join over resolved slots —
        byte-identical to the reference ``interpolate()`` path, minus the
        per-instance regex work.  Inter-task ``${task:...}`` projections
        are built lazily, only if a reference resolves through them."""
        task = self.spec.tasks[node.task]
        cmd = None
        if task.command:
            studies = _LazyStudies(self.spec.tasks,
                                   node.payload["global_combo"])
            cmd = compile_template(task.command).render(
                node.combo, node.task, studies)
        env = compile_environ(tuple(task.environ)).render(node.combo)
        return cmd, env

    def visualize(self, fmt: str = "ascii",
                  states: Mapping[str, str] | None = None) -> str:
        dag = self.build_dag()
        return to_dot(dag, states, self.name) if fmt == "dot" else to_ascii(dag, states)

    # -- execution ------------------------------------------------------------
    def _default_runner(self, node: TaskNode) -> Any:
        if node.task in self.registry:
            return self.registry[node.task](dict(node.combo))
        cmd, env = self.render_node(node)
        if cmd is None:
            raise RuntimeError(
                f"task {node.task!r} has no command and no registered callable")
        # ambient env snapshotted once per run, not copied per task
        return run_subprocess(cmd, env=env, timeout=payload_timeout(node),
                              base_env=getattr(self, "_run_base_env", None))

    def _remote_spec_defaults(self) -> dict[str, Any]:
        """Remote-execution keywords from the WDL, merged across tasks.

        A keyword a task leaves unset (``None`` / empty ``hosts``) defers
        to whichever task declares it; two tasks declaring *different*
        values for the same keyword is a spec error (the pool is built
        once per study, so per-task divergence cannot be honored)."""
        out: dict[str, Any] = {"hosts": None, "batch": None,
                               "nnodes": None, "ppnode": None}
        owner: dict[str, str] = {}
        for tname, task in self.spec.tasks.items():
            declared = {"hosts": task.hosts or None, "batch": task.batch,
                        "nnodes": task.nnodes, "ppnode": task.ppnode}
            for key, val in declared.items():
                if val is None:
                    continue
                if out[key] is None:
                    out[key], owner[key] = val, tname
                elif out[key] != val:
                    raise ValueError(
                        f"conflicting remote keyword {key!r}: task "
                        f"{owner[key]!r} declares {out[key]!r} but task "
                        f"{tname!r} declares {val!r}")
        return out

    def _spec_straggler_quantile(self) -> float | None:
        """The WDL ``straggler_quantile:`` keyword, merged across tasks
        (the scheduler has one cutoff rule per run, so divergent
        declarations are a spec error)."""
        out: float | None = None
        owner: str | None = None
        for tname, task in self.spec.tasks.items():
            q = task.straggler_quantile
            if q is None:
                continue
            if out is None:
                out, owner = q, tname
            elif out != q:
                raise ValueError(
                    f"conflicting straggler_quantile: task {owner!r} "
                    f"declares {out!r} but task {tname!r} declares {q!r}")
        return out

    @staticmethod
    def _auto_shards(worker: WorkerPool) -> int:
        """Journal/DB shard count for a backend: high-rate local
        parallel pools (lanes, processes) split the completion stream so
        group commits never serialize on one handle; everything else
        keeps the legacy single-segment layout."""
        slots = int(getattr(worker, "slots", 1) or 1)
        if getattr(worker, "kind", "") in ("lane", "process") and slots > 1:
            return min(4, slots)
        return 1

    def _make_worker(
        self,
        pool: str | WorkerPool,
        gang: GangExecutor | None,
        slots: int,
        hosts: Sequence[str] | None,
        ppnode: int | None,
        nnodes: int | None,
        transport: Any,
        submitter: Any,
    ) -> tuple[WorkerPool, bool]:
        """Resolve the execution backend (shared by the eager and
        windowed paths).  Returns ``(worker, owned)`` — an owned worker
        is shut down by the run that created it."""
        if gang is not None:
            return GangPool(gang), True
        if isinstance(pool, WorkerPool):
            return pool, False
        if pool == "lane":
            # a capture sourcing stderr needs the spool routed back even
            # on success (lanes otherwise read stderr only on failure)
            wants_stderr = any(cs.uses_stderr for cs in self.captures.values())
            return make_pool("lane", slots, render=self.render_node,
                             capture_stderr=wants_stderr), True
        if pool in ("ssh", "slurm", "pbs", "batch"):
            d = self._remote_spec_defaults()
            kind = pool if pool != "batch" else (d["batch"] or "slurm")
            return make_pool(
                kind, slots,
                hosts=list(hosts) if hosts else d["hosts"],
                ppnode=ppnode or d["ppnode"],
                nnodes=nnodes or d["nnodes"],
                render=self.render_node, transport=transport,
                submitter=submitter,
                spool_root=self.db.dir / "batch"), True
        return make_pool(pool, slots), True

    # -- chaos / degraded-run health ------------------------------------
    @staticmethod
    def _resolve_chaos(chaos: Any) -> Any:
        """Normalize ``run(chaos=…)`` to a live ``ChaosController``:
        accepts a controller, a ``FaultPlan``, a plan mapping, or a
        path to a plan YAML.  ``None`` falls through to whatever is
        already armed process-wide (``PAPAS_CHAOS`` / ``install``)."""
        if chaos is None:
            return chaos_mod.current()
        if isinstance(chaos, chaos_mod.ChaosController):
            return chaos
        if isinstance(chaos, chaos_mod.FaultPlan):
            return chaos.controller()
        if isinstance(chaos, Mapping):
            return chaos_mod.FaultPlan.from_dict(chaos).controller()
        return chaos_mod.FaultPlan.load(chaos).controller()

    # -- telemetry -------------------------------------------------------
    @staticmethod
    def _resolve_trace(trace: Any) -> Any:
        """Normalize ``run(trace=…)`` to a live ``Telemetry`` (or None):
        accepts a ``Telemetry``, ``True`` (fresh collector, default
        ``trace.json`` location), a path for the trace file, or
        ``False`` to force-disarm.  ``None`` falls through to whatever
        is already armed process-wide (``PAPAS_TRACE`` / ``install``)."""
        if trace is None:
            return telemetry_mod.current()
        if trace is False:
            return None
        if isinstance(trace, telemetry_mod.Telemetry):
            return trace
        if trace is True:
            return telemetry_mod.Telemetry()
        return telemetry_mod.Telemetry(path=trace)

    def _finalize_telemetry(self, tel: Any) -> None:
        """Persist the armed run's observability artifacts: the Chrome
        trace next to the provenance files and the metrics snapshot —
        including per-shard group-commit counters, captured *before*
        post-run compaction folds the segments — into ``study.json``."""
        snapshot = tel.metrics.snapshot()
        snapshot["groupcommit_shards"] = {
            "journal": self.journal.shard_counters(),
            "records": self.db.shard_counters(),
        }
        trace_path = Path(tel.path) if tel.path else self.db.dir / "trace.json"
        tel.trace.write(trace_path)
        meta = self.db.read_meta()
        meta["telemetry"] = snapshot
        meta["trace"] = str(trace_path)
        self.db.write_meta(meta)

    def _finalize_run_health(self, worker: Any, ctrl: Any
                             ) -> dict[str, Any]:
        """Post-run health verdict (graceful degradation): a run that
        survived faults — permanently lost hosts, injected chaos —
        completes *degraded* instead of dying, and ``study.json``
        records what was lost (per-host causes, the fault ledger) so
        reports can flag the result set (§4.3 fault tolerance)."""
        health: dict[str, Any] = {}
        lost = sorted(getattr(worker, "dead_hosts", None) or ())
        if lost:
            causes = getattr(worker, "host_causes", None) or {}
            health["lost_hosts"] = lost
            health["host_causes"] = {h: causes.get(h, "unknown")
                                     for h in lost}
        if ctrl is not None and len(ctrl.ledger):
            health["fault_ledger"] = ctrl.ledger.as_list()
        if health:
            health["degraded"] = True
            meta = self.db.read_meta()
            meta.update(health)
            self.db.write_meta(meta)
        return health

    # -- results capture ------------------------------------------------
    def _capture_state(self, aggregator: Any) -> tuple[
            Callable[[TaskNode, Any], str | None] | None,
            Callable[[TaskNode, TaskResult], dict[str, Any] | None] | None]:
        """Per-run capture machinery: ``(classify, finish)``.

        ``classify`` runs the text extractors against a completed
        attempt's value and fails the attempt when a *required* metric
        is missing (scheduler seam — retries and failure closure apply
        like any task failure); extracted metrics are cached so the
        final resolution never re-extracts.  ``finish`` folds in the
        engine-measured builtins, attaches the metrics to the
        ``TaskResult``, and feeds the streaming aggregator.  Both are
        ``None`` when the study declares no captures (and no aggregator
        rides along) — the hot path pays nothing.
        """
        if not self.captures and aggregator is None:
            return None, None
        cache: dict[str, dict[str, Any]] = {}

        def classify(node: TaskNode, value: Any) -> str | None:
            cs = self.captures.get(node.task)
            if cs is None:
                return None
            metrics, missing = cs.extract(value, combo=node.combo)
            cache[node.id] = metrics
            if missing:
                plural = "s" if len(missing) > 1 else ""
                return (f"missing required metric{plural}: "
                        f"{', '.join(sorted(missing))}")
            return None

        def finish(node: TaskNode, res: TaskResult
                   ) -> dict[str, Any] | None:
            cs = self.captures.get(node.task)
            metrics = None
            if cs is not None:
                metrics = cs.finalize(cache.pop(res.id, None), res)
                res.metrics = metrics
            if aggregator is not None and res.status == "ok":
                aggregator.add(node.combo, metrics or {})
            return metrics

        return (classify if self.captures else None), finish

    @staticmethod
    def _ids_from_indices(space: ParameterSpace,
                          completed_indices: Mapping[str, set[int]]
                          ) -> set[str]:
        """Reconstruct completed node ids from a v2 journal's per-task
        instance indices (eager resume of a streaming journal)."""
        cids: dict[int, str] = {}
        ids: set[str] = set()
        for tname, idxs in completed_indices.items():
            for i in idxs:
                cid = cids.get(i)
                if cid is None:
                    cid = cids[i] = combo_id(space.combo_at(i))
                ids.add(f"{tname}@{cid}")
        return ids

    @staticmethod
    def _indices_from_v1(space: ParameterSpace, instances: Sequence[Mapping[str, Any]],
                         completed: set[str]) -> dict[str, set[int]]:
        """Migrate a v1 journal's completed node ids to per-task space
        indices (streaming resume of an eager journal).  Instances no
        longer addressable in the current space are dropped — they would
        not be admitted anyway.

        A crash-state v1 journal (the eager run died between
        ``mark_complete`` and compaction) has completions in the sidecar
        log but an *empty* base instance list; completed ids their
        instance list cannot explain are resolved by streaming the
        sampled space until every cid is found — completions cluster at
        the front of sampling order, so the scan usually stops early.
        """
        idx_by_cid: dict[str, int] = {}
        for inst in instances:
            try:
                idx_by_cid[combo_id(inst)] = space.index_of(inst)
            except (KeyError, ValueError):
                continue
        unmatched = ({nid.partition("@")[2] for nid in completed}
                     - set(idx_by_cid))
        if unmatched:
            for i in space.iter_sample():
                cid = combo_id(space.combo_at(i))
                if cid in unmatched:
                    idx_by_cid[cid] = i
                    unmatched.discard(cid)
                    if not unmatched:
                        break
        out: dict[str, set[int]] = {}
        for nid in completed:
            tname, _, cid = nid.partition("@")
            if cid in idx_by_cid:
                out.setdefault(tname, set()).add(idx_by_cid[cid])
        return out

    def run(
        self,
        slots: int = 1,
        resume: bool = False,
        runner: Callable[[TaskNode], Any] | None = None,
        gang: GangExecutor | None = None,
        max_retries: int = 1,
        pool: str | WorkerPool = "inline",
        speculate: bool = False,
        hosts: Sequence[str] | None = None,
        ppnode: int | None = None,
        nnodes: int | None = None,
        transport: Any = None,
        submitter: Any = None,
        window: int | str | None = None,
        on_result: Callable[[TaskResult], None] | None = None,
        keep_results: bool = True,
        aggregator: Any = None,
        straggler_quantile: float | None = None,
        retry: Any = None,
        chaos: Any = None,
        trace: Any = None,
    ) -> dict[str, TaskResult]:
        """Execute the study through the unified event engine.

        ``resume=True`` reloads the journal and skips completed nodes
        (checkpoint/restart; either journal version resumes under either
        path).  ``pool`` selects the execution backend: ``"inline"``
        (deterministic, serial), ``"thread"`` / ``"process"`` (real
        parallelism across ``slots`` workers), ``"lane"`` (persistent
        shell worker lanes — the short-task throughput path; tasks must
        render to shell commands), ``"ssh"`` / ``"slurm"`` / ``"pbs"``
        (remote dispatch of rendered commands — slot count comes from
        ``hosts × ppnode`` / ``nnodes × ppnode``, defaulting to the WDL
        ``hosts:``/``batch:``/``nnodes``/``ppnode`` keywords;
        ``transport`` / ``submitter`` inject the network seam, e.g. the
        no-network ``LocalTransport``/``LocalSubmitter`` fakes), or any
        ``WorkerPool`` instance.  ``gang`` switches to batched dispatch —
        stackable ready groups launched as single programs, the paper's
        single-cluster-job technique — implemented as a pool policy on
        the same engine, so retries, failure closure, and journaling
        apply there too.  ``speculate`` enables straggler duplication
        (idempotent runners only).

        ``window=N`` switches to streaming admission: instances are
        stamped out lazily from their space index, at most
        ``slots + N`` task nodes stay live, and the journal is compact
        v2 — startup and memory stay O(slots + window) however large the
        space (``window=None`` keeps the eager whole-DAG path).
        ``window="auto"`` sizes the admission window adaptively from the
        observed completion rate (about half a second of throughput,
        clamped to [slots, 4096]) so short-task sweeps stop hand-tuning
        ``--window``.  ``straggler_quantile`` (e.g. 0.9 for p90)
        replaces the default ``straggler_factor × median`` straggler
        cutoff with the running runtime quantile; the WDL
        ``straggler_quantile:`` keyword sets the same thing, with the
        argument winning when both appear.

        ``on_result`` streams each ``TaskResult`` to the caller as it
        resolves (after journal/provenance bookkeeping).
        ``keep_results=False`` additionally skips the O(N_W) result
        accumulation — the returned dict is empty and, combined with
        ``window=N``, a 10^5-combination run holds O(slots + window)
        engine state end to end.  Journal and provenance DB writes are
        group-committed for the duration of the run (see
        ``flush_count``/``flush_interval`` on the constructor) and are
        always flushed before this method returns or raises.

        When tasks declare ``capture:`` metrics, every attempt is
        extracted once: a missing *required* metric classifies the
        attempt as failed (retried, then failure-closed, like a nonzero
        exit), the final metrics ride ``TaskResult.metrics`` and the
        provenance record (``metrics=…``), and ``aggregator`` (a
        ``ResultsAggregator``) is fed each ``ok`` resolution's
        ``(combo, metrics)`` — with ``keep_results=False`` a streaming
        run aggregates in O(groups) memory with no result accumulation
        anywhere.

        ``retry`` sets the run's default retry policy (a
        ``scheduler.RetryPolicy`` or a WDL ``retry:``-shaped mapping:
        ``max``/``backoff``/``base``/``jitter``/``retry_on``) — failed
        attempts re-queue after a backoff delay instead of instantly,
        and per-task WDL ``retry:`` blocks override it.  ``chaos``
        arms deterministic fault injection for the run (a
        ``chaos.FaultPlan``, a plan mapping, a plan-YAML path, or a
        live ``ChaosController``); the run then completes *degraded*
        rather than dying when hosts are permanently lost, with the
        fault ledger and per-host causes attached to ``study.json``.

        ``trace`` arms the telemetry layer for the run (``True``, a
        ``telemetry.Telemetry``, or a path for the Chrome-trace JSON;
        ``False`` force-disarms, ``None`` defers to ``PAPAS_TRACE``):
        the scheduler, pools, and group-commit writers emit lifecycle
        spans and metrics, ``trace.json`` lands in the study directory
        (Perfetto/``chrome://tracing`` loadable), and the metrics
        snapshot is attached to ``study.json`` under ``telemetry``.
        """
        if isinstance(window, str) and window != "auto":
            raise ValueError(
                f"window must be a positive int, 'auto', or None; "
                f"got {window!r}")
        if straggler_quantile is None:
            straggler_quantile = self._spec_straggler_quantile()
        if window is not None:
            return self._run_windowed(
                window=window, slots=slots, resume=resume, runner=runner,
                gang=gang, max_retries=max_retries, pool=pool,
                speculate=speculate, hosts=hosts, ppnode=ppnode,
                nnodes=nnodes, transport=transport, submitter=submitter,
                on_result=on_result, keep_results=keep_results,
                aggregator=aggregator,
                straggler_quantile=straggler_quantile,
                retry=retry, chaos=chaos, trace=trace)
        ctrl = self._resolve_chaos(chaos)
        tel = self._resolve_trace(trace)
        instances = self.instances()
        completed: set[str] = set()
        if resume and self.journal.exists():
            state = self.journal.load_state()
            completed = set(state.completed)
            if state.version == 1 and state.instances:
                instances = state.instances
            elif state.version == 2 and state.completed_indices:
                space = self.space()
                if state.space_hash and state.space_hash != space.space_hash():
                    raise ValueError(
                        f"cannot resume: journal was written for space "
                        f"{state.space_hash} but this study declares "
                        f"{space.space_hash()}")
                completed |= self._ids_from_indices(
                    space, state.completed_indices)
        dag = self.build_dag(instances)
        self.db.write_meta({
            "name": self.name,
            "n_instances": len(instances),
            "n_tasks": len(self.spec.tasks),
            "n_nodes": len(dag.nodes),
            "started": time.time(),
        })
        run_fn = runner or self._default_runner
        host_map: dict[str, str] = {}
        if resume:
            host_map.update(self.journal.hosts())
        self.journal.save(instances, completed, {"name": self.name},
                          hosts=host_map)

        # arm chaos + telemetry for the backend's whole lifetime — lane
        # pools capture both at construction, transports consult chaos
        # per dispatch — restoring whatever was armed before
        _prev_chaos = chaos_mod.current()
        chaos_mod.install(ctrl)
        _prev_tel = telemetry_mod.current()
        telemetry_mod.install(tel)
        if tel is not None:
            tel.begin_run(total=max(0, len(dag.nodes) - len(completed)),
                          slots=slots)
        worker: WorkerPool | None = None
        owned = False
        try:
            worker, owned = self._make_worker(pool, gang, slots, hosts,
                                              ppnode, nnodes, transport,
                                              submitter)
            # lane-style pools report transient local labels as hosts:
            # they stay in the per-attempt records, never the journal
            # host map (which must stay O(remote tasks), not O(N_W))
            keep_hosts = getattr(worker, "durable_hosts", True)
            capture_classify, capture_finish = \
                self._capture_state(aggregator)

            def _on_result(res: TaskResult) -> None:
                node = dag.nodes[res.id]
                metrics = (capture_finish(node, res) if capture_finish
                           else None)
                self.db.record(res.id, res.status, res.runtime,
                               combo=node.combo, error=res.error,
                               attempts=res.attempts, slot=res.slot,
                               host=res.host, metrics=metrics)
                if res.status == "ok":
                    completed.add(res.id)
                    host = res.host if keep_hosts else None
                    if host:
                        host_map[res.id] = host
                    self.journal.mark_complete(res.id, host=host)
                if ctrl is not None:
                    ctrl.on_record()      # sigkill seam: crash-by-plan
                if on_result is not None:
                    on_result(res)

            # remote pools derive their capacity from hosts/nnodes ×
            # ppnode; the scheduler must drive every dispatch lane the
            # pool offers (for batch pools that is the allocation
            # count, not the group size — one dispatch already hosts a
            # whole group)
            slots = max(slots,
                        getattr(worker, "dispatch_slots", slots) or slots)
            if tel is not None:
                tel.slots = max(1, slots)   # post-lift: the ETA divisor
            sched = Scheduler(slots=slots, max_retries=max_retries,
                              speculate=speculate,
                              straggler_quantile=straggler_quantile,
                              retry_policy=retry)
            # high-rate parallel backends shard the completion streams
            # so group commits never serialize on one buffered handle;
            # the compaction below folds every segment back to the base
            shards = self._auto_shards(worker)
            self.journal.set_shards(shards)
            self.db.set_shards(shards)
            # durability order: a journal entry must never become
            # durable before the provenance record it refers to — a
            # crash may lose a completion (resume re-runs it) but never
            # strand a journaled completion without its record
            self.journal.set_pre_flush(self.db.flush)
            self._run_base_env = dict(os.environ)  # one snapshot per run
            with self.journal.group_commit(self.flush_count,
                                           self.flush_interval), \
                    self.db.group_commit(self.flush_count,
                                         self.flush_interval):
                results = sched.execute(dag, run_fn, completed=completed,
                                        on_result=_on_result, pool=worker,
                                        keep_results=keep_results,
                                        classify=capture_classify)
        finally:
            chaos_mod.install(_prev_chaos)
            telemetry_mod.install(_prev_tel)
            self.journal.set_pre_flush(None)
            if owned and worker is not None:
                worker.shutdown()
        # compact the journal: fold the append log back into the base
        self.journal.save(instances, completed, {"name": self.name},
                          hosts=host_map)
        if tel is not None:
            self._finalize_telemetry(tel)
        self.journal.set_shards(1)
        self.db.set_shards(1)
        self.last_run_stats = {
            "peak_live_nodes": sched.peak_live_nodes,
            "n_instances": len(instances),
        }
        self.last_run_stats.update(self._finalize_run_health(worker, ctrl))
        return results

    def _run_windowed(
        self,
        window: int | str,
        slots: int,
        resume: bool,
        runner: Callable[[TaskNode], Any] | None,
        gang: GangExecutor | None,
        max_retries: int,
        pool: str | WorkerPool,
        speculate: bool,
        hosts: Sequence[str] | None,
        ppnode: int | None,
        nnodes: int | None,
        transport: Any,
        submitter: Any,
        on_result: Callable[[TaskResult], None] | None = None,
        keep_results: bool = True,
        aggregator: Any = None,
        straggler_quantile: float | None = None,
        retry: Any = None,
        chaos: Any = None,
        trace: Any = None,
    ) -> dict[str, TaskResult]:
        """Streaming execution: windowed admission + journal v2."""
        ctrl = self._resolve_chaos(chaos)
        tel = self._resolve_trace(trace)
        space = self.space()
        shash = space.space_hash()
        n_instances = space.sample_count()
        if space.size():
            # every instance shares one task topology — validate it once
            # on a template sub-DAG instead of per admission
            template = TaskDAG()
            for node in self._instance_nodes(space.combo_at(0)):
                template.add(node)
            template.validate()

        completed_idx: dict[str, set[int]] = {}
        host_map: dict[str, str] = {}
        if resume and self.journal.exists():
            state = self.journal.load_state()
            if state.version == 2:
                if state.space_hash and state.space_hash != shash:
                    raise ValueError(
                        f"cannot resume: journal was written for space "
                        f"{state.space_hash} but this study declares "
                        f"{shash}")
                completed_idx = {t: set(ix) for t, ix
                                 in (state.completed_indices or {}).items()}
            else:
                completed_idx = self._indices_from_v1(
                    space, state.instances or [], state.completed)
            host_map.update(state.hosts)

        self.db.write_meta({
            "name": self.name,
            "n_instances": n_instances,
            "n_tasks": len(self.spec.tasks),
            "n_nodes": n_instances * len(self.spec.tasks),
            "space": shash,
            "window": window,
            "started": time.time(),
        })
        self.journal.save_indexed(shash, n_instances, completed_idx,
                                  {"name": self.name}, hosts=host_map)

        source = InstanceWindow(self, space=space, completed=completed_idx)
        dag = TaskDAG()
        run_fn = runner or self._default_runner

        # see the eager path: arm chaos + telemetry for the backend's
        # lifetime
        _prev_chaos = chaos_mod.current()
        chaos_mod.install(ctrl)
        _prev_tel = telemetry_mod.current()
        telemetry_mod.install(tel)
        if tel is not None:
            done_nodes = sum(len(v) for v in completed_idx.values())
            tel.begin_run(
                total=max(0, n_instances * len(self.spec.tasks)
                          - done_nodes),
                slots=slots)
        worker: WorkerPool | None = None
        owned = False
        try:
            worker, owned = self._make_worker(pool, gang, slots, hosts,
                                              ppnode, nnodes, transport,
                                              submitter)
            # see the eager path: transient lane labels never enter the
            # journal host map — streaming journals stay O(completed
            # ranges)
            keep_hosts = getattr(worker, "durable_hosts", True)
            capture_classify, capture_finish = \
                self._capture_state(aggregator)

            def _on_result(res: TaskResult) -> None:
                # fires before the scheduler retires the node, so the
                # lookup below sees the live TaskNode
                node = dag.nodes[res.id]
                idx = node.payload.get("index")
                metrics = (capture_finish(node, res) if capture_finish
                           else None)
                self.db.record(res.id, res.status, res.runtime,
                               combo=node.combo, error=res.error,
                               attempts=res.attempts, slot=res.slot,
                               host=res.host, index=idx, metrics=metrics)
                if res.status == "ok":
                    host = res.host if keep_hosts else None
                    if host:
                        host_map[res.id] = host
                    if idx is not None:
                        completed_idx.setdefault(node.task,
                                                 set()).add(idx)
                    self.journal.mark_complete(res.id, host=host,
                                               index=idx, task=node.task)
                if ctrl is not None:
                    ctrl.on_record()      # sigkill seam: crash-by-plan
                if on_result is not None:
                    on_result(res)

            slots = max(slots,
                        getattr(worker, "dispatch_slots", slots) or slots)
            if tel is not None:
                tel.slots = max(1, slots)   # post-lift: the ETA divisor
            # "auto": size the admission window from the observed
            # completion rate (~half a second of throughput), floored
            # at the slot count
            win: int | AdaptiveWindow = (AdaptiveWindow(slots=slots)
                                         if window == "auto" else window)
            sched = Scheduler(slots=slots, max_retries=max_retries,
                              speculate=speculate,
                              straggler_quantile=straggler_quantile,
                              retry_policy=retry)
            # see the eager path: shard the completion streams; couple
            # journal durability to the DB's (records first, always)
            shards = self._auto_shards(worker)
            self.journal.set_shards(shards)
            self.db.set_shards(shards)
            self.journal.set_pre_flush(self.db.flush)
            self._run_base_env = dict(os.environ)  # one snapshot per run
            with self.journal.group_commit(self.flush_count,
                                           self.flush_interval), \
                    self.db.group_commit(self.flush_count,
                                         self.flush_interval):
                results = sched.execute(dag, run_fn, on_result=_on_result,
                                        pool=worker, source=source,
                                        window=win,
                                        keep_results=keep_results,
                                        classify=capture_classify)
        finally:
            chaos_mod.install(_prev_chaos)
            telemetry_mod.install(_prev_tel)
            self.journal.set_pre_flush(None)
            if owned and worker is not None:
                worker.shutdown()
        # compact: fold the append log back into a fresh v2 base
        self.journal.save_indexed(shash, n_instances, completed_idx,
                                  {"name": self.name}, hosts=host_map)
        if tel is not None:
            self._finalize_telemetry(tel)
        self.journal.set_shards(1)
        self.db.set_shards(1)
        self.last_run_stats = {
            "peak_live_nodes": sched.peak_live_nodes,
            "n_instances": n_instances,
            "admitted_instances": source.admitted,
            "skipped_complete": source.skipped,
            "slots": slots,     # post-lift: the admission bound's slots
            "window": win.current if isinstance(win, AdaptiveWindow)
            else window,
        }
        self.last_run_stats.update(self._finalize_run_health(worker, ctrl))
        return results


class InstanceWindow:
    """Lazy instance source for streaming execution (``run(window=N)``).

    Iterates the space's sampled *indices* and stamps out one instance's
    self-contained task sub-DAG per ``next_subdag()`` call — nothing is
    enumerated ahead of the scheduler's admission window.  ``completed``
    (task name → completed space indices, e.g. from a v2 journal) makes
    resume free: an instance whose every task is complete is skipped
    without ever being admitted; a partially complete instance admits
    with its done node ids declared, so only the remainder runs.
    """

    def __init__(
        self,
        study: ParameterStudy,
        space: ParameterSpace | None = None,
        completed: Mapping[str, set[int]] | None = None,
    ) -> None:
        self.study = study
        self.space = space if space is not None else study.space()
        # snapshot: completions recorded *during* the run must not make
        # the source skip instances it still owes the scheduler
        self._completed = {t: frozenset(ix)
                           for t, ix in (completed or {}).items()}
        self._indices = self.space.iter_sample()
        self.admitted = 0           # instances handed to the scheduler
        self.skipped = 0            # instances already fully complete

    def next_subdag(self) -> tuple[list[TaskNode], set[str]] | None:
        """The next not-fully-complete instance's ``(nodes, done node
        ids)`` — or ``None`` when the sampled index stream is dry."""
        tasks = self.study.spec.tasks
        for i in self._indices:
            done = {t for t, ix in self._completed.items() if i in ix}
            if len(done) == len(tasks):
                self.skipped += 1
                continue
            nodes = self.study._instance_nodes(self.space.combo_at(i),
                                               index=i)
            self.admitted += 1
            return nodes, {n.id for n in nodes if n.task in done}
        return None


def load_study(
    *paths: str | Path,
    registry: TaskRegistry | None = None,
    root: str | Path = ".papas",
    name: str | None = None,
) -> ParameterStudy:
    """Parse one or more parameter files into a runnable study."""
    from .wdl import merge

    specs = [parse_file(p) for p in paths]
    spec = specs[0] if len(specs) == 1 else merge(*specs)
    return ParameterStudy(spec, registry=registry, root=root, name=name)
