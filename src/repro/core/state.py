"""Study checkpoint/restart journal (paper §4.1).

"PaPaS provides checkpoint-restart functionality in case of fault or a
deliberate pause/stop operation.  A parameter study's state can be saved
in a workflow file and reloaded at a later time."

The journal is a JSON base document (the study's expanded instance list
plus the completions known when it was written) and an append-only
sidecar log of task ids completed since.  Recording one completion is an
O(1) append — not a full rewrite of the study state — so journaling
stays cheap for long sweeps and safe when results arrive from a
concurrent engine (a lock serializes writers; base writes stay atomic
via tmp + rename).  ``load()`` folds the log back into the base.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Any, Mapping


class StudyJournal:
    def __init__(self, path: str | Path) -> None:
        self.path = Path(path)
        self.log_path = self.path.with_name(self.path.name + ".log")
        self._lock = threading.Lock()

    def exists(self) -> bool:
        return self.path.exists()

    # journals ride along when a bound runner is pickled to a process
    # pool; the lock is process-local state
    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = threading.Lock()

    # -- base document ---------------------------------------------------
    def _write_base(
        self,
        instances: list[dict[str, Any]],
        completed: set[str],
        meta: Mapping[str, Any] | None,
        hosts: Mapping[str, str] | None = None,
    ) -> None:
        doc = {
            "version": 1,
            "instances": instances,
            "completed": sorted(completed),
            "meta": dict(meta or {}),
            "hosts": dict(hosts or {}),
        }
        tmp = self.path.with_suffix(".tmp")
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp.write_text(json.dumps(doc, default=str))
        os.replace(tmp, self.path)
        # the log's entries are folded into the base we just wrote
        if self.log_path.exists():
            self.log_path.unlink()

    def save(
        self,
        instances: list[dict[str, Any]],
        completed: set[str],
        meta: Mapping[str, Any] | None = None,
        hosts: Mapping[str, str] | None = None,
    ) -> None:
        """Write (compact) the full study state atomically.  ``hosts``
        maps task id → executing host (remote backends)."""
        with self._lock:
            self._write_base(instances, completed, meta, hosts)

    def mark_complete(self, task_id: str, host: str | None = None) -> None:
        """Incrementally record one completion: an O(1) locked append to
        the sidecar log, never a rewrite of the base document.  ``host``
        records where the task ran (remote provenance)."""
        entry: dict[str, Any] = {"completed": task_id}
        if host:
            entry["host"] = host
        with self._lock:
            if not self.path.exists():
                self._write_base([], set(), {})
            with self.log_path.open("a") as f:
                f.write(json.dumps(entry) + "\n")
                f.flush()

    def load(self) -> tuple[list[dict[str, Any]], set[str], dict[str, Any]]:
        with self._lock:
            doc = json.loads(self.path.read_text())
            if doc.get("version") != 1:
                raise ValueError(
                    f"unsupported journal version {doc.get('version')!r}")
            completed = set(doc["completed"])
            if self.log_path.exists():
                with self.log_path.open() as f:
                    for line in f:
                        line = line.strip()
                        if line:
                            completed.add(json.loads(line)["completed"])
            return doc["instances"], completed, doc.get("meta", {})

    def hosts(self) -> dict[str, str]:
        """Task id → executing host, folded from the base document and
        the sidecar log (remote-backend provenance)."""
        with self._lock:
            hosts: dict[str, str] = {}
            if self.path.exists():
                doc = json.loads(self.path.read_text())
                hosts.update(doc.get("hosts") or {})
            if self.log_path.exists():
                with self.log_path.open() as f:
                    for line in f:
                        line = line.strip()
                        if line:
                            entry = json.loads(line)
                            if entry.get("host"):
                                hosts[entry["completed"]] = entry["host"]
            return hosts
