"""Study checkpoint/restart journal (paper §4.1) — now streaming-aware.

"PaPaS provides checkpoint-restart functionality in case of fault or a
deliberate pause/stop operation.  A parameter study's state can be saved
in a workflow file and reloaded at a later time."

Two on-disk formats share one append-only design:

* **v1 (legacy, eager)** — the base document stores the study's fully
  expanded instance list plus the completed node ids known when it was
  written.  O(N_W) bytes per compaction; still written by the eager
  (non-windowed) execution path and always readable.
* **v2 (compact, streaming)** — the base document stores the *space
  hash* (pairing the journal with its declared parameter space), the
  instance count, and per-task completed instance **indices**
  range-compressed to ``[[start, end], ...]`` spans.  A long sweep that
  completed instances 0..99999 journals as one two-integer range, not
  10^5 combos — O(completed ranges), never O(N_W).

Either way, recording one completion is an O(1) locked append to a
sidecar log — not a rewrite of the base — so journaling stays cheap for
long sweeps and safe when results arrive from a concurrent engine (base
writes stay atomic via tmp + rename).  ``load_state()`` folds the log
back into the base and understands both versions, so a v1 journal
resumes transparently under the streaming engine and vice versa.

**Group commit.**  By default every ``mark_complete`` opens the sidecar
log, appends, and flushes — one durable write per task, the right
default for standalone journal use.  For short-task sweeps that is two
syscall-heavy operations per completion, so the journal also supports a
*batched writer*: entries accumulate in memory against a single
long-lived file handle and flush as a group every ``flush_count``
entries or ``flush_interval`` seconds, dropping the bookkeeping cost to
amortized O(1/flush_count) opens+flushes per task.  The engine enables
it for the duration of a run via the ``group_commit()`` context manager,
which guarantees the buffer is flushed when the run returns *or raises*
— a crash mid-study loses nothing already handed to ``mark_complete``
at the last flush boundary, and nothing at all once ``run()`` exits.
Readers (``load_state``/``hosts``) see buffered entries immediately:
the log view is file contents plus the in-memory tail.

**Sharding.**  Under a many-lane or multi-process backend the single
buffered log handle becomes the completion stream's serialization
point, so the sidecar log can split into per-shard append segments
(``<name>.log`` plus ``<name>.log.s1`` …): ``mark_complete`` round-
robins across K independent group-commit writers and readers union
every segment on disk.  Compaction (``save``/``save_indexed``) folds
all segments into the base document and removes them, and
``load_state()`` globs segments rather than trusting the current shard
count — a crash mid-run with any shard layout resumes to the same
merged state as the single-handle world.  The engine picks a shard
count from the pool's parallelism (``run()``); standalone journals
default to one shard, which *is* the legacy layout.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Iterable, Iterator, Mapping

from .groupcommit import ShardedGroupCommit, iter_jsonl
from .locklint import make_lock


def compress_ranges(indices: Iterable[int]) -> list[list[int]]:
    """Fold an index set into sorted inclusive ``[start, end]`` spans."""
    out: list[list[int]] = []
    for i in sorted(set(indices)):
        if out and i == out[-1][1] + 1:
            out[-1][1] = i
        else:
            out.append([i, i])
    return out


def expand_ranges(ranges: Iterable[Iterable[int]]) -> Iterator[int]:
    """Inverse of ``compress_ranges``: yield every covered index."""
    for start, end in ranges:
        yield from range(int(start), int(end) + 1)


@dataclasses.dataclass
class JournalState:
    """Everything a resume needs, folded from base document + log."""

    version: int
    completed: set[str]                 # completed node ids (both versions)
    meta: dict[str, Any]
    hosts: dict[str, str]
    instances: list[dict[str, Any]] | None = None   # v1 base only
    completed_indices: dict[str, set[int]] | None = None  # v2: task → indices
    space_hash: str | None = None       # v2 only
    n_instances: int | None = None      # v2 only


class StudyJournal:
    def __init__(self, path: str | Path, flush_count: int = 1,
                 flush_interval: float | None = None,
                 shards: int = 1) -> None:
        """``flush_count``/``flush_interval`` configure the batched
        writer: buffered appends flush every N entries or T seconds,
        whichever comes first.  The default (1, None) keeps the legacy
        one-durable-write-per-completion behavior.  ``shards`` splits
        the sidecar log into per-shard append segments (see
        ``set_shards``); readers union them, so 1 — the default — is
        the legacy single-log layout."""
        self.path = Path(path)
        self.log_path = self.path.with_name(self.path.name + ".log")
        self._writer = ShardedGroupCommit(self.log_path, flush_count,
                                          flush_interval, shards)
        self._base_known = False    # base existence verified (skip stats)
        self._lock = make_lock("journal")

    def set_shards(self, shards: int) -> None:
        """Split (or re-merge) the sidecar log across ``shards`` append
        segments so a many-lane or multi-process run never serializes
        its completion stream on one buffered handle.  Safe mid-life:
        dropped segments flush first, and ``load_state()`` unions every
        segment on disk regardless of the current count."""
        with self._lock:
            self._writer.set_shards(shards)

    def exists(self) -> bool:
        return self.path.exists()

    # journals ride along when a bound runner is pickled to a process
    # pool; the lock is process-local state (the writer drops its own
    # handle and buffer — the parent keeps, and flushes, the originals)
    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = make_lock("journal")

    # -- group-commit machinery ------------------------------------------
    @property
    def n_appends(self) -> int:
        """Completions handed to ``mark_complete``."""
        return self._writer.n_appends

    @property
    def n_flushes(self) -> int:
        """Group flushes actually performed."""
        return self._writer.n_flushes

    def flush(self) -> None:
        """Force buffered completions to the sidecar log now."""
        with self._lock:
            self._writer.flush()

    def shard_counters(self) -> list[dict[str, Any]]:
        """Per-segment group-commit counters (telemetry snapshot)."""
        return self._writer.shard_counters()

    def close(self) -> None:
        """Flush and release the long-lived log handle."""
        with self._lock:
            self._writer.close()

    @contextmanager
    def group_commit(self, flush_count: int = 64,
                     flush_interval: float | None = 0.2):
        """Batch appends for the enclosed block.  On exit — normal or
        exceptional — the buffer is flushed, the handle closed, and the
        previous flush policy restored, so completions recorded before a
        crash are durable before the exception propagates."""
        with self._lock:
            prev = self._writer.set_policy(flush_count, flush_interval)
        try:
            yield self
        finally:
            with self._lock:
                self._writer.set_policy(*prev)
                self._writer.close()

    # -- base documents --------------------------------------------------
    def _replace_base(self, doc: Mapping[str, Any]) -> None:
        # buffered entries are folded into the base by the caller (the
        # completed sets passed in already include them) — drop them with
        # the log they would have landed in
        self._writer.drop_buffered()
        tmp = self.path.with_suffix(".tmp")
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp.write_text(json.dumps(doc, default=str,
                                  separators=(",", ":")))
        os.replace(tmp, self.path)
        self._base_known = True
        # every log segment's entries are folded into the base just wrote
        self._writer.unlink_segments()

    def _write_base(
        self,
        instances: list[dict[str, Any]],
        completed: set[str],
        meta: Mapping[str, Any] | None,
        hosts: Mapping[str, str] | None = None,
    ) -> None:
        self._replace_base({
            "version": 1,
            "instances": instances,
            "completed": sorted(completed),
            "meta": dict(meta or {}),
            "hosts": dict(hosts or {}),
        })

    def save(
        self,
        instances: list[dict[str, Any]],
        completed: set[str],
        meta: Mapping[str, Any] | None = None,
        hosts: Mapping[str, str] | None = None,
    ) -> None:
        """Write (compact) the full eager study state atomically as a v1
        document.  ``hosts`` maps task id → executing host."""
        with self._lock:
            self._write_base(instances, completed, meta, hosts)

    def save_indexed(
        self,
        space_hash: str,
        n_instances: int,
        completed: Mapping[str, Iterable[int]],
        meta: Mapping[str, Any] | None = None,
        hosts: Mapping[str, str] | None = None,
    ) -> None:
        """Write (compact) a v2 document: the space hash plus per-task
        completed instance indices, range-compressed — O(completed
        ranges) bytes, independent of N_W."""
        with self._lock:
            self._replace_base({
                "version": 2,
                "space": space_hash,
                "n_instances": int(n_instances),
                "completed": {task: compress_ranges(ix)
                              for task, ix in sorted(completed.items())},
                "meta": dict(meta or {}),
                "hosts": dict(hosts or {}),
            })

    # -- incremental appends ---------------------------------------------
    def mark_complete(self, task_id: str, host: str | None = None,
                      index: int | None = None,
                      task: str | None = None) -> None:
        """Incrementally record one completion: a locked append to the
        sidecar log, never a rewrite of the base document.  ``host``
        records where the task ran (remote provenance); ``index`` +
        ``task`` record the instance's space index for journal v2 (range
        compression happens at the next compaction).  Under the default
        flush policy the entry is durable on return; under group commit
        it is buffered and flushed with its batch."""
        entry: dict[str, Any] = {"completed": task_id}
        if host:
            entry["host"] = host
        if index is not None:
            entry["index"] = int(index)
        if task is not None:
            entry["task"] = task
        with self._lock:
            if not self._base_known:
                if not self.path.exists():
                    self._write_base([], set(), {})
                self._base_known = True
            self._writer.append(
                json.dumps(entry, separators=(",", ":")) + "\n")

    def set_pre_flush(self, fn: Any) -> None:
        """Durability-ordering hook: ``fn`` runs before any journal
        batch physically writes.  The study engine points it at the
        provenance DB's flush so a completion can never be durable in
        the journal before its record is durable in the DB — a crash
        may lose a completion (resume re-runs it) but never strand a
        journal entry whose record is gone."""
        with self._lock:
            self._writer.set_pre_flush(fn)

    # -- readers ----------------------------------------------------------
    def _log_entries(self) -> Iterator[dict[str, Any]]:
        # every on-disk segment first (union over shards — including
        # segments a previous run wrote with a different shard count),
        # then the unflushed in-memory tail — a reader holding the lock
        # sees every recorded completion.  Segments read through the
        # corruption-tolerant iterator: a torn tail (crash mid-write)
        # warns and drops that entry instead of refusing resume.
        for seg in self._writer.segment_paths():
            yield from iter_jsonl(seg, "journal")
        for line in self._writer.pending():
            yield json.loads(line)

    def load_state(self) -> JournalState:
        """Fold base document + sidecar log into a ``JournalState``,
        accepting either journal version (v1 read-compat)."""
        with self._lock:
            doc = json.loads(self.path.read_text())
            version = doc.get("version")
            if version not in (1, 2):
                raise ValueError(
                    f"unsupported journal version {version!r}")
            hosts: dict[str, str] = dict(doc.get("hosts") or {})
            if version == 1:
                state = JournalState(
                    version=1,
                    completed=set(doc["completed"]),
                    meta=doc.get("meta", {}),
                    hosts=hosts,
                    instances=doc["instances"],
                )
            else:
                state = JournalState(
                    version=2,
                    completed=set(),
                    meta=doc.get("meta", {}),
                    hosts=hosts,
                    completed_indices={
                        task: set(expand_ranges(ranges))
                        for task, ranges in (doc.get("completed") or {}).items()},
                    space_hash=doc.get("space"),
                    n_instances=doc.get("n_instances"),
                )
            for entry in self._log_entries():
                state.completed.add(entry["completed"])
                if entry.get("host"):
                    state.hosts[entry["completed"]] = entry["host"]
                if (state.completed_indices is not None
                        and entry.get("task") is not None
                        and entry.get("index") is not None):
                    state.completed_indices.setdefault(
                        entry["task"], set()).add(int(entry["index"]))
            return state

    def load(self) -> tuple[list[dict[str, Any]], set[str], dict[str, Any]]:
        """Legacy v1 reader: ``(instances, completed ids, meta)``.  A v2
        journal has no instance list — use ``load_state()`` (which also
        reads v1) anywhere a streaming journal may appear."""
        state = self.load_state()
        if state.version != 1:
            raise ValueError(
                "journal is v2 (indexed); use load_state() to read it")
        return state.instances or [], state.completed, state.meta

    def hosts(self) -> dict[str, str]:
        """Task id → executing host, folded from the base document and
        the sidecar log (remote-backend provenance)."""
        with self._lock:
            hosts: dict[str, str] = {}
            if self.path.exists():
                doc = json.loads(self.path.read_text())
                hosts.update(doc.get("hosts") or {})
            for entry in self._log_entries():
                if entry.get("host"):
                    hosts[entry["completed"]] = entry["host"]
            return hosts
