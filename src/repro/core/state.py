"""Study checkpoint/restart journal (paper §4.1).

"PaPaS provides checkpoint-restart functionality in case of fault or a
deliberate pause/stop operation.  A parameter study's state can be saved
in a workflow file and reloaded at a later time."

The journal is a JSON file: the study's expanded instance list plus the
set of completed instance ids.  `resume()` rebuilds exactly the pending
portion of the study.  Writes are atomic (tmp + rename) so a crash never
corrupts the journal.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Mapping


class StudyJournal:
    def __init__(self, path: str | Path) -> None:
        self.path = Path(path)

    def exists(self) -> bool:
        return self.path.exists()

    def save(
        self,
        instances: list[dict[str, Any]],
        completed: set[str],
        meta: Mapping[str, Any] | None = None,
    ) -> None:
        doc = {
            "version": 1,
            "instances": instances,
            "completed": sorted(completed),
            "meta": dict(meta or {}),
        }
        tmp = self.path.with_suffix(".tmp")
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp.write_text(json.dumps(doc, default=str))
        os.replace(tmp, self.path)

    def load(self) -> tuple[list[dict[str, Any]], set[str], dict[str, Any]]:
        doc = json.loads(self.path.read_text())
        if doc.get("version") != 1:
            raise ValueError(f"unsupported journal version {doc.get('version')!r}")
        return doc["instances"], set(doc["completed"]), doc.get("meta", {})

    def mark_complete(self, task_id: str) -> None:
        """Incrementally record completion (cheap append-style update)."""
        if self.path.exists():
            instances, completed, meta = self.load()
        else:
            instances, completed, meta = [], set(), {}
        completed.add(task_id)
        self.save(instances, completed, meta)
