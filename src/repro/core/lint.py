"""``papas lint`` — pre-flight static analysis for parameter studies.

A typo'd ``${...}`` reference, a dangling ``after:`` edge, or a
``baseline:`` outside the declared space only surfaces *mid-sweep*
otherwise — after hours of real compute on a 10^5-combination study.
This module proves the whole class of "this study can never succeed"
errors statically, before a single instance is rendered: every check
works on parameter *key sets* and index math (``sample_count()``), so
linting a 10^5-combo study costs the same as a 10-combo one.

Architecture: a flat registry of :class:`Rule` metadata (stable ids,
``E``/``W``/``I`` severity classes) plus a list of check functions, each
of which walks the :class:`~repro.core.wdl.StudySpec` through a
:class:`LintContext` and emits :class:`Finding`\\ s.  ``lint()`` runs
every check, applies the study's ``lint: suppress:`` list, and returns a
:class:`LintReport`.

Rule catalog (study pack):

== ======= ====================================================
id  sev    meaning
== ======= ====================================================
E001 error file does not parse (emitted by the CLI front end)
E101 error unresolvable ``${...}`` reference in a template
E102 error ambiguous ``${...}`` reference (several tails match)
E201 error ``after:`` names an unknown task
E202 error dependency cycle among tasks
E203 error task unreachable (depends on a cycle / unknown task)
E301 error parameterized infile has no producing outfile
E302 error infile's producer is not an ``after:`` ancestor
W303 warn  static infile path not found on disk
E401 error capture regex ``group:`` does not exist in pattern
E403 error capture reads ``outfile:<name>`` never declared
E501 error baseline key matches no parameter / captured metric
E502 error baseline value outside the declared parameter values
E503 error two tasks declare different ``baseline:`` points
E504 error parameter space cannot be constructed (sampling, fixed)
E505 error conflicting remote keywords across tasks
E506 error conflicting ``straggler_quantile`` across tasks
W601 warn  estimated sweep runtime exceeds the study budget
I601 info  sweep cost estimate (count × duration / slots)
W701 warn  retry backoff ceiling exceeds the task timeout
W802 warn  capture metric declared but consumed by nothing
E901 error engine lock acquisition-order cycle (locklint pack)
== ======= ====================================================

Suppression: a study opts out per rule id via its ``lint:`` block
(``suppress: [W601]``).  ``E001`` and the engine pack cannot be
suppressed from a study file.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Any, Callable, Iterable, Mapping

from .interpolate import _INTERP_RE, classify_reference
from .paramspace import ParameterSpace, from_task
from .results import BUILTIN_CAPTURES, KeyResolutionError, _canon, resolve_key
from .wdl import StudySpec, TaskSpec

__all__ = [
    "Finding",
    "LintReport",
    "Rule",
    "RULES",
    "findings_from_lock_report",
    "lint",
]

SEVERITIES = ("error", "warn", "info")

#: default cost-estimate budget (days) — override via ``lint:
#: max_runtime_days:`` in the study or ``lint(max_runtime_days=...)``.
DEFAULT_MAX_RUNTIME_DAYS = 30.0
#: default assumed concurrency for the cost estimate.
DEFAULT_SLOTS = 8


@dataclasses.dataclass(frozen=True)
class Rule:
    """Registry metadata for one diagnostic: a stable id, a severity
    class, and a one-line summary (the full message is per-finding)."""

    id: str
    severity: str
    summary: str


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: a rule id plus the location that triggered it."""

    rule: str
    severity: str
    message: str
    task: str | None = None
    keyword: str | None = None
    file: str | None = None
    line: int | None = None

    @property
    def keyword_path(self) -> str:
        """``task.keyword`` dotted path ('' when unlocated)."""
        return ".".join(p for p in (self.task, self.keyword) if p)

    def render(self) -> str:
        loc = []
        if self.file:
            loc.append(f"{self.file}:{self.line}" if self.line
                       else str(self.file))
        if self.keyword_path:
            loc.append(self.keyword_path)
        where = " ".join(loc)
        return (f"{self.severity.upper():5s} {self.rule} "
                f"{where + ': ' if where else ''}{self.message}")

    def as_dict(self) -> dict[str, Any]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


#: rule id → metadata.  Checks emit by id; severity lives here so a
#: rule's class can never drift between emit sites.
RULES: dict[str, Rule] = {r.id: r for r in (
    Rule("E001", "error", "file does not parse as WDL"),
    Rule("E101", "error", "unresolvable ${...} reference"),
    Rule("E102", "error", "ambiguous ${...} reference"),
    Rule("E201", "error", "after: names an unknown task"),
    Rule("E202", "error", "dependency cycle among tasks"),
    Rule("E203", "error", "task unreachable behind a cycle/unknown dep"),
    Rule("E301", "error", "parameterized infile has no producer"),
    Rule("E302", "error", "infile producer is not an after: ancestor"),
    Rule("W303", "warn", "static infile not found on disk"),
    Rule("E401", "error", "capture regex group does not exist"),
    Rule("E403", "error", "capture reads an undeclared outfile"),
    Rule("E501", "error", "baseline key matches nothing"),
    Rule("E502", "error", "baseline value outside declared values"),
    Rule("E503", "error", "conflicting baselines across tasks"),
    Rule("E504", "error", "parameter space cannot be constructed"),
    Rule("E505", "error", "conflicting remote keywords"),
    Rule("E506", "error", "conflicting straggler_quantile"),
    Rule("W601", "warn", "estimated runtime exceeds budget"),
    Rule("I601", "info", "sweep cost estimate"),
    Rule("W701", "warn", "retry backoff ceiling exceeds task timeout"),
    Rule("W802", "warn", "capture metric declared but never consumed"),
    Rule("E901", "error", "lock acquisition-order cycle"),
)}

#: the study rule pack: check functions run in order by ``lint()``.
CHECKS: list[Callable[["LintContext"], None]] = []


def check(fn: Callable[["LintContext"], None]
          ) -> Callable[["LintContext"], None]:
    CHECKS.append(fn)
    return fn


class LintContext:
    """Everything the rule pack needs, computed once per study.

    Per-task parameter mappings, their key-set scopes, the (lazily
    constructed, cached) global :class:`ParameterSpace`, duration
    priors, and the source line map for locating findings."""

    def __init__(self, spec: StudySpec, slots: int | None = None,
                 priors: Mapping[str, float] | None = None,
                 max_runtime_days: float | None = None) -> None:
        self.spec = spec
        lint_block = spec.lint or {}
        self.slots = int(slots if slots is not None
                         else lint_block.get("slots", DEFAULT_SLOTS))
        self.max_runtime_days = float(
            max_runtime_days if max_runtime_days is not None
            else lint_block.get("max_runtime_days",
                                DEFAULT_MAX_RUNTIME_DAYS))
        self.priors = dict(priors or {})
        self.findings: list[Finding] = []
        #: task → {param key → value list}
        self.params: dict[str, dict[str, list[Any]]] = {
            tname: t.parameters() for tname, t in spec.tasks.items()}
        #: task → parameter key set (scope for classify_reference)
        self.scopes: dict[str, set[str]] = {
            tname: set(p) for tname, p in self.params.items()}
        self._lines: Mapping[tuple, int] = \
            (spec.origin or {}).get("lines") or {}
        self._file: str | None = (spec.origin or {}).get("file")
        self._space: ParameterSpace | None = None
        self._space_err: Exception | None = None

    def space_or_err(self) -> tuple[ParameterSpace | None, Exception | None]:
        """The study-global namespaced space (cached), or the exception
        its construction raised — mirrors ``ParameterStudy.space()``."""
        if self._space is None and self._space_err is None:
            try:
                self._space = self._build_space()
            except Exception as e:
                self._space_err = e
        return self._space, self._space_err

    def _build_space(self) -> ParameterSpace:
        params: dict[str, list[Any]] = {}
        fixed: list[list[str]] = []
        sampling: dict[str, Any] | None = None
        sampling_owner: str | None = None
        for tname, task in self.spec.tasks.items():
            tspace = from_task(self.params[tname], task.fixed, task.sampling)
            for pname, values in tspace.params.items():
                params[f"{tname}/{pname}"] = values
            for group in tspace.fixed:
                fixed.append([f"{tname}/{p}" for p in group])
            if task.sampling:
                block = dict(task.sampling)
                if sampling is None:
                    sampling, sampling_owner = block, tname
                elif block != sampling:
                    raise ValueError(
                        f"conflicting sampling blocks: task "
                        f"{sampling_owner!r} declares {sampling!r} but "
                        f"task {tname!r} declares {block!r}")
        return ParameterSpace(params=params, fixed=fixed, sampling=sampling)

    def emit(self, rule_id: str, message: str, task: str | None = None,
             keyword: str | None = None) -> None:
        meta = RULES[rule_id]
        parts: list[str] = []
        if task:
            parts.append(task)
        if keyword:
            parts.extend(keyword.split("."))
        line = None
        for n in range(len(parts), 0, -1):
            line = self._lines.get(tuple(parts[:n]))
            if line is not None:
                break
        self.findings.append(Finding(
            rule=rule_id, severity=meta.severity, message=message,
            task=task, keyword=keyword, file=self._file, line=line))


@dataclasses.dataclass
class LintReport:
    """The outcome of one ``lint()`` run."""

    findings: list[Finding]
    suppressed: list[str] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warn"]

    @property
    def infos(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "info"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def render(self) -> str:
        lines = [f.render() for f in self.findings]
        n_e, n_w = len(self.errors), len(self.warnings)
        lines.append(f"{n_e} error(s), {n_w} warning(s), "
                     f"{len(self.infos)} info")
        if self.suppressed:
            lines.append(f"suppressed: {', '.join(self.suppressed)}")
        return "\n".join(lines)

    def as_dict(self) -> dict[str, Any]:
        return {"ok": self.ok,
                "errors": len(self.errors),
                "warnings": len(self.warnings),
                "suppressed": list(self.suppressed),
                "findings": [f.as_dict() for f in self.findings]}


# ---------------------------------------------------------------------------
# study rule pack
# ---------------------------------------------------------------------------

def _static_values(path: str, params: Mapping[str, list[Any]],
                   studies: Mapping[str, Mapping[str, list[Any]]] | None
                   ) -> list[Any] | None:
    """The value list a resolvable reference draws from (the static
    counterpart of ``resolve()``'s ok branches); None when unbound."""
    if path in params:
        return params[path]
    tails = [k for k in params if k.endswith(":" + path)]
    if len(tails) == 1:
        return params[tails[0]]
    head, _, rest = path.partition(":")
    if studies and head in studies and rest:
        other = studies[head]
        if rest in other:
            return other[rest]
        otails = [k for k in other if k.endswith(":" + rest)]
        if len(otails) == 1:
            return other[otails[0]]
    return None


def _check_template(ctx: LintContext, tname: str, text: str, keyword: str,
                    inter_task: bool) -> None:
    """Classify every ``${...}`` slot in one template, following nested
    references (a resolved value containing ``${...}``) the same way the
    render fixpoint does — but over key sets, never values-per-instance."""
    scope = ctx.scopes[tname]
    studies_scopes = ctx.scopes if inter_task else None
    studies_params = ctx.params if inter_task else None
    seen: set[str] = set()
    work = list(_INTERP_RE.findall(text))
    while work:
        path = work.pop()
        if path in seen:
            continue
        seen.add(path)
        status, detail = classify_reference(path, scope, studies_scopes)
        if status == "ok":
            values = _static_values(path, ctx.params[tname], studies_params)
            for v in values or ():
                if isinstance(v, str) and "${" in v:
                    work.extend(_INTERP_RE.findall(v))
            continue
        rid = "E101" if status == "unbound" else "E102"
        ctx.emit(rid,
                 f"reference ${{{path}}} cannot resolve: {detail}",
                 task=tname, keyword=keyword)


@check
def check_references(ctx: LintContext) -> None:
    """E101/E102 — every template's ``${...}`` slots must bind.

    Contexts and their runtime scope, mirrored exactly: the command
    renders with inter-task ``${task:...}`` lookup; infile/outfile name
    templates and ``capture: file:`` sources render against the combo
    alone (no ``studies`` — see ``staging.stage_inputs`` and
    ``CaptureSet._read_file``).  Environ values are never interpolated,
    so they are deliberately not checked."""
    for tname, task in ctx.spec.tasks.items():
        if task.command:
            _check_template(ctx, tname, task.command, "command",
                            inter_task=True)
        for fname, ftmpl in task.infiles.items():
            _check_template(ctx, tname, ftmpl, f"infiles.{fname}",
                            inter_task=False)
        for fname, ftmpl in task.outfiles.items():
            _check_template(ctx, tname, ftmpl, f"outfiles.{fname}",
                            inter_task=False)
        for mname, cap in task.capture.items():
            source = getattr(cap, "source", "stdout")
            if source.startswith("file:"):
                _check_template(ctx, tname, source[len("file:"):],
                                f"capture.{mname}.source",
                                inter_task=False)


@check
def check_dag(ctx: LintContext) -> None:
    """E201/E202/E203 — the task graph must be closed and acyclic."""
    names = set(ctx.spec.tasks)
    blocked: set[str] = set()
    for tname, task in ctx.spec.tasks.items():
        for dep in task.after:
            if dep not in names:
                ctx.emit("E201",
                         f"after: references unknown task {dep!r} "
                         f"(tasks: {', '.join(sorted(names))})",
                         task=tname, keyword="after")
                blocked.add(tname)
    # cycle detection over known edges (task level, not instance level:
    # every instance replicates the same sub-DAG)
    color: dict[str, int] = {}          # 0 unvisited / 1 on stack / 2 done
    cycle_members: set[str] = set()
    cycles: list[list[str]] = []

    def dfs(node: str, stack: list[str]) -> None:
        color[node] = 1
        stack.append(node)
        for dep in ctx.spec.tasks[node].after:
            if dep not in names:
                continue
            c = color.get(dep, 0)
            if c == 1:
                cyc = stack[stack.index(dep):]
                if not cycle_members.issuperset(cyc):
                    cycles.append(list(cyc))
                cycle_members.update(cyc)
            elif c == 0:
                dfs(dep, stack)
        stack.pop()
        color[node] = 2

    for tname in ctx.spec.tasks:
        if color.get(tname, 0) == 0:
            dfs(tname, [])
    for cyc in cycles:
        ctx.emit("E202",
                 f"dependency cycle: {' -> '.join(cyc + [cyc[0]])} — no "
                 f"instance of these tasks can ever start",
                 task=cyc[0], keyword="after")
    blocked |= cycle_members
    # propagate unreachability downstream of cycles / unknown deps
    downstream: dict[str, list[str]] = {}
    for tname, task in ctx.spec.tasks.items():
        for dep in task.after:
            if dep in names:
                downstream.setdefault(dep, []).append(tname)
    frontier = list(blocked)
    unreachable: set[str] = set()
    while frontier:
        for succ in downstream.get(frontier.pop(), ()):
            if succ not in blocked and succ not in unreachable:
                unreachable.add(succ)
                frontier.append(succ)
    for tname in sorted(unreachable):
        ctx.emit("E203",
                 f"task can never start: it depends (transitively) on "
                 f"a cycle or an unknown task",
                 task=tname, keyword="after")


@check
def check_dataflow(ctx: LintContext) -> None:
    """E301/E302/W303 — infiles must come from somewhere.

    A *parameterized* infile path (it has ``${...}`` slots) is expected
    to be produced by an upstream outfile — matching by logical name or
    by identical path template; no producer is E301 and a producer the
    consumer is not ordered after is E302.  A *static* infile is an
    external input: it only warns (W303) when absent on disk at lint
    time."""
    # consumer → transitive after-ancestors (known tasks only)
    names = set(ctx.spec.tasks)

    def ancestors(tname: str) -> set[str]:
        out: set[str] = set()
        stack = [d for d in ctx.spec.tasks[tname].after if d in names]
        while stack:
            dep = stack.pop()
            if dep not in out:
                out.add(dep)
                stack.extend(d for d in ctx.spec.tasks[dep].after
                             if d in names)
        return out

    for tname, task in ctx.spec.tasks.items():
        anc = ancestors(tname) if task.infiles else set()
        for fname, ftmpl in task.infiles.items():
            producers = [
                other for other, ot in ctx.spec.tasks.items()
                if other != tname
                and (fname in ot.outfiles
                     or ftmpl in ot.outfiles.values())]
            if producers:
                if not any(p in anc for p in producers):
                    ctx.emit(
                        "E302",
                        f"infile {fname!r} is produced by "
                        f"{sorted(producers)} but none is an after: "
                        f"ancestor of this task — staging may race "
                        f"production",
                        task=tname, keyword=f"infiles.{fname}")
                continue
            if "${" in ftmpl:
                ctx.emit(
                    "E301",
                    f"infile {fname!r} has a parameterized path "
                    f"{ftmpl!r} but no task declares a matching "
                    f"outfile (by name or identical template)",
                    task=tname, keyword=f"infiles.{fname}")
            elif not os.path.exists(ftmpl):
                ctx.emit(
                    "W303",
                    f"static infile {ftmpl!r} does not exist (external "
                    f"input expected on disk before the run)",
                    task=tname, keyword=f"infiles.{fname}")


@check
def check_captures(ctx: LintContext) -> None:
    """E401/E403 — capture extraction must be able to succeed."""
    for tname, task in ctx.spec.tasks.items():
        for mname, cap in task.capture.items():
            source = getattr(cap, "source", "stdout")
            if source.startswith("outfile:") \
                    and source[len("outfile:"):] not in task.outfiles:
                ctx.emit(
                    "E403",
                    f"capture {mname!r} reads {source!r} but the task "
                    f"declares no such outfile "
                    f"(declared: {sorted(task.outfiles) or 'none'})",
                    task=tname, keyword=f"capture.{mname}.source")
            pattern = getattr(cap, "pattern", None)
            group = getattr(cap, "group", None)
            if pattern is None or group is None:
                continue
            if isinstance(group, int):
                if group > pattern.groups:
                    ctx.emit(
                        "E401",
                        f"capture {mname!r} extracts group {group} but "
                        f"its regex has only {pattern.groups} group(s)",
                        task=tname, keyword=f"capture.{mname}.group")
            elif group not in pattern.groupindex:
                ctx.emit(
                    "E401",
                    f"capture {mname!r} extracts named group {group!r} "
                    f"but its regex defines "
                    f"{sorted(pattern.groupindex) or 'no named groups'}",
                    task=tname, keyword=f"capture.{mname}.group")


@check
def check_baseline(ctx: LintContext) -> None:
    """E501/E502/E503 — the speedup reference point must exist."""
    declared: tuple[str, dict[str, Any]] | None = None
    for tname, task in ctx.spec.tasks.items():
        if not task.baseline:
            continue
        if declared is not None and declared[1] != task.baseline:
            ctx.emit(
                "E503",
                f"conflicting baseline: task {declared[0]!r} declares "
                f"{declared[1]!r} but this task declares "
                f"{task.baseline!r} — a study has one reference point",
                task=tname, keyword="baseline")
        elif declared is None:
            declared = (tname, dict(task.baseline))
        params = ctx.params[tname]
        metric_names = set(task.capture) | set(BUILTIN_CAPTURES)
        for bkey, bval in task.baseline.items():
            # the aggregator resolves baseline keys against group-by
            # axes drawn from parameters *and* captured metrics
            if bkey in metric_names:
                continue   # reported-value axis: membership unknowable
            try:
                resolved = resolve_key(bkey, params)
            except KeyResolutionError as e:
                ctx.emit("E501", str(e), task=tname,
                         keyword=f"baseline.{bkey}")
                continue
            if resolved is None:
                if resolve_key(bkey, metric_names) is not None:
                    continue
                ctx.emit(
                    "E501",
                    f"baseline key {bkey!r} matches no parameter "
                    f"(declared: {sorted(params) or 'none'}) and no "
                    f"captured metric "
                    f"(declared: {sorted(metric_names)})",
                    task=tname, keyword=f"baseline.{bkey}")
                continue
            values = {_canon(v) for v in params[resolved]}
            if _canon(bval) not in values:
                shown = sorted(values, key=repr)
                preview = ", ".join(repr(v) for v in shown[:8])
                if len(shown) > 8:
                    preview += f", ... ({len(shown)} values)"
                ctx.emit(
                    "E502",
                    f"baseline {bkey!r}={bval!r} is not one of the "
                    f"declared values of {resolved!r}: [{preview}]",
                    task=tname, keyword=f"baseline.{bkey}")


@check
def check_space(ctx: LintContext) -> None:
    """E504/E505/E506 — global-singleton keywords must agree.

    ``sampling`` applies to the global combination space and the
    pool/straggler policy is built once per study, so divergent per-task
    declarations can never be honored (same checks ``ParameterStudy``
    runs at run time, surfaced before admission)."""
    _space, err = ctx.space_or_err()
    if err is not None:
        ctx.emit("E504", f"parameter space cannot be constructed: {err}")
    merged: dict[str, tuple[str, Any]] = {}
    for tname, task in ctx.spec.tasks.items():
        declared: dict[str, Any] = {
            "hosts": task.hosts or None, "batch": task.batch,
            "nnodes": task.nnodes, "ppnode": task.ppnode}
        for key, val in declared.items():
            if val is None:
                continue
            if key not in merged:
                merged[key] = (tname, val)
            elif merged[key][1] != val:
                ctx.emit(
                    "E505",
                    f"conflicting remote keyword {key!r}: task "
                    f"{merged[key][0]!r} declares {merged[key][1]!r} "
                    f"but this task declares {val!r}",
                    task=tname, keyword=key)
        q = task.straggler_quantile
        if q is not None:
            if "straggler_quantile" not in merged:
                merged["straggler_quantile"] = (tname, q)
            elif merged["straggler_quantile"][1] != q:
                ctx.emit(
                    "E506",
                    f"conflicting straggler_quantile: task "
                    f"{merged['straggler_quantile'][0]!r} declares "
                    f"{merged['straggler_quantile'][1]!r} but this "
                    f"task declares {q!r}",
                    task=tname, keyword="straggler_quantile")


def _fmt_duration(seconds: float) -> str:
    if seconds >= 2 * 86400:
        return f"{seconds / 86400:.1f} days"
    if seconds >= 2 * 3600:
        return f"{seconds / 3600:.1f} hours"
    if seconds >= 120:
        return f"{seconds / 60:.1f} minutes"
    return f"{seconds:.1f} s"


@check
def check_cost(ctx: LintContext) -> None:
    """W601/I601 — the sweep must be feasible before it is admitted.

    ``sample_count()`` is mixed-radix index math (O(params), never
    O(instances)); per-task duration priors come from observed medians
    (``priors``) or, failing that, the declared ``timeout:`` — an upper
    bound, which is the right direction for an admission gate.  Tasks
    with neither contribute nothing (and the estimate says so)."""
    space, err = ctx.space_or_err()
    if err is not None:
        return
    count = space.sample_count()
    per_instance = 0.0
    unpriced: list[str] = []
    for tname, task in ctx.spec.tasks.items():
        dur = ctx.priors.get(tname)
        if dur is None:
            dur = task.timeout
        if dur is None:
            unpriced.append(tname)
        else:
            per_instance += float(dur)
    if per_instance <= 0:
        return
    total = count * per_instance
    wall = total / max(1, ctx.slots)
    days = wall / 86400.0
    detail = (f"{count} instance(s) x {_fmt_duration(per_instance)} "
              f"/ {ctx.slots} slot(s) ~= {_fmt_duration(wall)}")
    if unpriced:
        detail += (f" (tasks without timeout/prior excluded: "
                   f"{', '.join(sorted(unpriced))})")
    if days > ctx.max_runtime_days:
        ctx.emit("W601",
                 f"estimated sweep runtime {days:.1f} days at "
                 f"{ctx.slots} slots exceeds the "
                 f"{ctx.max_runtime_days:g}-day budget: {detail}")
    else:
        ctx.emit("I601", f"estimated sweep cost: {detail}")


@check
def check_retry(ctx: LintContext) -> None:
    """W701 — the retry backoff must not outlive the task it retries.

    The worst-case single backoff delay (``RetryPolicy.ceiling``: the
    last exponential step, jitter included) is compared against the
    task's declared ``timeout:`` — a policy that waits longer between
    attempts than the task is even allowed to run idles slots for no
    recovery benefit, and usually means ``base:`` was given in the
    wrong unit."""
    from .scheduler import RetryPolicy
    for tname, task in ctx.spec.tasks.items():
        if not task.retry or task.timeout is None:
            continue
        try:
            policy = RetryPolicy.from_any(task.retry)
        except ValueError:
            continue         # shape errors are the parser's to report
        ceil = policy.ceiling()
        timeout = float(task.timeout)
        if ceil > timeout:
            ctx.emit(
                "W701",
                f"worst-case retry backoff {_fmt_duration(ceil)} "
                f"(max={policy.retries(1)}, {policy.backoff}, "
                f"base={policy.base:g}s) exceeds the task timeout "
                f"{_fmt_duration(timeout)} — retries would idle the "
                f"slot longer than the task may run",
                task=tname, keyword="retry")


@check
def check_dead_captures(ctx: LintContext) -> None:
    """W802 — a declared capture should be consumed by something.

    A ``capture:`` metric that is not ``required:``, is not a builtin
    passthrough, and is referenced by no ``baseline:`` key is extracted
    on every instance and then dropped on the floor — usually a
    leftover from an earlier report shape, sometimes a typo'd name on
    the consuming side.  Report axes chosen at the CLI (``--group-by``,
    ``--metric``) are invisible statically, so this is a warning, never
    an error."""
    consumed: set[str] = set()
    for task in ctx.spec.tasks.values():
        consumed.update(task.baseline)
    for tname, task in ctx.spec.tasks.items():
        for mname, cap in task.capture.items():
            if getattr(cap, "required", False):
                continue   # a contract with the run: missing = failure
            if getattr(cap, "kind", None) == "builtin":
                continue   # zero extraction cost — nothing is wasted
            used = False
            for bkey in consumed:
                try:
                    if resolve_key(bkey, {mname}) is not None:
                        used = True
                        break
                except KeyResolutionError:
                    used = True   # ambiguous — it may be this metric
                    break
            if not used:
                ctx.emit(
                    "W802",
                    f"capture {mname!r} is extracted on every instance "
                    f"but consumed by nothing in the study file (no "
                    f"baseline: reference, not required:) — dead "
                    f"metric, or a typo on the consuming side",
                    task=tname, keyword=f"capture.{mname}")


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint(spec: StudySpec, *, slots: int | None = None,
         priors: Mapping[str, float] | None = None,
         max_runtime_days: float | None = None) -> LintReport:
    """Run the study rule pack over a parsed spec.

    ``slots`` and ``max_runtime_days`` parameterize the cost estimator
    (explicit argument > study ``lint:`` block > defaults); ``priors``
    maps task names to observed median durations in seconds (see
    ``ParameterStudy.lint`` for the variant that loads them from the
    study's own provenance records).  Suppressed rule ids (the study's
    ``lint: suppress:`` list) are dropped from the report and recorded
    in ``report.suppressed``.
    """
    ctx = LintContext(spec, slots=slots, priors=priors,
                      max_runtime_days=max_runtime_days)
    for fn in CHECKS:
        fn(ctx)
    suppress = {str(s) for s in (spec.lint or {}).get("suppress", ())}
    findings = [f for f in ctx.findings if f.rule not in suppress]
    suppressed = sorted({f.rule for f in ctx.findings
                         if f.rule in suppress})
    return LintReport(findings=findings, suppressed=suppressed)


def findings_from_lock_report(report: Mapping[str, Any]) -> LintReport:
    """The engine rule pack's verdict: convert a
    :mod:`repro.core.locklint` auditor report into E901 findings (one
    per acquisition-order cycle), so CI renders engine and study
    diagnostics through one formatter."""
    findings = [
        Finding(rule="E901", severity="error",
                message=(f"lock acquisition-order cycle "
                         f"{' -> '.join(list(cyc) + [cyc[0]])} — "
                         f"potential deadlock"))
        for cyc in report.get("cycles", ())]
    if not findings:
        locks = report.get("locks", [])
        findings.append(Finding(
            rule="I601", severity="info",
            message=(f"acquisition-order graph over "
                     f"{len(locks)} lock(s) "
                     f"({', '.join(locks) or 'none'}), "
                     f"{report.get('n_acquisitions', 0)} acquisition(s), "
                     f"{len(report.get('edges', []))} edge(s): "
                     f"no cycles")))
    return LintReport(findings=findings)
