"""``${...}`` value interpolation (paper §5) — with compiled templates.

Supports intra-task references (``${keyword}``, ``${keyword:value}``) and
inter-task references (``${task:keyword}``, ``${task:keyword:value}``),
plus ``substitute`` partial-file-content rewriting where the keyword is a
Python regular expression and the value list provides replacements.

Two rendering paths produce byte-identical output:

* ``interpolate()`` — the reference implementation: regex substitution
  with a small fixpoint loop (one level of nested results).  O(len(text))
  regex work per instance.
* ``CompiledTemplate`` / ``compile_template()`` — the throughput path: a
  template is parsed **once** into alternating static segments and
  parameter slots, so rendering one instance is a list join over resolved
  slot values instead of a regex pass.  A 10^5-combination sweep pays the
  parse once per distinct template, not once per instance (parasweep's
  template pre-compilation, applied to the paper's §5 syntax).  The rare
  nested case — a resolved value that itself contains ``${...}`` — falls
  back to the reference fixpoint loop for the remaining passes, keeping
  the two paths byte-identical.
"""
from __future__ import annotations

import re
from functools import lru_cache
from typing import Any, Mapping

_INTERP_RE = re.compile(r"\$\{([^}]+)\}")


class InterpolationError(KeyError):
    pass


def _fmt(v: Any) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return str(v)


def resolve(
    path: str,
    combo: Mapping[str, Any],
    task: str | None = None,
    studies: Mapping[str, Mapping[str, Any]] | None = None,
) -> Any:
    """Resolve one ``${path}`` reference.

    Lookup order (paper: both entry levels, intra- then inter-task):
      1. exact key in the current combination (``args:size``),
      2. bare user keyword (``size`` matching unique ``*:size``),
      3. task-qualified (``other_task:args:size``) against ``studies``.
    """
    if path in combo:
        return combo[path]
    tails = [k for k in combo if k.endswith(":" + path)]
    if len(tails) == 1:
        return combo[tails[0]]
    if studies:
        head, _, rest = path.partition(":")
        if head in studies and rest:
            other = studies[head]
            if rest in other:
                return other[rest]
            tails = [k for k in other if k.endswith(":" + rest)]
            if len(tails) == 1:
                return other[tails[0]]
    raise InterpolationError(f"cannot resolve ${{{path}}} (task={task!r})")


def classify_reference(
    path: str,
    scope: "set[str] | frozenset[str]",
    studies_scopes: Mapping[str, "set[str] | frozenset[str]"] | None = None,
) -> tuple[str, str]:
    """Statically classify one ``${path}`` reference against parameter
    *key sets* instead of a concrete combination.

    Mirrors :func:`resolve` exactly — same lookup order, same tie
    rules — so ``("ok", ...)`` here means ``resolve()`` succeeds for
    every instance, and anything else means it raises
    :class:`InterpolationError` for every instance.  This is what lets
    ``papas lint`` prove a 10^5-combination study renders without
    materializing a single combo.

    Returns ``(status, detail)`` with status ``"ok"``, ``"unbound"``,
    or ``"ambiguous"`` (both non-ok states raise at runtime; the split
    is diagnostic).
    """
    if path in scope:
        return "ok", ""
    tails = [k for k in scope if k.endswith(":" + path)]
    if len(tails) == 1:
        return "ok", ""
    head, _, rest = path.partition(":")
    if studies_scopes and head in studies_scopes and rest:
        other = studies_scopes[head]
        if rest in other:
            return "ok", ""
        otails = [k for k in other if k.endswith(":" + rest)]
        if len(otails) == 1:
            return "ok", ""
        if len(otails) > 1:
            return ("ambiguous",
                    f"{rest!r} matches {sorted(otails)} in task {head!r}")
        if len(tails) <= 1:
            return ("unbound",
                    f"task {head!r} declares no parameter {rest!r} "
                    f"(declared: {sorted(other) or 'none'})")
    if len(tails) > 1:
        return ("ambiguous",
                f"{path!r} matches multiple parameters {sorted(tails)}")
    detail = (f"no parameter of the task matches "
              f"(declared: {sorted(scope) or 'none'})")
    if rest and studies_scopes is not None and head not in studies_scopes:
        detail += f"; no task named {head!r} for an inter-task reference"
    return "unbound", detail


def interpolate(
    text: str,
    combo: Mapping[str, Any],
    task: str | None = None,
    studies: Mapping[str, Mapping[str, Any]] | None = None,
) -> str:
    """Expand every ``${...}`` in ``text`` against a parameter combination."""

    def _sub(m: re.Match[str]) -> str:
        return _fmt(resolve(m.group(1), combo, task, studies))

    prev, cur = None, text
    # allow one level of nested results (a value containing ${...})
    for _ in range(4):
        if prev == cur:
            break
        prev, cur = cur, _INTERP_RE.sub(_sub, cur)
    return cur


class CompiledTemplate:
    """A ``${...}`` template parsed once into static segments + slots.

    ``render`` resolves each slot against a combination and joins — no
    regex work on the hot path.  Output is byte-identical to
    ``interpolate(text, ...)``: the first substitution pass is performed
    by construction (the segment list mirrors ``_INTERP_RE`` matches
    exactly), and if resolved values re-introduce ``${...}`` the
    remaining fixpoint passes run through the same regex machinery the
    reference path uses.
    """

    __slots__ = ("text", "paths", "_parts")

    def __init__(self, text: str) -> None:
        self.text = text
        parts: list[tuple[bool, str]] = []   # (is_slot, literal-or-path)
        paths: list[str] = []
        pos = 0
        for m in _INTERP_RE.finditer(text):
            if m.start() > pos:
                parts.append((False, text[pos:m.start()]))
            parts.append((True, m.group(1)))
            paths.append(m.group(1))
            pos = m.end()
        if pos < len(text):
            parts.append((False, text[pos:]))
        self._parts = tuple(parts)
        #: every slot path, in order — lets callers reason about which
        #: parameters (and which inter-task references) a template needs
        self.paths = tuple(paths)

    @property
    def static(self) -> bool:
        """True when the template has no slots (render is free)."""
        return not self.paths

    def render(
        self,
        combo: Mapping[str, Any],
        task: str | None = None,
        studies: Mapping[str, Mapping[str, Any]] | None = None,
    ) -> str:
        if not self.paths:
            return self.text
        out: list[str] = []
        for is_slot, s in self._parts:
            out.append(_fmt(resolve(s, combo, task, studies))
                       if is_slot else s)
        cur = "".join(out)
        if "${" in cur:
            # a resolved value contained ${...}: finish with the same
            # fixpoint passes interpolate() applies after its first
            def _sub(m: re.Match[str]) -> str:
                return _fmt(resolve(m.group(1), combo, task, studies))

            prev = cur
            for _ in range(3):
                cur = _INTERP_RE.sub(_sub, cur)
                if cur == prev:
                    break
                prev = cur
        return cur


@lru_cache(maxsize=4096)
def compile_template(text: str) -> CompiledTemplate:
    """Parse-once cache: the same template text (a task's command, an
    environ value, a file template) compiles exactly once per process."""
    return CompiledTemplate(text)


class CompiledEnviron:
    """Pre-resolved ``environ`` key pairs for one task: per-instance
    rendering is a dict build over precomputed ``environ:VAR`` lookup
    keys — byte-identical to ``render_environ``."""

    __slots__ = ("_pairs",)

    def __init__(self, environ_keys: "tuple[str, ...] | Mapping[str, Any]"
                 ) -> None:
        self._pairs = tuple((var, f"environ:{var}") for var in environ_keys)

    def render(self, combo: Mapping[str, Any]) -> dict[str, str]:
        env: dict[str, str] = {}
        for var, key in self._pairs:
            if key in combo:
                env[var] = _fmt(combo[key])
        return env


@lru_cache(maxsize=1024)
def compile_environ(environ_keys: tuple[str, ...]) -> CompiledEnviron:
    """Parse-once cache for environ stamping, keyed by the variable
    name tuple."""
    return CompiledEnviron(environ_keys)


def substitute_content(
    content: str, rules: Mapping[str, Any]
) -> str:
    """Apply ``substitute`` rules to file content: each keyword is a
    Python regex, each value the chosen replacement for this instance."""
    out = content
    for pattern, replacement in rules.items():
        out = re.sub(pattern, _fmt(replacement), out)
    return out


def render_command(
    command: str,
    combo: Mapping[str, Any],
    task: str | None = None,
    studies: Mapping[str, Mapping[str, Any]] | None = None,
) -> str:
    """Render a task's command line for one workflow instance."""
    return interpolate(command, combo, task, studies)


def render_environ(
    environ_keys: Mapping[str, Any],
    combo: Mapping[str, Any],
) -> dict[str, str]:
    """Materialize the per-instance environment variable assignment."""
    env: dict[str, str] = {}
    for var in environ_keys:
        key = f"environ:{var}"
        if key in combo:
            env[var] = _fmt(combo[key])
    return env
