"""``${...}`` value interpolation (paper §5).

Supports intra-task references (``${keyword}``, ``${keyword:value}``) and
inter-task references (``${task:keyword}``, ``${task:keyword:value}``),
plus ``substitute`` partial-file-content rewriting where the keyword is a
Python regular expression and the value list provides replacements.
"""
from __future__ import annotations

import re
from typing import Any, Mapping

_INTERP_RE = re.compile(r"\$\{([^}]+)\}")


class InterpolationError(KeyError):
    pass


def _fmt(v: Any) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return str(v)


def resolve(
    path: str,
    combo: Mapping[str, Any],
    task: str | None = None,
    studies: Mapping[str, Mapping[str, Any]] | None = None,
) -> Any:
    """Resolve one ``${path}`` reference.

    Lookup order (paper: both entry levels, intra- then inter-task):
      1. exact key in the current combination (``args:size``),
      2. bare user keyword (``size`` matching unique ``*:size``),
      3. task-qualified (``other_task:args:size``) against ``studies``.
    """
    if path in combo:
        return combo[path]
    tails = [k for k in combo if k.endswith(":" + path)]
    if len(tails) == 1:
        return combo[tails[0]]
    if studies:
        head, _, rest = path.partition(":")
        if head in studies and rest:
            other = studies[head]
            if rest in other:
                return other[rest]
            tails = [k for k in other if k.endswith(":" + rest)]
            if len(tails) == 1:
                return other[tails[0]]
    raise InterpolationError(f"cannot resolve ${{{path}}} (task={task!r})")


def interpolate(
    text: str,
    combo: Mapping[str, Any],
    task: str | None = None,
    studies: Mapping[str, Mapping[str, Any]] | None = None,
) -> str:
    """Expand every ``${...}`` in ``text`` against a parameter combination."""

    def _sub(m: re.Match[str]) -> str:
        return _fmt(resolve(m.group(1), combo, task, studies))

    prev, cur = None, text
    # allow one level of nested results (a value containing ${...})
    for _ in range(4):
        if prev == cur:
            break
        prev, cur = cur, _INTERP_RE.sub(_sub, cur)
    return cur


def substitute_content(
    content: str, rules: Mapping[str, Any]
) -> str:
    """Apply ``substitute`` rules to file content: each keyword is a
    Python regex, each value the chosen replacement for this instance."""
    out = content
    for pattern, replacement in rules.items():
        out = re.sub(pattern, _fmt(replacement), out)
    return out


def render_command(
    command: str,
    combo: Mapping[str, Any],
    task: str | None = None,
    studies: Mapping[str, Mapping[str, Any]] | None = None,
) -> str:
    """Render a task's command line for one workflow instance."""
    return interpolate(command, combo, task, studies)


def render_environ(
    environ_keys: Mapping[str, Any],
    combo: Mapping[str, Any],
) -> dict[str, str]:
    """Materialize the per-instance environment variable assignment."""
    env: dict[str, str] = {}
    for var in environ_keys:
        key = f"environ:{var}"
        if key in combo:
            env[var] = _fmt(combo[key])
    return env
