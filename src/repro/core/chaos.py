"""Deterministic, seeded fault injection over the engine's backend seams.

A study that only ever runs on a quiet laptop never exercises the
recovery paths the paper promises for multi-tenant clusters (§4.3, §9):
scheduler retries, SSH host quarantine + probation, lane respawn,
journal-v2 crash resume.  This module makes those paths *drivable*: a
``FaultPlan`` is an ordered list of addressable ``FaultEvent``\\ s, each
naming a seam, a trigger count, and a firing budget, and a
``ChaosController`` built from the plan answers the seams' questions
("should this lane die now?", "is this host reachable?") fully
deterministically — same plan, same study, same faults, every run.

Seams (all pre-existing; chaos only *answers*, never reaches in):

========= =============================================================
kind      injection point
========= =============================================================
``kill_lane``        ``executors.LaneWorkerPool._pump`` — SIGKILL the
                     lane's shell after *after* completed frames; the
                     pool's own death path harvests, respawns, and the
                     scheduler retries the charged head.
``fail_host``        ``remote.LocalTransport.start`` — raise
                     ``TransportError`` for the named host, feeding
                     ``SSHWorkerPool`` quarantine + probation.
``hang_host``        ``remote.LocalTransport.start`` — sleep ``delay``
                     seconds before dispatch, tripping task timeouts.
``lose_job``         ``remote.LocalSubmitter.submit`` — accept the
                     script but never spawn it; the batch deadline
                     expires and the scheduler retries.
``dup_job``          ``remote.LocalSubmitter.submit`` — spawn the
                     rendered script twice; completion handling must
                     stay idempotent.
``sigkill``          ``study._on_result`` — SIGKILL *this* process
                     after *after* recorded completions; resume must
                     replay to the exact pre-crash record set.
``truncate_segment`` applied to files (not a live seam): tear the tail
                     of a sharded ``*.s<k>`` append segment, the shape
                     a crash mid-``write()`` leaves behind.
========= =============================================================

Zero overhead when disabled — the same contract as ``locklint``'s
``make_lock``: pools capture ``chaos.current()`` at construction (one
``None`` attribute), transports consult it per dispatch (never the hot
frame path).  With no plan armed, ``current()`` is ``None`` and every
seam costs one identity check.

Arming: pass ``run(chaos=plan_or_path)``, ``--chaos plan.yaml`` on the
launchers, or set ``PAPAS_CHAOS=plan.yaml`` in the environment (checked
once, lazily).  Every fired fault lands in the controller's
``FaultLedger``; ``ParameterStudy`` attaches it to ``study.json`` so a
degraded run carries its own forensics.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import signal
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Iterable, Iterator, Mapping

from . import telemetry as _telemetry

__all__ = ["FAULT_KINDS", "FaultEvent", "FaultPlan", "FaultLedger",
           "ChaosController", "current", "install", "activated",
           "truncate_tail", "record_fingerprint"]

FAULT_KINDS = ("kill_lane", "fail_host", "hang_host", "lose_job",
               "dup_job", "sigkill", "truncate_segment")


@dataclasses.dataclass
class FaultEvent:
    """One addressable fault: fire ``times`` times once the seam's
    trigger counter passes ``after``.

    ``after`` counts seam-specific units: completed frames per lane
    (``kill_lane``), dispatches per host (``fail_host``/``hang_host``),
    submitted jobs (``lose_job``/``dup_job``), recorded completions
    (``sigkill``).  ``lane``/``host`` of ``None`` match any target.
    A bounded ``times`` is what makes probation observable: a host that
    fails twice and then answers its probe has recovered."""

    kind: str
    after: int = 0
    times: int = 1
    lane: int | None = None
    host: str | None = None
    delay: float = 0.25          # hang_host: seconds to stall dispatch
    glob: str = "*.s*"           # truncate_segment: file pattern
    nbytes: int | None = None    # truncate_segment: bytes to tear off

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} "
                f"(valid: {', '.join(FAULT_KINDS)})")
        if self.after < 0 or self.times < 1:
            raise ValueError(
                f"fault {self.kind}: after must be >= 0 and times >= 1")

    def to_dict(self) -> dict[str, Any]:
        out: dict[str, Any] = {"kind": self.kind}
        for f in dataclasses.fields(self):
            if f.name == "kind":
                continue
            v = getattr(self, f.name)
            if v != f.default:
                out[f.name] = v
        return out


class FaultLedger:
    """Thread-safe record of every fault actually fired — the run's
    forensics, attached to ``study.json`` when the study degrades."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: list[dict[str, Any]] = []

    def record(self, kind: str, target: str, at: int) -> None:
        with self._lock:
            self._entries.append(
                {"n": len(self._entries) + 1, "fault": kind,
                 "target": target, "at": at})
        tel = _telemetry.current()
        if tel is not None:
            # chaos firings surface in the trace as instant events on a
            # dedicated track, and as a labeled counter family
            tel.trace.instant("chaos", f"{kind}:{target}",
                              time.monotonic(), cat="chaos",
                              args={"at": at})
            tel.metrics.counter("papas_faults_total", kind=kind).inc()

    def as_list(self) -> list[dict[str, Any]]:
        with self._lock:
            return [dict(e) for e in self._entries]

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


@dataclasses.dataclass
class FaultPlan:
    """A seeded, ordered set of fault events.

    Load one from YAML (``--chaos plan.yaml``)::

        name: lane-kill
        seed: 7
        events:
          - kind: kill_lane
            lane: 0
            after: 3
            times: 2

    or build one in code and pass it to ``ParameterStudy.run(chaos=…)``.
    ``seed`` drives nothing at injection time (events are exhaustively
    deterministic); it names the plan for ``generate()`` and the ledger.
    """

    events: list[FaultEvent] = dataclasses.field(default_factory=list)
    seed: int = 0
    name: str = ""

    @classmethod
    def from_dict(cls, doc: Any) -> "FaultPlan":
        if isinstance(doc, list):
            doc = {"events": doc}
        if not isinstance(doc, Mapping):
            raise ValueError("fault plan must be a mapping or a list "
                             "of events")
        events = []
        for i, ev in enumerate(doc.get("events") or []):
            if not isinstance(ev, Mapping):
                raise ValueError(f"fault plan event #{i + 1}: expected "
                                 f"a mapping, got {type(ev).__name__}")
            known = {f.name for f in dataclasses.fields(FaultEvent)}
            bad = sorted(set(ev) - known)
            if bad:
                raise ValueError(f"fault plan event #{i + 1}: unknown "
                                 f"key(s) {', '.join(bad)}")
            events.append(FaultEvent(**dict(ev)))
        return cls(events=events, seed=int(doc.get("seed", 0)),
                   name=str(doc.get("name", "")))

    @classmethod
    def load(cls, path: str | Path) -> "FaultPlan":
        import yaml
        doc = yaml.safe_load(Path(path).read_text())
        plan = cls.from_dict(doc)
        if not plan.name:
            plan.name = Path(path).stem
        return plan

    @classmethod
    def generate(cls, seed: int, lanes: int = 2,
                 hosts: Iterable[str] = (),
                 max_events: int = 3) -> "FaultPlan":
        """A random-but-reproducible plan for property tests: any two
        calls with the same arguments yield the identical plan."""
        rng = random.Random(seed)
        hosts = list(hosts)
        kinds = ["kill_lane"] if lanes else []
        if hosts:
            kinds += ["fail_host", "hang_host"]
        events = []
        for _ in range(rng.randint(1, max(1, max_events))):
            kind = rng.choice(kinds)
            if kind == "kill_lane":
                events.append(FaultEvent(
                    "kill_lane", lane=rng.randrange(lanes),
                    after=rng.randint(1, 5), times=rng.randint(1, 2)))
            elif kind == "fail_host":
                events.append(FaultEvent(
                    "fail_host", host=rng.choice(hosts),
                    after=rng.randint(0, 4), times=rng.randint(1, 2)))
            else:
                events.append(FaultEvent(
                    "hang_host", host=rng.choice(hosts),
                    after=rng.randint(0, 4), delay=0.02))
        return cls(events=events, seed=seed, name=f"generated-{seed}")

    def to_dict(self) -> dict[str, Any]:
        return {"name": self.name, "seed": self.seed,
                "events": [e.to_dict() for e in self.events]}

    def controller(self) -> "ChaosController":
        return ChaosController(self)


class ChaosController:
    """Answers the seams' questions for one plan, counting triggers and
    firing each event at most ``times`` times.  All seam methods are
    thread-safe (the lane mux, SSH worker threads, and the event loop
    all consult the same controller)."""

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self.ledger = FaultLedger()
        self._lock = threading.Lock()
        self._fired = [0] * len(plan.events)
        self._frames: dict[int, int] = {}      # lane idx → frames seen
        self._dispatches: dict[str, int] = {}  # host → dispatches seen
        self._jobs = 0                         # batch submissions seen
        self._records = 0                      # completions recorded

    def _match(self, kinds: tuple[str, ...], count: int,
               field: str | None = None,
               target: Any = None) -> FaultEvent | None:
        """First unexhausted event of a kind in ``kinds`` whose address
        matches ``target`` and whose trigger ``after`` has passed."""
        for i, ev in enumerate(self.plan.events):
            if ev.kind not in kinds or self._fired[i] >= ev.times:
                continue
            if field is not None:
                addr = getattr(ev, field)
                if addr is not None and addr != target:
                    continue
            if count > ev.after:
                self._fired[i] += 1
                return ev
        return None

    # -- seams -------------------------------------------------------------
    def lane_frame(self, lane: int) -> bool:
        """LaneWorkerPool._pump: one completed frame on ``lane``.
        True → kill this lane's worker now."""
        with self._lock:
            n = self._frames.get(lane, 0) + 1
            self._frames[lane] = n
            ev = self._match(("kill_lane",), n, "lane", lane)
            if ev is not None:
                self.ledger.record("kill_lane", f"lane{lane}", n)
                return True
        return False

    def host_action(self, host: str) -> tuple[str, float] | None:
        """LocalTransport.start: one dispatch bound for ``host``.
        Returns ``("fail_host", 0)`` (raise TransportError),
        ``("hang_host", delay)`` (stall), or None."""
        with self._lock:
            n = self._dispatches.get(host, 0) + 1
            self._dispatches[host] = n
            ev = self._match(("fail_host", "hang_host"), n, "host", host)
            if ev is not None:
                self.ledger.record(ev.kind, host, n)
                return (ev.kind, ev.delay)
        return None

    def job_action(self) -> str | None:
        """LocalSubmitter.submit: one batch submission.  Returns
        ``"lose_job"`` (never spawn), ``"dup_job"`` (spawn twice), or
        None."""
        with self._lock:
            self._jobs += 1
            ev = self._match(("lose_job", "dup_job"), self._jobs)
            if ev is not None:
                self.ledger.record(ev.kind, f"job{self._jobs}",
                                   self._jobs)
                return ev.kind
        return None

    def on_record(self) -> None:
        """study._on_result: one completion recorded.  A matching
        ``sigkill`` event kills this process dead — no cleanup, no
        flush — exactly the crash journal resume must survive."""
        with self._lock:
            self._records += 1
            ev = self._match(("sigkill",), self._records)
        if ev is not None:
            os.kill(os.getpid(), signal.SIGKILL)

    def apply_file_faults(self, root: str | Path) -> list[Path]:
        """Fire every pending ``truncate_segment`` event against files
        under ``root`` (deterministic pick among glob matches).  Called
        by the harness after a crash, before resume — a live process
        never tears its own files."""
        root = Path(root)
        torn: list[Path] = []
        with self._lock:
            for i, ev in enumerate(self.plan.events):
                if (ev.kind != "truncate_segment"
                        or self._fired[i] >= ev.times):
                    continue
                matches = sorted(p for p in root.rglob(ev.glob)
                                 if p.is_file() and p.stat().st_size)
                if not matches:
                    continue
                rng = random.Random(f"{self.plan.seed}#{i}")
                for _ in range(ev.times - self._fired[i]):
                    p = matches[rng.randrange(len(matches))]
                    if truncate_tail(p, ev.nbytes):
                        self._fired[i] += 1
                        torn.append(p)
                        self.ledger.record("truncate_segment", str(p),
                                           p.stat().st_size)
        return torn


def truncate_tail(path: str | Path, nbytes: int | None = None) -> bool:
    """Tear the file's tail the way a crash mid-``write()`` does: drop
    the trailing newline plus ``nbytes`` bytes (default: half of the
    final line), leaving a syntactically torn last record."""
    path = Path(path)
    data = path.read_bytes()
    body = data.rstrip(b"\n")
    if not body:
        return False
    last_line_len = len(body) - (body.rfind(b"\n") + 1)
    cut = nbytes if nbytes is not None else max(1, last_line_len // 2)
    cut = min(cut, len(body))
    path.write_bytes(body[:-cut] if cut else body)
    return True


def record_fingerprint(records: Iterable[Mapping[str, Any]]) -> list[str]:
    """Canonical latest-ok-wins projection of a record stream: one
    sorted ``task_id|combo-json`` line per succeeded task.  Volatile
    fields (timestamps, runtimes, hosts, attempt counts) are excluded,
    so a chaos run and its fault-free twin compare byte-for-byte."""
    latest: dict[str, str] = {}
    for r in records:
        if r.get("status") == "ok":
            latest[str(r.get("task_id"))] = json.dumps(
                r.get("combo"), sort_keys=True, separators=(",", ":"))
    return sorted(f"{tid}|{combo}" for tid, combo in latest.items())


# -- module arming (the make_lock pattern) --------------------------------
_controller: ChaosController | None = None
_env_checked = False


def current() -> ChaosController | None:
    """The armed controller, or None.  ``PAPAS_CHAOS=plan.yaml`` in the
    environment arms one lazily (checked once); otherwise only
    ``install``/``activated`` arm.  Pools capture this at construction,
    so a disabled run pays one attribute load per seam — nothing on the
    frame hot path."""
    global _controller, _env_checked
    if _controller is None and not _env_checked:
        _env_checked = True
        path = os.environ.get("PAPAS_CHAOS")
        if path:
            _controller = FaultPlan.load(path).controller()
    return _controller


def install(ctrl: ChaosController | None) -> None:
    """Arm (or disarm, with None) the process-wide controller."""
    global _controller
    _controller = ctrl


@contextmanager
def activated(ctrl: ChaosController) -> Iterator[ChaosController]:
    """Arm ``ctrl`` for the duration of the block, restoring whatever
    was armed before."""
    prev = _controller
    install(ctrl)
    try:
        yield ctrl
    finally:
        install(prev)
