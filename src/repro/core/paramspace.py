"""Parameter combinatorics (paper §5.1).

Every multi-valued parameter contributes a factor to the Cartesian
product; ``fixed`` groups are zipped (bijection) and contribute a single
factor; ``sampling`` selects a subset of the resulting combination space.

The expansion is deterministic: parameters iterate in declaration order,
row-major, with fixed groups hoisted to the outermost loops (matching the
paper's "move fixed parameters into the outermost loop structures").

Because the order is a plain mixed-radix counter over the loop factors,
every combination has an integer address: ``combo_at(i)`` decodes index
``i`` in O(#factors) without enumerating anything, ``index_of(combo)``
is its inverse, and ``iter_sample()`` streams the post-``sampling``
subset as indices — the basis for studies over spaces far too large to
materialize (millions of combinations cost no startup memory).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import itertools
import json
import random
from typing import Any, Iterator, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class ParameterSpace:
    """A declared parameter space: names → value lists, plus fixed groups."""

    params: dict[str, list[Any]]
    fixed: list[list[str]] = dataclasses.field(default_factory=list)
    sampling: dict[str, Any] | None = None

    def __post_init__(self) -> None:
        if self.sampling:
            method = str(self.sampling.get("method", "uniform")).lower()
            if method not in ("uniform", "random"):
                raise ValueError(f"unknown sampling method {method!r}")
        seen: set[str] = set()
        for group in self.fixed:
            lens = {len(self.params[p]) for p in group}
            if len(lens) > 1:
                raise ValueError(
                    f"fixed group {group} has mismatched lengths "
                    f"{[len(self.params[p]) for p in group]}"
                )
            for p in group:
                if p not in self.params:
                    raise ValueError(f"fixed group references unknown parameter {p!r}")
                if p in seen:
                    raise ValueError(f"parameter {p!r} appears in multiple fixed groups")
                seen.add(p)

    # -- cardinality ----------------------------------------------------
    def size(self) -> int:
        """N_W = ∏ N_i with fixed groups counted once each."""
        n = 1
        grouped = {p for g in self.fixed for p in g}
        for g in self.fixed:
            n *= len(self.params[g[0]])
        for name, values in self.params.items():
            if name not in grouped:
                n *= len(values)
        return n

    # -- enumeration ----------------------------------------------------
    def _factors(self) -> list[tuple[tuple[str, ...], list[tuple[Any, ...]]]]:
        """Ordered loop factors: fixed groups outermost, then free params."""
        factors: list[tuple[tuple[str, ...], list[tuple[Any, ...]]]] = []
        grouped = {p for g in self.fixed for p in g}
        for g in self.fixed:
            cols = [self.params[p] for p in g]
            factors.append((tuple(g), list(zip(*cols))))
        for name, values in self.params.items():
            if name not in grouped:
                factors.append(((name,), [(v,) for v in values]))
        return factors

    def combinations(self) -> Iterator[dict[str, Any]]:
        """Yield every unique parameter combination (one per workflow)."""
        factors = self._factors()
        names: list[str] = [n for grp, _ in factors for n in grp]
        for combo in itertools.product(*(vals for _, vals in factors)):
            flat = tuple(v for tup in combo for v in tup)
            yield dict(zip(names, flat))

    # -- O(1) indexed addressing ----------------------------------------
    @functools.cached_property
    def _addressing(self) -> tuple[list[tuple[tuple[str, ...], list[tuple[Any, ...]]]],
                                   list[str], list[int]]:
        """Cached (factors, flat names, radices) — the mixed-radix digit
        plan shared by ``combo_at`` and ``index_of``."""
        factors = self._factors()
        names = [n for grp, _ in factors for n in grp]
        radices = [len(vals) for _, vals in factors]
        return factors, names, radices

    def combo_at(self, index: int) -> dict[str, Any]:
        """Decode combination ``index`` (row-major mixed radix, matching
        ``combinations()`` order) without enumerating the space."""
        n = self.size()
        if not 0 <= index < n:
            raise IndexError(f"combination index {index} out of range [0, {n})")
        factors, names, radices = self._addressing
        digits: list[int] = [0] * len(radices)
        rem = index
        for pos in range(len(radices) - 1, -1, -1):
            rem, digits[pos] = divmod(rem, radices[pos])
        flat = tuple(v for (_, vals), d in zip(factors, digits)
                     for v in vals[d])
        return dict(zip(names, flat))

    @functools.cached_property
    def _value_index(self) -> list[dict[Any, int] | None]:
        """Per-factor value-tuple → digit maps (``None`` where a value is
        unhashable; ``index_of`` falls back to a linear scan there)."""
        factors, _, _ = self._addressing
        maps: list[dict[Any, int] | None] = []
        for _, vals in factors:
            try:
                maps.append({v: i for i, v in enumerate(vals)})
            except TypeError:
                maps.append(None)
        return maps

    def index_of(self, combo: Mapping[str, Any]) -> int:
        """Inverse of ``combo_at``: the row-major index of ``combo``.
        Raises ``KeyError``/``ValueError`` when the combination does not
        belong to this space."""
        factors, _, _ = self._addressing
        index = 0
        for (group, vals), vmap in zip(factors, self._value_index):
            tup = tuple(combo[p] for p in group)
            if vmap is not None:
                digit = vmap.get(tup)
                if digit is None:
                    raise ValueError(
                        f"combination value {tup!r} for {group} is not in "
                        f"this parameter space")
            else:
                try:
                    digit = vals.index(tup)
                except ValueError:
                    raise ValueError(
                        f"combination value {tup!r} for {group} is not in "
                        f"this parameter space") from None
            index = index * len(vals) + digit
        return index

    def space_hash(self) -> str:
        """Stable short hash of the declared space (params + fixed +
        sampling) — journal v2 uses it to pair a resume with its study."""
        blob = json.dumps(
            {"params": self.params, "fixed": self.fixed,
             "sampling": self.sampling},
            sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- sampling -------------------------------------------------------
    def sample_count(self) -> int:
        """Post-``sampling`` instance count, computed without enumerating
        the combination space."""
        n = self.size()
        if not self.sampling:
            return n
        if "count" in self.sampling:
            k = int(self.sampling["count"])
        elif "fraction" in self.sampling:
            k = max(1, int(round(float(self.sampling["fraction"]) * n)))
        else:
            k = n
        return min(k, n)

    def iter_sample(self, seed: int | None = None) -> Iterator[int]:
        """Stream the post-``sampling`` subset as combination *indices*,
        in deterministic order, without materializing the space.

        ``method: uniform`` strides the index range to reach the
        requested count; ``method: random`` draws indices without
        replacement (O(k) via ``random.sample`` over a lazy ``range``).
        ``count`` (int) or ``fraction`` (0..1] select the subset size.
        """
        n = self.size()
        if not self.sampling:
            yield from range(n)
            return
        method = str(self.sampling.get("method", "uniform")).lower()
        k = self.sample_count()
        if method == "uniform":
            if k == n:
                yield from range(n)
                return
            stride = n / k
            for i in range(k):
                yield int(i * stride)
            return
        if method == "random":
            rng = random.Random(
                self.sampling.get("seed", seed if seed is not None else 0))
            yield from rng.sample(range(n), k)
            return
        raise ValueError(f"unknown sampling method {method!r}")

    def sample(self, seed: int | None = None) -> list[dict[str, Any]]:
        """Apply the ``sampling`` keyword: subset of the combination space
        (materialized; prefer ``iter_sample``/``combo_at`` for large
        spaces)."""
        return [self.combo_at(i) for i in self.iter_sample(seed)]


def combo_id(combo: Mapping[str, Any]) -> str:
    """Stable short identifier for a parameter combination (provenance)."""
    blob = json.dumps({k: combo[k] for k in sorted(combo)}, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def from_task(params: Mapping[str, Sequence[Any]], fixed: Sequence[Sequence[str]],
              sampling: Mapping[str, Any] | None = None) -> ParameterSpace:
    """Build a space from TaskSpec.parameters() output, resolving bare
    fixed names (``size`` → ``args:size``) to full parameter paths."""
    resolved: list[list[str]] = []
    for group in fixed:
        rg: list[str] = []
        for pname in group:
            if pname in params:
                rg.append(pname)
            else:
                matches = [k for k in params if k.endswith(":" + pname)]
                if len(matches) != 1:
                    raise ValueError(f"fixed parameter {pname!r} is unknown/ambiguous")
                rg.append(matches[0])
        resolved.append(rg)
    return ParameterSpace(
        params={k: list(v) for k, v in params.items()},
        fixed=resolved,
        sampling=dict(sampling) if sampling else None,
    )
