"""Parameter combinatorics (paper §5.1).

Every multi-valued parameter contributes a factor to the Cartesian
product; ``fixed`` groups are zipped (bijection) and contribute a single
factor; ``sampling`` selects a subset of the resulting combination space.

The expansion is deterministic: parameters iterate in declaration order,
row-major, with fixed groups hoisted to the outermost loops (matching the
paper's "move fixed parameters into the outermost loop structures").
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import random
from typing import Any, Iterator, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class ParameterSpace:
    """A declared parameter space: names → value lists, plus fixed groups."""

    params: dict[str, list[Any]]
    fixed: list[list[str]] = dataclasses.field(default_factory=list)
    sampling: dict[str, Any] | None = None

    def __post_init__(self) -> None:
        seen: set[str] = set()
        for group in self.fixed:
            lens = {len(self.params[p]) for p in group}
            if len(lens) > 1:
                raise ValueError(
                    f"fixed group {group} has mismatched lengths "
                    f"{[len(self.params[p]) for p in group]}"
                )
            for p in group:
                if p not in self.params:
                    raise ValueError(f"fixed group references unknown parameter {p!r}")
                if p in seen:
                    raise ValueError(f"parameter {p!r} appears in multiple fixed groups")
                seen.add(p)

    # -- cardinality ----------------------------------------------------
    def size(self) -> int:
        """N_W = ∏ N_i with fixed groups counted once each."""
        n = 1
        grouped = {p for g in self.fixed for p in g}
        for g in self.fixed:
            n *= len(self.params[g[0]])
        for name, values in self.params.items():
            if name not in grouped:
                n *= len(values)
        return n

    # -- enumeration ----------------------------------------------------
    def _factors(self) -> list[tuple[tuple[str, ...], list[tuple[Any, ...]]]]:
        """Ordered loop factors: fixed groups outermost, then free params."""
        factors: list[tuple[tuple[str, ...], list[tuple[Any, ...]]]] = []
        grouped = {p for g in self.fixed for p in g}
        for g in self.fixed:
            cols = [self.params[p] for p in g]
            factors.append((tuple(g), list(zip(*cols))))
        for name, values in self.params.items():
            if name not in grouped:
                factors.append(((name,), [(v,) for v in values]))
        return factors

    def combinations(self) -> Iterator[dict[str, Any]]:
        """Yield every unique parameter combination (one per workflow)."""
        factors = self._factors()
        names: list[str] = [n for grp, _ in factors for n in grp]
        for combo in itertools.product(*(vals for _, vals in factors)):
            flat = tuple(v for tup in combo for v in tup)
            yield dict(zip(names, flat))

    def sample(self, seed: int | None = None) -> list[dict[str, Any]]:
        """Apply the ``sampling`` keyword: subset of the combination space.

        ``method: uniform`` takes every k-th combination to reach the
        requested count; ``method: random`` draws without replacement.
        ``count`` (int) or ``fraction`` (0..1] select the subset size.
        """
        combos = list(self.combinations())
        if not self.sampling:
            return combos
        method = str(self.sampling.get("method", "uniform")).lower()
        if "count" in self.sampling:
            k = int(self.sampling["count"])
        elif "fraction" in self.sampling:
            k = max(1, int(round(float(self.sampling["fraction"]) * len(combos))))
        else:
            k = len(combos)
        k = min(k, len(combos))
        if method == "uniform":
            if k == len(combos):
                return combos
            stride = len(combos) / k
            return [combos[int(i * stride)] for i in range(k)]
        if method == "random":
            rng = random.Random(self.sampling.get("seed", seed if seed is not None else 0))
            return rng.sample(combos, k)
        raise ValueError(f"unknown sampling method {method!r}")


def combo_id(combo: Mapping[str, Any]) -> str:
    """Stable short identifier for a parameter combination (provenance)."""
    blob = json.dumps({k: combo[k] for k in sorted(combo)}, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def from_task(params: Mapping[str, Sequence[Any]], fixed: Sequence[Sequence[str]],
              sampling: Mapping[str, Any] | None = None) -> ParameterSpace:
    """Build a space from TaskSpec.parameters() output, resolving bare
    fixed names (``size`` → ``args:size``) to full parameter paths."""
    resolved: list[list[str]] = []
    for group in fixed:
        rg: list[str] = []
        for pname in group:
            if pname in params:
                rg.append(pname)
            else:
                matches = [k for k in params if k.endswith(":" + pname)]
                if len(matches) != 1:
                    raise ValueError(f"fixed parameter {pname!r} is unknown/ambiguous")
                rg.append(matches[0])
        resolved.append(rg)
    return ParameterSpace(
        params={k: list(v) for k, v in params.items()},
        fixed=resolved,
        sampling=dict(sampling) if sampling else None,
    )
