"""Group-commit append writer shared by the journal and provenance DB.

One buffered writer over one long-lived append handle: entries
accumulate in memory and flush as a group every ``flush_count`` appends
or ``flush_interval`` seconds (checked at append time), dropping
bookkeeping cost from one open+flush per record to amortized
O(1/flush_count).  The default policy (1, None) is durable-per-append.

The writer is deliberately lock-free: ``StudyJournal`` and ``StudyDB``
call it under their own locks, which also guard the surrounding
document state.  Readers get buffered-entry visibility through
``pending()``.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Any


class GroupCommitWriter:
    """Buffered line appender with a group-commit flush policy."""

    def __init__(self, path: Path, flush_count: int = 1,
                 flush_interval: float | None = None) -> None:
        self.path = Path(path)
        self.flush_count = max(1, int(flush_count))
        self.flush_interval = flush_interval
        self.n_appends = 0          # lines handed to append()
        self.n_flushes = 0          # group flushes actually performed
        self._buf: list[str] = []
        self._file: Any = None      # single long-lived append handle
        self._last_flush = time.monotonic()

    # writers ride along when a bound runner is pickled to a process
    # pool; the open handle and unflushed buffer are process-local state
    # (the parent keeps — and flushes — the buffer)
    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        state["_file"] = None
        state["_buf"] = []
        return state

    def append(self, line: str, force: bool = False) -> None:
        """Buffer one line (must be newline-terminated); flush when
        ``force`` is set or the count/interval policy says so."""
        self._buf.append(line)
        self.n_appends += 1
        if (force
                or len(self._buf) >= self.flush_count
                or (self.flush_interval is not None
                    and time.monotonic() - self._last_flush
                    >= self.flush_interval)):
            self.flush()

    def pending(self) -> list[str]:
        """Buffered-but-unflushed lines (read-through for readers)."""
        return list(self._buf)

    def flush(self) -> None:
        if not self._buf:
            return
        if self._file is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._file = self.path.open("a")
        self._file.write("".join(self._buf))
        self._file.flush()
        self._buf.clear()
        self.n_flushes += 1
        self._last_flush = time.monotonic()

    def close(self) -> None:
        """Flush and release the long-lived handle."""
        self.flush()
        if self._file is not None:
            self._file.close()
            self._file = None

    def drop_buffered(self) -> None:
        """Discard the buffer and release the handle without writing —
        for compaction, when the caller has folded every buffered entry
        into a fresh base document."""
        self._buf.clear()
        self.close()

    def set_policy(self, flush_count: int,
                   flush_interval: float | None) -> tuple[int, float | None]:
        """Swap the flush policy, returning the previous one."""
        prev = (self.flush_count, self.flush_interval)
        self.flush_count = max(1, int(flush_count))
        self.flush_interval = flush_interval
        return prev
