"""Group-commit append writers shared by the journal and provenance DB.

``GroupCommitWriter`` is one buffered writer over one long-lived append
handle: entries accumulate in memory and flush as a group every
``flush_count`` appends or ``flush_interval`` seconds (checked at append
time), dropping bookkeeping cost from one open+flush per record to
amortized O(1/flush_count).  The default policy (1, None) is
durable-per-append.

``ShardedGroupCommit`` spreads that stream over K per-shard append
*segments* (shard 0 is the legacy path itself; shard k is
``<path>.s<k>``) so concurrent completion streams — worker lanes, a
process pool — never serialize on one buffered handle's flush.  Readers
union the segments (``segment_paths()`` globs whatever exists on disk,
including stale segments from a previous run with more shards), so the
merged view is identical to the single-handle world.

Both writers are deliberately lock-free: ``StudyJournal`` and
``StudyDB`` call them under their own locks, which also guard the
surrounding document state.  Readers get buffered-entry visibility
through ``pending()``.

Crash semantics: ``pre_flush`` is a hook fired before a non-empty
batch physically writes — the study engine points the *journal's* hook
at the provenance DB's flush, so a journal entry can never become
durable before the record it refers to (a crash may lose a completion,
which resume simply re-runs, but never a record for a completion the
journal kept).  On the read side, ``iter_jsonl`` is the
corruption-tolerant segment reader every loader shares: a SIGKILL
mid-``write()`` legitimately leaves a torn final line, and a resume
that refuses to load over one torn record would turn a survivable
crash into data loss.
"""
from __future__ import annotations

import json
import re
import time
import warnings
from pathlib import Path
from typing import Any, Callable, Iterator

from . import telemetry as _telemetry

_SEG_RE = re.compile(r"\.s(\d+)$")


def iter_jsonl(path: Path, label: str = "record") -> Iterator[Any]:
    """Stream JSON values from a line-oriented segment, tolerating
    corruption: a line that does not parse (torn tail from a crash
    mid-write, truncated segment) is dropped with a ``RuntimeWarning``
    instead of refusing the whole load."""
    with Path(path).open() as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except ValueError:
                warnings.warn(
                    f"{label} {Path(path).name}:{lineno}: dropping "
                    f"corrupt/truncated entry ({line[:60]!r})",
                    RuntimeWarning, stacklevel=2)


class GroupCommitWriter:
    """Buffered line appender with a group-commit flush policy."""

    def __init__(self, path: Path, flush_count: int = 1,
                 flush_interval: float | None = None) -> None:
        self.path = Path(path)
        self.flush_count = max(1, int(flush_count))
        self.flush_interval = flush_interval
        self.n_appends = 0          # lines handed to append()
        self.n_flushes = 0          # group flushes actually performed
        #: fired before a non-empty batch physically writes — the
        #: durability-ordering seam (see module docstring)
        self.pre_flush: Callable[[], None] | None = None
        self._buf: list[str] = []
        self._file: Any = None      # single long-lived append handle
        self._last_flush = time.monotonic()

    # writers ride along when a bound runner is pickled to a process
    # pool; the open handle and unflushed buffer are process-local state
    # (the parent keeps — and flushes — the buffer)
    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        state["_file"] = None
        state["_buf"] = []
        state["pre_flush"] = None
        return state

    def append(self, line: str, force: bool = False) -> None:
        """Buffer one line (must be newline-terminated); flush when
        ``force`` is set or the count/interval policy says so."""
        self._buf.append(line)
        self.n_appends += 1
        if (force
                or len(self._buf) >= self.flush_count
                or (self.flush_interval is not None
                    and time.monotonic() - self._last_flush
                    >= self.flush_interval)):
            self.flush()

    def pending(self) -> list[str]:
        """Buffered-but-unflushed lines (read-through for readers)."""
        return list(self._buf)

    def flush(self) -> None:
        if not self._buf:
            return
        if self.pre_flush is not None:
            self.pre_flush()
        # telemetry consulted per *flush*, not per append, so the cost
        # rides the already-amortized path (writers outlive any single
        # armed run, so a construction-time capture would go stale)
        tel = _telemetry.current()
        t0 = time.monotonic() if tel is not None else 0.0
        n = len(self._buf)
        if self._file is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._file = self.path.open("a")
        self._file.write("".join(self._buf))
        self._file.flush()
        self._buf.clear()
        self.n_flushes += 1
        self._last_flush = time.monotonic()
        if tel is not None:
            tel.metrics.counter("papas_groupcommit_flushes_total",
                                segment=self.path.name).inc()
            tel.metrics.counter("papas_groupcommit_lines_total",
                                segment=self.path.name).inc(n)
            tel.trace.complete(f"commit:{self.path.name}", f"flush x{n}",
                               t0, self._last_flush, cat="commit",
                               args={"lines": n})

    def close(self) -> None:
        """Flush and release the long-lived handle."""
        self.flush()
        if self._file is not None:
            self._file.close()
            self._file = None

    def drop_buffered(self) -> None:
        """Discard the buffer and release the handle without writing —
        for compaction, when the caller has folded every buffered entry
        into a fresh base document."""
        self._buf.clear()
        self.close()

    def set_policy(self, flush_count: int,
                   flush_interval: float | None) -> tuple[int, float | None]:
        """Swap the flush policy, returning the previous one."""
        prev = (self.flush_count, self.flush_interval)
        self.flush_count = max(1, int(flush_count))
        self.flush_interval = flush_interval
        return prev


class ShardedGroupCommit:
    """K ``GroupCommitWriter``\\ s over per-shard append segments.

    Drop-in for a single ``GroupCommitWriter`` (same append/flush/policy
    surface; counters aggregate), plus ``set_shards`` to re-split the
    stream and ``segment_paths`` for readers.  Appends round-robin
    across shards, so each shard's flush covers ~1/K of the entries and
    no single handle becomes the serialization point.  The default —
    one shard — *is* the legacy single-handle writer, byte-for-byte."""

    def __init__(self, path: Path, flush_count: int = 1,
                 flush_interval: float | None = None,
                 shards: int = 1) -> None:
        self.path = Path(path)
        self._writers = [
            GroupCommitWriter(self._shard_path(k), flush_count,
                              flush_interval)
            for k in range(max(1, int(shards)))]
        self._rr = 0
        # counters carried over from writers dropped by set_shards, so
        # n_appends/n_flushes stay whole-stream totals across re-splits
        self._retired_appends = 0
        self._retired_flushes = 0

    def _shard_path(self, k: int) -> Path:
        return (self.path if k == 0
                else self.path.with_name(self.path.name + f".s{k}"))

    @property
    def shards(self) -> int:
        return len(self._writers)

    def set_shards(self, shards: int) -> None:
        """Re-split the stream over ``shards`` segments.  A no-op when
        the count already matches; dropped writers flush and close
        first, so re-splitting never loses buffered entries."""
        shards = max(1, int(shards))
        if shards == len(self._writers):
            return
        for w in self._writers[shards:]:
            w.close()
            self._retired_appends += w.n_appends
            self._retired_flushes += w.n_flushes
        del self._writers[shards:]
        fc = self._writers[0].flush_count
        fi = self._writers[0].flush_interval
        pf = self._writers[0].pre_flush
        while len(self._writers) < shards:
            w = GroupCommitWriter(self._shard_path(len(self._writers)),
                                  fc, fi)
            w.pre_flush = pf
            self._writers.append(w)
        self._rr = 0

    def segment_paths(self) -> list[Path]:
        """Every on-disk segment, base first then ``.s<k>`` ascending —
        globbed, not enumerated from the current writers, so a resume
        with fewer shards still reads every segment a previous run
        wrote."""
        out = [self.path] if self.path.exists() else []
        extra = []
        for p in self.path.parent.glob(self.path.name + ".s*"):
            m = _SEG_RE.search(p.name)
            if m and p.name[:-len(m.group(0))] == self.path.name:
                extra.append((int(m.group(1)), p))
        out.extend(p for _, p in sorted(extra))
        return out

    def shard_counters(self) -> list[dict[str, Any]]:
        """Per-segment append/flush counters — the telemetry snapshot's
        ``group-commit per shard`` payload.  Totals retired by
        ``set_shards`` re-splits are reported on a synthetic entry so
        the sum always matches ``n_appends``/``n_flushes``."""
        out = [{"segment": w.path.name, "appends": w.n_appends,
                "flushes": w.n_flushes} for w in self._writers]
        if self._retired_appends or self._retired_flushes:
            out.append({"segment": "(retired)",
                        "appends": self._retired_appends,
                        "flushes": self._retired_flushes})
        return out

    def unlink_segments(self) -> None:
        """Remove every on-disk segment (compaction folded them into a
        fresh base document)."""
        for p in self.segment_paths():
            try:
                p.unlink()
            except FileNotFoundError:
                pass

    # -- GroupCommitWriter surface ----------------------------------------
    @property
    def flush_count(self) -> int:
        return self._writers[0].flush_count

    @property
    def flush_interval(self) -> float | None:
        return self._writers[0].flush_interval

    @property
    def n_appends(self) -> int:
        return self._retired_appends + sum(w.n_appends
                                           for w in self._writers)

    @property
    def n_flushes(self) -> int:
        return self._retired_flushes + sum(w.n_flushes
                                           for w in self._writers)

    def append(self, line: str, force: bool = False) -> None:
        w = self._writers[self._rr]
        self._rr = (self._rr + 1) % len(self._writers)
        w.append(line, force)

    def pending(self) -> list[str]:
        return [line for w in self._writers for line in w.pending()]

    def flush(self) -> None:
        for w in self._writers:
            w.flush()

    def close(self) -> None:
        for w in self._writers:
            w.close()

    def drop_buffered(self) -> None:
        for w in self._writers:
            w.drop_buffered()

    def set_policy(self, flush_count: int,
                   flush_interval: float | None) -> tuple[int, float | None]:
        prev: tuple[int, float | None] | None = None
        for w in self._writers:
            p = w.set_policy(flush_count, flush_interval)
            if prev is None:
                prev = p
        return prev if prev is not None else (flush_count, flush_interval)

    def set_pre_flush(self, fn: Callable[[], None] | None) -> None:
        """Install (or clear) the pre-flush hook on every shard —
        future shards created by ``set_shards`` inherit it."""
        for w in self._writers:
            w.pre_flush = fn
