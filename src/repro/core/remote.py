"""Remote execution backends — the paper's distributed parallelization
(§4.3, §9: "using SSH, batch systems, and C++ MPI") behind the same
three-method ``WorkerPool`` interface the scheduler already drives.

Two backends, zero scheduler changes:

* ``SSHWorkerPool`` — maps the WDL ``hosts:`` list × ``ppnode`` to
  execution slots (one worker thread per host lane) and dispatches each
  task's *rendered shell command* to its host through a pluggable
  ``Transport``.  ``SSHTransport`` shells out to real ``ssh``;
  ``LocalTransport`` is the in-process fake used by tests and CI — it
  runs commands on the local machine while preserving per-"host" slot
  accounting, injected host failures, and scripted results, so the
  remote path is exercised without any network.  A host whose transport
  fails (connection refused, ssh exit 255, injected fault) is
  quarantined *on probation*: its lanes back off (exponentially in the
  strike count) and re-probe instead of dying outright, so a transient
  outage heals; only a host failing its ``max_probes`` probes too is
  quarantined permanently, its lanes retire, and the scheduler's normal
  retry re-dispatches the failed attempts onto a surviving host.  When
  every host goes down, queued work fails with a structured
  ``AllHostsQuarantinedError`` carrying each host's last failure cause.
* ``BatchWorkerPool`` — the paper's single-cluster-job technique:
  ``take`` claims up to ``nnodes × ppnode`` ready tasks as one group,
  renders a SLURM/PBS submission script that runs the whole group
  inside ONE allocation (each member writes ``<i>.rc``/``<i>.out``/
  ``<i>.err`` to a spool directory), submits it through a pluggable
  submitter (``SchedulerSubmitter`` → real ``sbatch``/``qsub``;
  ``LocalSubmitter`` → runs the script with ``sh`` locally), and polls
  the spool for completion, surfacing one ``CompletionEvent`` per
  group.

Both pools implement ``cancel(token)`` (called by the scheduler when a
speculative duplicate loses the race or a dispatch expires), killing
the remote process / batch job so the *backend* resource is released,
not just the scheduler slot.

Failure taxonomy: a task's nonzero exit is data (a ``ShellResult``
classified by the scheduler, same as local pools); ``TransportError``
is a *host-level* fault (host unreachable / allocation lost) that
fails the attempt and quarantines the host.
"""
from __future__ import annotations

import dataclasses
import queue
import re
import shlex
import subprocess
import threading
import time
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence, TYPE_CHECKING

from . import chaos as _chaos
from . import telemetry as _telemetry
from .dag import TaskNode
from .locklint import make_lock
from .executors import (
    CompletionEvent, Runner, ShellResult, WorkerPool, merged_env,
    run_subprocess,
)

if TYPE_CHECKING:  # pragma: no cover
    from .dag import TaskDAG

#: renders one node to its shell form: ``node -> (command | None, env)``.
RenderFn = Callable[[TaskNode], "tuple[str | None, dict[str, str]]"]

_CANCELLED = "cancelled: dispatch abandoned by scheduler"


class TransportError(RuntimeError):
    """Host-level failure (unreachable, ssh refused, allocation lost) —
    distinct from a task's own nonzero exit, which is data."""


class AllHostsQuarantinedError(TransportError):
    """Every host in an ``SSHWorkerPool`` is permanently quarantined.

    Carries ``causes`` — host → the last transport failure that killed
    it — so callers (and the degraded-run report) see *why* the pool
    died, not just that it did.  ``str()`` keeps the historical
    ``no live hosts (all N quarantined)`` prefix."""

    def __init__(self, causes: Mapping[str, str]) -> None:
        self.causes = dict(causes)
        detail = "; ".join(f"{h}: {c}"
                           for h, c in sorted(self.causes.items()))
        super().__init__(
            f"no live hosts (all {len(self.causes)} quarantined)"
            + (f" — {detail}" if detail else ""))


def parse_hosts(hosts: "str | Sequence[str]") -> list[str]:
    """Normalize a host list: comma-separated string or sequence."""
    if isinstance(hosts, str):
        hosts = hosts.split(",")
    out = [str(h).strip() for h in hosts if str(h).strip()]
    if not out:
        raise ValueError("empty host list")
    return out


def node_command(render: RenderFn | None, node: TaskNode
                 ) -> tuple[str | None, dict[str, str]]:
    """A node's shell form: the study's render fn when provided, else
    the ``command``/``environ`` keys of the node payload."""
    if render is not None:
        return render(node)
    payload = node.payload if isinstance(node.payload, Mapping) else {}
    cmd = payload.get("command")
    env = payload.get("environ") or {}
    return (str(cmd) if cmd else None), {k: str(v) for k, v in env.items()}


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


class RemoteProcess:
    """One in-flight remote command: ``wait`` returns its ShellResult;
    ``kill`` releases the underlying resource early (cancellation)."""

    def wait(self, timeout: float | None = None) -> ShellResult:
        raise NotImplementedError

    def kill(self) -> None:  # pragma: no cover - interface default
        pass


class _PopenProcess(RemoteProcess):
    def __init__(self, popen: subprocess.Popen, t0: float,
                 ssh: bool = False, host: str = "") -> None:
        self._popen = popen
        self._t0 = t0
        self._ssh = ssh
        self._host = host

    def wait(self, timeout: float | None = None) -> ShellResult:
        try:
            out, err = self._popen.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            self.kill()
            out, err = self._popen.communicate()
            raise
        rc = self._popen.returncode
        runtime = time.monotonic() - self._t0
        # ssh reserves exit 255 for its own (connection-level) failures
        if self._ssh and rc == 255:
            raise TransportError(
                f"ssh to {self._host} failed: {(err or '').strip()[-500:]}")
        return ShellResult(rc, out or "", err or "", runtime)

    def kill(self) -> None:
        try:
            self._popen.kill()
        except OSError:  # pragma: no cover - already gone
            pass


class _HookProcess(RemoteProcess):
    """Runs a test hook in the waiting worker thread."""

    def __init__(self, hook: Callable[[], ShellResult], t0: float) -> None:
        self._hook = hook
        self._t0 = t0
        self.killed = threading.Event()

    def wait(self, timeout: float | None = None) -> ShellResult:
        res = self._hook()
        return dataclasses.replace(res, runtime=time.monotonic() - self._t0)

    def kill(self) -> None:
        self.killed.set()


class Transport:
    """Starts one command on one host.  ``start`` is called from the
    worker thread owning the host lane; it may block."""

    def start(self, host: str, command: str,
              env: Mapping[str, str] | None = None,
              cwd: str | None = None) -> RemoteProcess:
        raise NotImplementedError


class SSHTransport(Transport):
    """Real ``ssh`` subprocess transport.  Environment and cwd are
    inlined into the remote command (``export K=V; cd D && cmd``) so no
    server-side agent is required — the paper's portability constraint.
    """

    def __init__(self, ssh_command: Sequence[str] = ("ssh",),
                 options: Sequence[str] = ("-oBatchMode=yes",
                                           "-oStrictHostKeyChecking=accept-new")
                 ) -> None:
        self.ssh_command = list(ssh_command)
        self.options = list(options)

    @staticmethod
    def remote_command(command: str, env: Mapping[str, str] | None,
                       cwd: str | None) -> str:
        parts = [f"export {k}={shlex.quote(str(v))};"
                 for k, v in (env or {}).items()]
        if cwd:
            parts.append(f"cd {shlex.quote(cwd)} &&")
        parts.append(command)
        return " ".join(parts)

    def start(self, host: str, command: str,
              env: Mapping[str, str] | None = None,
              cwd: str | None = None) -> RemoteProcess:
        argv = [*self.ssh_command, *self.options, host,
                self.remote_command(command, env, cwd)]
        t0 = time.monotonic()
        try:
            popen = subprocess.Popen(argv, stdout=subprocess.PIPE,
                                     stderr=subprocess.PIPE, text=True)
        except OSError as e:  # ssh binary missing / unspawnable
            raise TransportError(f"cannot spawn ssh for {host}: {e}") from e
        return _PopenProcess(popen, t0, ssh=True, host=host)


class LocalTransport(Transport):
    """In-process fake transport: "hosts" are labels; commands run on
    the local machine via ``sh -c``.  Tests and CI exercise the full
    remote code path (slot accounting, host identity, quarantine,
    cancellation) with zero network dependency.

    Knobs for tests:

    * ``fail_hosts`` — hosts that raise ``TransportError`` on dispatch
      (connection-refused simulation; may be mutated while running).
    * ``hook(host, command) -> ShellResult | None`` — when it returns a
      result, no subprocess is spawned; the hook runs *in the worker
      thread*, so it may sleep/block to script completion order.
    """

    def __init__(self, fail_hosts: Sequence[str] = (),
                 hook: Callable[[str, str], "ShellResult | None"] | None = None
                 ) -> None:
        self.fail_hosts = set(fail_hosts)
        self.hook = hook

    def start(self, host: str, command: str,
              env: Mapping[str, str] | None = None,
              cwd: str | None = None) -> RemoteProcess:
        ctrl = _chaos.current()
        if ctrl is not None:
            act = ctrl.host_action(host)
            if act is not None:
                kind, delay = act
                if kind == "hang_host":
                    # stall the dispatch (trips task timeouts); runs on
                    # the worker thread, never the event loop
                    time.sleep(delay)
                else:
                    raise TransportError(
                        f"host {host} unreachable (chaos)")
        if host in self.fail_hosts:
            raise TransportError(f"host {host} unreachable (injected)")
        t0 = time.monotonic()
        if self.hook is not None:
            hook, h, c = self.hook, host, command

            def run() -> ShellResult:
                res = hook(h, c)
                if res is not None:
                    return res
                return _local_shell(c, env, cwd)

            return _HookProcess(run, t0)
        popen = subprocess.Popen(["sh", "-c", command],
                                 stdout=subprocess.PIPE,
                                 stderr=subprocess.PIPE, text=True,
                                 env=merged_env(env), cwd=cwd)
        return _PopenProcess(popen, t0)


def _local_shell(command: str, env: Mapping[str, str] | None,
                 cwd: str | None) -> ShellResult:
    return run_subprocess(command, env=env, cwd=cwd, shell=True)


# ---------------------------------------------------------------------------
# SSH pool
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _RemoteDispatch:
    token: int
    runner: Runner | None
    nodes: list[TaskNode]


class SSHWorkerPool(WorkerPool):
    """``hosts × ppnode`` execution slots, one worker thread per host
    lane, dispatching rendered shell commands over a ``Transport``.

    ``render`` maps a node to ``(command, env)`` — usually
    ``ParameterStudy.render_node``.  Without a render fn the node's
    payload ``command`` key is used; a node with neither fails its
    attempt with a clear error (registry callables cannot be shipped
    over ssh).

    Quarantine is probational: a host's first transport failure parks
    it for ``probation`` seconds (doubling per strike); the next
    dispatch after the backoff is its probe, and a success clears the
    strikes.  A host failing ``max_probes`` probes beyond the first
    strike joins ``dead_hosts`` permanently.  ``probation=0`` restores
    the legacy die-on-first-failure behavior.
    """

    kind = "ssh"

    def __init__(
        self,
        hosts: "str | Sequence[str]",
        ppnode: int = 1,
        transport: Transport | None = None,
        render: RenderFn | None = None,
        cwd: str | None = None,
        probation: float = 0.25,
        max_probes: int = 2,
    ) -> None:
        self.hosts = parse_hosts(hosts)
        if ppnode < 1:
            raise ValueError("ppnode must be >= 1")
        self.ppnode = ppnode
        self.slots = len(self.hosts) * ppnode
        self.transport = transport or SSHTransport()
        self.render = render
        self.cwd = cwd
        self.probation = max(0.0, float(probation))
        self.max_probes = max(0, int(max_probes))
        self._pending: "queue.Queue[_RemoteDispatch | None]" = queue.Queue()
        self._events: "queue.Queue[CompletionEvent]" = queue.Queue()
        self._lock = make_lock("ssh.pool")
        self._procs: dict[int, RemoteProcess] = {}
        self._cancelled: set[int] = set()
        self.dead_hosts: set[str] = set()
        #: host → probation expiry (monotonic); absent = not quarantined
        self.quarantine: dict[str, float] = {}
        #: host → last transport failure message (feeds the structured
        #: ``AllHostsQuarantinedError`` and the degraded-run report)
        self.host_causes: dict[str, str] = {}
        self._strikes: dict[str, int] = {}
        #: set when the pool drained with every host dead
        self.all_quarantined: AllHostsQuarantinedError | None = None
        self._live = self.slots
        self._shutdown = False
        # observability seam, captured before the lanes start (None
        # when disarmed — one identity check per dispatch)
        self._telemetry = _telemetry.current()
        self._threads = [
            threading.Thread(target=self._worker, args=(host, lane),
                             name=f"papas-ssh-{host}-{lane}", daemon=True)
            for host in self.hosts for lane in range(ppnode)
        ]
        for t in self._threads:
            t.start()

    # -- scheduler interface -------------------------------------------
    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        self._pending.put(_RemoteDispatch(token, runner, list(nodes)))

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        with self._lock:
            no_workers = self._live == 0
        if no_workers:
            self._drain_pending()
        try:
            return self._events.get(timeout=timeout)
        except queue.Empty:
            return None

    def cancel(self, token: int) -> None:
        """Release the host slot held by an abandoned dispatch: kill its
        remote process so the owning lane frees up promptly."""
        with self._lock:
            self._cancelled.add(token)
            proc = self._procs.get(token)
        if proc is not None:
            proc.kill()

    def shutdown(self) -> None:
        self._shutdown = True
        for _ in self._threads:
            self._pending.put(None)
        with self._lock:
            procs = list(self._procs.values())
        for p in procs:
            p.kill()

    # -- worker machinery ----------------------------------------------
    def _run_node(self, token: int, host: str, node: TaskNode) -> Any:
        cmd, env = node_command(self.render, node)
        if cmd is None:
            raise RuntimeError(
                f"task {node.task!r} has no shell command; remote pools "
                "cannot ship in-process registry callables")
        payload = node.payload if isinstance(node.payload, Mapping) else {}
        timeout = payload.get("timeout")
        proc = self.transport.start(host, cmd, env=env, cwd=self.cwd)
        with self._lock:
            self._procs[token] = proc
        try:
            return proc.wait(float(timeout) if timeout else None)
        finally:
            with self._lock:
                self._procs.pop(token, None)

    def _worker(self, host: str, lane: int) -> None:
        try:
            while True:
                item = self._pending.get()
                if item is None:
                    return
                with self._lock:
                    host_dead = host in self.dead_hosts
                    until = self.quarantine.get(host)
                if host_dead:
                    self._pending.put(item)  # hand off to a live lane
                    return
                if until is not None:
                    now = time.monotonic()
                    if now < until:
                        # quarantined: hand the work back rather than
                        # dispatch into a known-bad host, and wait out
                        # the probation backoff in bounded naps so
                        # shutdown stays responsive
                        self._pending.put(item)
                        if self._shutdown:
                            return
                        time.sleep(min(until - now, 0.05))
                        continue
                    # backoff elapsed: this dispatch is the probe
                if item.token in self._cancelled:
                    self._emit(item, [None] * len(item.nodes),
                               [_CANCELLED] * len(item.nodes), host)
                    continue
                cause = self._run_dispatch(item, host, lane)
                if cause is not None and self._host_struck(host, cause):
                    return
        finally:
            with self._lock:
                self._live -= 1
                last = self._live == 0
            if last and not self._shutdown:
                self._drain_pending()

    def _host_struck(self, host: str, cause: str) -> bool:
        """Record one transport failure on ``host``.  Under probation
        the host backs off ``probation × 2**(strikes-1)`` seconds and
        is re-probed, up to ``max_probes`` probes; past that (or with
        probation disabled) it dies permanently.  True → this lane
        should retire."""
        with self._lock:
            strikes = self._strikes.get(host, 0) + 1
            self._strikes[host] = strikes
            self.host_causes[host] = cause
            retire = not (self.probation > 0 and strikes <= self.max_probes)
            if retire:
                self.quarantine.pop(host, None)
                self.dead_hosts.add(host)
            else:
                self.quarantine[host] = (
                    time.monotonic()
                    + self.probation * (2 ** min(strikes - 1, 16)))
        tel = self._telemetry
        if tel is not None:
            tel.metrics.counter("papas_host_strikes_total", host=host).inc()
            if retire:
                tel.metrics.counter("papas_hosts_dead_total").inc()
            else:
                tel.metrics.counter("papas_host_probes_total",
                                    host=host).inc()
        return retire

    def _host_recovered(self, host: str) -> None:
        """A successful dispatch on a previously-striking host: the
        probe passed, so quarantine and strikes clear."""
        recovered = False
        with self._lock:
            if host in self._strikes:
                self._strikes.pop(host, None)
                self.quarantine.pop(host, None)
                recovered = True
        if recovered and self._telemetry is not None:
            self._telemetry.metrics.counter(
                "papas_host_recoveries_total", host=host).inc()

    def _run_dispatch(self, item: _RemoteDispatch, host: str,
                      lane: int = 0) -> "str | None":
        """Run one dispatch on ``host``; a non-None return is the
        transport failure that means the host failed."""
        t0 = time.monotonic()
        values: list[Any] = []
        errors: list[str | None] = []
        cause: "str | None" = None
        ran_any = False
        for node in item.nodes:
            if cause is not None or item.token in self._cancelled:
                values.append(None)
                errors.append(_CANCELLED if cause is None
                              else f"host {host} failed earlier in batch")
                continue
            try:
                values.append(self._run_node(item.token, host, node))
                errors.append(None)
                ran_any = True
            except TransportError as e:
                values.append(None)
                errors.append(f"host {host} failed: {e}")
                cause = str(e)
            except Exception as e:  # noqa: BLE001 — fault isolation
                values.append(None)
                if item.token in self._cancelled:
                    errors.append(_CANCELLED)
                else:
                    errors.append(f"{type(e).__name__}: {e}")
                    ran_any = True
        if cause is None and ran_any:
            self._host_recovered(host)
        tel = self._telemetry
        if tel is not None:
            # one track per host lane: dispatches on a lane are
            # sequential, so the retroactive slice pair nests cleanly
            tel.trace.complete(
                f"host:{host}/{lane}",
                f"{item.nodes[0].task} x{len(item.nodes)}",
                t0, time.monotonic(), cat="host",
                args={"tasks": len(item.nodes),
                      "transport_failure": cause or ""})
        self._emit(item, values, errors, host, t0)
        return cause

    def _emit(self, item: _RemoteDispatch, values: list[Any],
              errors: list[str | None], host: str,
              t0: float | None = None) -> None:
        t1 = time.monotonic()
        self._events.put(CompletionEvent(
            item.token, values, errors, t0 if t0 is not None else t1, t1,
            host=host))

    def _drain_pending(self) -> None:
        """No live lanes remain: fail queued dispatches instead of
        leaving the scheduler blocked on events that can never come.
        The error is the structured ``AllHostsQuarantinedError`` —
        per-host causes included — stashed on ``all_quarantined`` for
        callers that want more than the message."""
        with self._lock:
            causes = {h: self.host_causes.get(h, "quarantined")
                      for h in self.hosts}
            exc = self.all_quarantined
            if exc is None:
                exc = self.all_quarantined = AllHostsQuarantinedError(
                    causes)
        msg = str(exc)
        while True:
            try:
                item = self._pending.get_nowait()
            except queue.Empty:
                return
            if item is None:
                continue
            n = len(item.nodes)
            now = time.monotonic()
            self._events.put(CompletionEvent(
                item.token, [None] * n, [msg] * n, now, now, host=None))


# ---------------------------------------------------------------------------
# Batch-scheduler pool (SLURM / PBS)
# ---------------------------------------------------------------------------

BATCH_KINDS = ("slurm", "pbs")


def render_batch_script(
    batch: str,
    *,
    job_name: str,
    nnodes: int,
    ppnode: int,
    entries: Sequence[tuple[str, "Mapping[str, str] | None"]],
    spool: "str | Path",
) -> str:
    """Render one submission script hosting a whole task group — the
    paper's "grouping intra/inter-workflow tasks as a single batch job".

    ``entries`` is the ordered ``(command, env)`` list; member *i*
    writes ``<spool>/<i>.out``/``.err`` and its exit code to
    ``<spool>/<i>.rc``.  The body is plain POSIX sh, so the same script
    runs under ``sbatch``, ``qsub``, or a bare ``sh`` (the test/CI fake
    submitter).
    """
    if batch not in BATCH_KINDS:
        raise ValueError(
            f"unknown batch kind {batch!r}; valid kinds: "
            + ", ".join(BATCH_KINDS))
    spool = str(spool)
    lines = ["#!/bin/sh"]
    if batch == "slurm":
        lines += [
            f"#SBATCH --job-name={job_name}",
            f"#SBATCH --nodes={nnodes}",
            f"#SBATCH --ntasks-per-node={ppnode}",
            f"#SBATCH --output={spool}/job.out",
            f"#SBATCH --error={spool}/job.err",
        ]
    else:
        lines += [
            f"#PBS -N {job_name}",
            f"#PBS -l nodes={nnodes}:ppn={ppnode}",
            f"#PBS -o {spool}/job.out",
            f"#PBS -e {spool}/job.err",
        ]
    lines += [
        "",
        f"# {len(entries)} tasks inside one {batch} allocation "
        f"({nnodes} nodes x {ppnode} procs)",
    ]
    for i, (command, env) in enumerate(entries):
        exports = " ".join(
            f"export {k}={shlex.quote(str(v))};" for k, v in (env or {}).items())
        body = f"{exports} {command}" if exports else command
        # outer subshell so the whole run-then-record unit backgrounds
        # (members of one allocation execute concurrently); the rc file
        # is written to a temp name then mv'd so the poller never reads
        # a created-but-not-yet-written file (NFS visibility races)
        lines.append(
            f"( ( {body} ) > {spool}/{i}.out 2> {spool}/{i}.err; "
            f"printf '%s' \"$?\" > {spool}/{i}.rc.tmp && "
            f"mv {spool}/{i}.rc.tmp {spool}/{i}.rc ) &")
    lines += ["wait", ""]
    return "\n".join(lines)


class Submitter:
    """Hands a rendered script to a queueing system."""

    def submit(self, script: Path) -> str:
        """Submit; returns the job id.  Raises TransportError on a
        submission-level failure."""
        raise NotImplementedError

    def cancel(self, job_id: str) -> None:  # pragma: no cover - default
        pass


class SchedulerSubmitter(Submitter):
    """Real ``sbatch`` / ``qsub`` submission."""

    _SPECS = {
        "slurm": (("sbatch",), ("scancel",), re.compile(r"(\d+)\s*$")),
        "pbs": (("qsub",), ("qdel",), re.compile(r"^\s*(\S+)")),
    }

    def __init__(self, batch: str = "slurm") -> None:
        if batch not in self._SPECS:
            raise ValueError(f"unknown batch kind {batch!r}")
        self.batch = batch
        self.submit_cmd, self.cancel_cmd, self.id_re = self._SPECS[batch]

    def submit(self, script: Path) -> str:
        try:
            proc = subprocess.run([*self.submit_cmd, str(script)],
                                  capture_output=True, text=True, check=False)
        except OSError as e:
            raise TransportError(
                f"cannot spawn {self.submit_cmd[0]}: {e}") from e
        if proc.returncode != 0:
            raise TransportError(
                f"{self.submit_cmd[0]} failed ({proc.returncode}): "
                f"{proc.stderr.strip()[-500:]}")
        m = self.id_re.search(proc.stdout.strip())
        if not m:
            raise TransportError(
                f"cannot parse job id from {proc.stdout.strip()!r}")
        return m.group(1)

    def cancel(self, job_id: str) -> None:
        subprocess.run([*self.cancel_cmd, job_id], capture_output=True,
                       check=False)


class LocalSubmitter(Submitter):
    """Fake submitter: runs the script with ``sh`` on this machine in
    the background — same spool protocol, no scheduler binary.

    Chaos seam: an armed plan's ``lose_job`` event makes ``submit``
    accept the script but never spawn it (the queue "lost" the job —
    its ``.rc`` files never appear and the batch deadline fires);
    ``dup_job`` spawns the script twice (a requeue raced the original
    — completion handling must stay idempotent)."""

    def __init__(self) -> None:
        self._procs: dict[str, subprocess.Popen] = {}
        self._dups: list[subprocess.Popen] = []
        self._n = 0

    def submit(self, script: Path) -> str:
        ctrl = _chaos.current()
        act = ctrl.job_action() if ctrl is not None else None
        self._n += 1
        if act == "lose_job":
            return f"local{self._n}.lost"
        popen = subprocess.Popen(["sh", str(script)],
                                 stdout=subprocess.DEVNULL,
                                 stderr=subprocess.DEVNULL)
        if act == "dup_job":
            self._dups.append(subprocess.Popen(
                ["sh", str(script)], stdout=subprocess.DEVNULL,
                stderr=subprocess.DEVNULL))
        job_id = f"local{self._n}.{popen.pid}"
        self._procs[job_id] = popen
        return job_id

    def cancel(self, job_id: str) -> None:
        popen = self._procs.get(job_id)
        if popen is not None and popen.poll() is None:
            popen.kill()


@dataclasses.dataclass
class _BatchJob:
    token: int
    job_id: str
    spool: Path
    nodes: list[TaskNode]
    submitted: float


class BatchWorkerPool(WorkerPool):
    """Grouped-allocation backend: one submitted job hosts up to
    ``nnodes × ppnode`` tasks (the group ``take`` claims), completion
    detected by polling the spool's per-task ``.rc`` files — which only
    needs the shared filesystem every batch cluster already has.

    A dispatch here is a whole allocation, so the scheduler drives
    ``max_allocations`` dispatch lanes (default 1 — the paper's single
    cluster job), NOT ``slots`` of them: that would submit
    ``nnodes × ppnode`` simultaneous jobs each requesting the full node
    budget."""

    kind = "batch"

    def __init__(
        self,
        batch: str = "slurm",
        nnodes: int = 1,
        ppnode: int = 1,
        render: RenderFn | None = None,
        submitter: Submitter | None = None,
        spool_root: "str | Path | None" = None,
        job_name: str = "papas",
        poll_interval: float = 0.05,
        max_allocations: int = 1,
    ) -> None:
        if batch not in BATCH_KINDS:
            raise ValueError(
                f"unknown batch kind {batch!r}; valid kinds: "
                + ", ".join(BATCH_KINDS))
        if nnodes < 1 or ppnode < 1 or max_allocations < 1:
            raise ValueError(
                "nnodes, ppnode, and max_allocations must be >= 1")
        self.batch = batch
        self.nnodes = nnodes
        self.ppnode = ppnode
        self.slots = nnodes * ppnode      # tasks per allocation (group size)
        self.max_allocations = max_allocations
        self.render = render
        self.submitter = submitter or SchedulerSubmitter(batch)
        if spool_root is None:
            import tempfile

            spool_root = tempfile.mkdtemp(prefix="papas-batch-")
        self.spool_root = Path(spool_root)
        self.job_name = job_name
        self.poll_interval = poll_interval
        self._jobs: dict[int, _BatchJob] = {}
        self._events: "queue.Queue[CompletionEvent]" = queue.Queue()

    @property
    def dispatch_slots(self) -> int:
        return self.max_allocations

    # -- scheduler interface -------------------------------------------
    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        group = ready[: self.slots]
        del ready[: len(group)]
        return group

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        nodes = list(nodes)
        spool = self.spool_root / f"job{token:05d}"
        spool.mkdir(parents=True, exist_ok=True)
        entries: list[tuple[str, Mapping[str, str] | None]] = []
        try:
            for node in nodes:
                cmd, env = node_command(self.render, node)
                if cmd is None:
                    raise RuntimeError(
                        f"task {node.task!r} has no shell command; batch "
                        "pools cannot ship in-process registry callables")
                entries.append((cmd, env))
            script = render_batch_script(
                self.batch, job_name=f"{self.job_name}-{token}",
                nnodes=self.nnodes, ppnode=self.ppnode, entries=entries,
                spool=spool)
            path = spool / "job.sh"
            path.write_text(script)
            path.chmod(0o755)
            job_id = self.submitter.submit(path)
        except Exception as e:  # noqa: BLE001 — submission failure = attempt failure
            now = time.monotonic()
            msg = f"{type(e).__name__}: {e}"
            self._events.put(CompletionEvent(
                token, [None] * len(nodes), [msg] * len(nodes), now, now,
                host=None))
            return
        self._jobs[token] = _BatchJob(token, job_id, spool, nodes,
                                      time.monotonic())

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            try:
                return self._events.get_nowait()
            except queue.Empty:
                pass
            ev = self._poll_jobs()
            if ev is not None:
                return ev
            if not self._jobs and deadline is None:
                return None     # nothing submitted: don't block forever
            if deadline is not None and time.monotonic() >= deadline:
                return None
            time.sleep(self.poll_interval)

    def cancel(self, token: int) -> None:
        """Cancel the whole allocation and synthesize its completion so
        the scheduler's slot bookkeeping resolves immediately."""
        job = self._jobs.pop(token, None)
        if job is None:
            return
        self.submitter.cancel(job.job_id)
        now = time.monotonic()
        n = len(job.nodes)
        self._events.put(CompletionEvent(
            token, [None] * n, [_CANCELLED] * n, job.submitted, now,
            host=f"{self.batch}:{job.job_id}"))

    def shutdown(self) -> None:
        for token in list(self._jobs):
            job = self._jobs.pop(token)
            self.submitter.cancel(job.job_id)

    # -- internals ------------------------------------------------------
    def _poll_jobs(self) -> CompletionEvent | None:
        for token, job in list(self._jobs.items()):
            rcs = [job.spool / f"{i}.rc" for i in range(len(job.nodes))]
            if not all(p.exists() for p in rcs):
                continue
            del self._jobs[token]
            finished = time.monotonic()
            elapsed = finished - job.submitted
            values: list[Any] = []
            errors: list[str | None] = []
            for i, rc_path in enumerate(rcs):
                try:
                    rc = int(rc_path.read_text().strip() or "1")
                except ValueError:
                    rc = 1
                out = _read_or_empty(job.spool / f"{i}.out")
                err = _read_or_empty(job.spool / f"{i}.err")
                values.append(ShellResult(rc, out, err, elapsed))
                errors.append(None)     # scheduler classifies the rc
            return CompletionEvent(
                token, values, errors, job.submitted, finished,
                host=f"{self.batch}:{job.job_id}")
        return None


def _read_or_empty(path: Path) -> str:
    try:
        return path.read_text()
    except OSError:
        return ""
