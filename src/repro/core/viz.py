"""Visualization engine (paper §4.4): DAG → DOT / ASCII.

The paper wraps PyGraphviz; we emit DOT text directly (no system
dependency — keeps the framework lightweight and user-space) plus an
ASCII rendering for terminals.  State coloring mirrors the paper's
"current state of the processing" view.
"""
from __future__ import annotations

from typing import Mapping

from .dag import TaskDAG

_STATE_COLOR = {
    "pending": "gray",
    "running": "gold",
    "ok": "palegreen",
    "failed": "tomato",
    "skipped": "lightblue",
}


def _esc(s: str) -> str:
    """Escape a string for a double-quoted DOT id or label: backslashes
    first, then quotes — a task named ``a"b`` or ``a\\b`` must not break
    out of (or corrupt) the quoted token."""
    return str(s).replace("\\", "\\\\").replace('"', '\\"')


def to_dot(dag: TaskDAG, states: Mapping[str, str] | None = None,
           title: str = "papas_study") -> str:
    states = states or {}
    lines = [f'digraph "{_esc(title)}" {{', "  rankdir=LR;",
             '  node [shape=box, style=filled, fillcolor=white];']
    for nid, node in sorted(dag.nodes.items()):
        state = states.get(nid, "pending")
        color = _STATE_COLOR.get(state, "white")
        label = f"{_esc(node.task)}\\n{_esc(nid)}"
        lines.append(f'  "{_esc(nid)}" [label="{label}", fillcolor={color}];')
    for nid, node in sorted(dag.nodes.items()):
        for dep in node.deps:
            lines.append(f'  "{_esc(dep)}" -> "{_esc(nid)}";')
    lines.append("}")
    return "\n".join(lines)


def to_ascii(dag: TaskDAG, states: Mapping[str, str] | None = None) -> str:
    """Level-ordered text rendering of the study DAG."""
    states = states or {}
    out = []
    for depth, level in enumerate(dag.levels()):
        out.append(f"level {depth}:")
        for nid in sorted(level):
            node = dag.nodes[nid]
            mark = {"ok": "x", "failed": "!", "running": ">",
                    "skipped": "-"}.get(states.get(nid, "pending"), " ")
            deps = f"  <- {', '.join(node.deps)}" if node.deps else ""
            out.append(f"  [{mark}] {nid} ({node.task}){deps}")
    return "\n".join(out)
