"""PaPaS Workflow Description Language (WDL) parser.

Implements the keyword-value WDL of Ponce et al. (PEARC'18) §5:

* A parameter study is a mapping of task names to up-to-two-level
  keyword/value entries.
* Serialization formats: YAML, JSON, and INI-like (subset).
* Numeric ranges with step size: ``start:step:end`` (inclusive) and the
  multiplicative form ``start:*k:end`` used by the paper's matmul example
  (``16:*2:16384``).  The two-field form ``a:b`` means step 1.
* ``#`` comments, colon-delimited entries, indentation scoping (all three
  handled natively by the YAML reader; the INI reader implements a
  restricted equivalent).
* All keywords parse as strings; values are type-inferred.

Reserved keywords (paper §5): command, name, environ, after, infiles,
outfiles, substitute, parallel, batch, nnodes, ppnode, hosts, fixed,
sampling — plus framework extensions: ``timeout`` (per-attempt
wall-clock bound enforced by the scheduler), ``straggler_quantile``
(straggler cutoff as a runtime quantile, e.g. ``p90`` or ``0.9``,
instead of the default ``straggler_factor × median``), ``allow_nonzero``
(nonzero shell exits are data, not failures), ``capture`` (declarative
metric extraction — a mapping of metric names to extractors over task
output: a regex string, or a mapping with exactly one of
``regex:``/``json:``/``csv:``/``builtin:`` plus optional ``source:``
(stdout | stderr | outfile:<name> | file:<path template>),
``required:``, ``type:``, and ``group:``; builtins are ``rc``,
``duration``, ``host``, ``slot`` — see ``repro.core.results``),
``baseline`` (the reference parameter point for derived
speedup/efficiency metrics, e.g. ``baseline: {threads: 1}``), and
``retry`` (per-task retry policy threaded to the scheduler: ``max:``
attempts beyond the first, ``backoff: exponential | fixed``, ``base:``
seconds before the first re-dispatch, ``jitter:`` a ±fraction spread,
``max_delay:`` a cap on any single backoff (default 30 s),
``retry_on:`` the failure kinds worth retrying — any of ``nonzero``,
``timeout``, ``host``, ``error`` — e.g. ``retry: {max: 3, backoff:
exponential, base: 0.5, retry_on: [timeout, host]}``; see
``repro.core.scheduler.RetryPolicy``).  Anything else is a user-defined
keyword usable in interpolations (e.g. ``args`` in the paper's Fig. 5).

One top-level section name is reserved for the framework: ``lint:`` is
not a task but the study-local static-analysis policy consumed by
``papas lint`` / ``sweep --check`` (see ``repro.core.lint``)::

    lint:
      suppress: [W601, E302]   # rule ids to silence for this study
      max_runtime_days: 90     # cost-estimate budget (default 30)
      slots: 16                # assumed concurrency for the estimate

Parse diagnostics are structured: every :class:`WDLError` carries the
task name, the dotted keyword path (``matmul.capture.gflops.regex``),
and the source file/line when parsed from YAML/INI.
"""
from __future__ import annotations

import configparser
import dataclasses
import io
import json
import re
from pathlib import Path
from typing import Any, Mapping, Sequence

import yaml

RESERVED_KEYWORDS = frozenset(
    {
        "command",
        "name",
        "environ",
        "after",
        "infiles",
        "outfiles",
        "substitute",
        "parallel",
        "batch",
        "nnodes",
        "ppnode",
        "hosts",
        "fixed",
        "sampling",
        "timeout",
        "allow_nonzero",
        "capture",
        "baseline",
        "straggler_quantile",
        "retry",
    }
)

#: ``start:step:end`` — step may be ``*k`` for multiplicative ranges.
_RANGE_RE = re.compile(
    r"^\s*(?P<start>[+-]?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)\s*:"
    r"(?:\s*(?P<step>\*?\s*[+-]?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)\s*:)?"
    r"\s*(?P<end>[+-]?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)\s*$"
)


class WDLError(ValueError):
    """Raised on malformed workflow description input.

    Every diagnostic carries structured context — ``task`` (the task
    section it arose in), ``keyword`` (the dotted keyword path inside
    the task, e.g. ``capture.gflops.regex``), and ``file``/``line``
    (the source location when parsed from YAML/INI) — so tools like
    ``papas lint`` can point at the exact declaration.  ``str()``
    prefixes whatever context is known::

        study.yaml:12: matmul.capture.gflops.regex: bad regex ...
    """

    def __init__(self, message: str, *, task: str | None = None,
                 keyword: str | None = None, file: str | None = None,
                 line: int | None = None) -> None:
        super().__init__(message)
        self.message = message
        self.task = task
        self.keyword = keyword
        self.file = file
        self.line = line

    def with_context(self, *, task: str | None = None,
                     keyword: str | None = None, file: str | None = None,
                     line: int | None = None) -> "WDLError":
        """Fill in context fields not already set (inner raise sites know
        more than outer ones — first writer wins); returns ``self``."""
        if self.task is None:
            self.task = task
        if self.keyword is None:
            self.keyword = keyword
        if self.file is None:
            self.file = file
        if self.line is None:
            self.line = line
        return self

    @property
    def keyword_path(self) -> str:
        """``task.keyword.sub`` dotted path ('' when no context)."""
        return ".".join(p for p in (self.task, self.keyword) if p)

    def __str__(self) -> str:
        prefix = []
        if self.file:
            prefix.append(f"{self.file}:{self.line}" if self.line
                          else str(self.file))
        if self.keyword_path:
            prefix.append(self.keyword_path)
        if prefix:
            return f"{': '.join(prefix)}: {self.message}"
        return self.message


def _num(text: str) -> int | float:
    """Parse a numeric literal, preferring int."""
    f = float(text)
    if f.is_integer() and "e" not in text.lower() and "." not in text:
        return int(text)
    return f


def parse_range(text: str) -> list[int | float] | None:
    """Expand ``start[:step]:end`` range notation to a value list.

    Returns None when ``text`` is not range syntax.  Supports additive
    steps (``1:2:9`` → 1,3,5,7,9) and multiplicative steps
    (``16:*2:128`` → 16,32,64,128).  Two-field ``1:8`` means step 1.
    """
    if not isinstance(text, str):
        return None
    m = _RANGE_RE.match(text)
    if not m:
        return None
    start = _num(m.group("start"))
    end = _num(m.group("end"))
    step_raw = m.group("step")
    values: list[int | float] = []
    if step_raw is None:
        step: int | float = 1
        multiplicative = False
    else:
        step_raw = step_raw.replace(" ", "")
        multiplicative = step_raw.startswith("*")
        step = _num(step_raw[1:] if multiplicative else step_raw)
    if multiplicative:
        if step == 0 or abs(step) == 1 or start == 0:
            raise WDLError(f"degenerate multiplicative range: {text!r}")
        cur = start
        # multiplicative ranges iterate |cur| toward |end|
        while (abs(cur) <= abs(end)) if abs(step) > 1 else (abs(cur) >= abs(end)):
            values.append(cur)
            cur = cur * step
            if len(values) > 1_000_000:
                raise WDLError(f"range too large: {text!r}")
    else:
        if step == 0:
            raise WDLError(f"zero step in range: {text!r}")
        cur = start
        if step > 0:
            while cur <= end + 1e-12:
                values.append(cur if isinstance(start, float) or isinstance(step, float) else int(cur))
                cur = cur + step
                if len(values) > 1_000_000:
                    raise WDLError(f"range too large: {text!r}")
        else:
            while cur >= end - 1e-12:
                values.append(cur if isinstance(start, float) or isinstance(step, float) else int(cur))
                cur = cur + step
    return values


def infer_value(raw: Any) -> Any:
    """Type-infer a scalar WDL value (paper: 'values are inferred')."""
    if isinstance(raw, str):
        rng = parse_range(raw)
        if rng is not None:
            return rng
        txt = raw.strip()
        for caster in (int, float):
            try:
                return caster(txt)
            except ValueError:
                continue
        if txt.lower() in ("true", "false"):
            return txt.lower() == "true"
        return raw
    return raw


def _expand_values(raw: Any) -> list[Any]:
    """Normalize a keyword's raw value(s) into the multi-value list form."""
    if isinstance(raw, list):
        out: list[Any] = []
        for item in raw:
            v = infer_value(item)
            if isinstance(v, list):
                out.extend(v)
            else:
                out.append(v)
        return out
    v = infer_value(raw)
    return v if isinstance(v, list) else [v]


@dataclasses.dataclass
class TaskSpec:
    """One task (section) of a parameter study."""

    task: str
    command: str | None = None
    name: str = ""
    environ: dict[str, list[Any]] = dataclasses.field(default_factory=dict)
    after: list[str] = dataclasses.field(default_factory=list)
    infiles: dict[str, str] = dataclasses.field(default_factory=dict)
    outfiles: dict[str, str] = dataclasses.field(default_factory=dict)
    substitute: dict[str, list[Any]] = dataclasses.field(default_factory=dict)
    parallel: str | None = None
    batch: str | None = None
    nnodes: int | None = None
    ppnode: int | None = None
    hosts: list[str] = dataclasses.field(default_factory=list)
    fixed: list[list[str]] = dataclasses.field(default_factory=list)
    sampling: dict[str, Any] | None = None
    timeout: float | None = None
    allow_nonzero: bool = False
    #: straggler cutoff as a runtime quantile (e.g. 0.9 or "p90") —
    #: replaces the default ``straggler_factor × median`` rule
    straggler_quantile: float | None = None
    #: metric name → CaptureSpec (declarative result extraction)
    capture: dict[str, Any] = dataclasses.field(default_factory=dict)
    #: reference parameter point for speedup/efficiency derivation
    baseline: dict[str, Any] = dataclasses.field(default_factory=dict)
    #: retry policy for the scheduler (``max``, ``backoff``, ``base``,
    #: ``jitter``, ``retry_on``) — empty means the engine default
    retry: dict[str, Any] = dataclasses.field(default_factory=dict)
    #: user-defined keywords → {subkey: [values]} or {None: [values]}
    user: dict[str, dict[str | None, list[Any]]] = dataclasses.field(
        default_factory=dict
    )

    def parameters(self) -> dict[str, list[Any]]:
        """All sweepable parameters, name → value list.

        Names are colon paths mirroring interpolation syntax:
        ``environ:VAR``, ``<user_kw>:<sub>`` or bare ``<user_kw>``.
        """
        params: dict[str, list[Any]] = {}
        for var, values in self.environ.items():
            params[f"environ:{var}"] = values
        for kw, subs in self.user.items():
            for sub, values in subs.items():
                key = kw if sub is None else f"{kw}:{sub}"
                params[key] = values
        for pattern, values in self.substitute.items():
            params[f"substitute:{pattern}"] = values
        return params


@dataclasses.dataclass
class StudySpec:
    """A parsed parameter study: ordered tasks (+ a ``lint:`` policy
    block and, when parsed from a file, the source origin)."""

    tasks: dict[str, TaskSpec]
    #: parsed top-level ``lint:`` block — keys ``suppress`` (rule ids),
    #: ``max_runtime_days``, ``slots`` (see ``repro.core.lint``)
    lint: dict[str, Any] = dataclasses.field(default_factory=dict)
    #: source provenance: {"file": str|None, "lines": {(task, kw, ...):
    #: line}} — populated by the YAML/INI parsers, diagnostic-only
    origin: dict[str, Any] = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    def validate(self) -> None:
        names = set(self.tasks)
        for t in self.tasks.values():
            for dep in t.after:
                if dep not in names:
                    raise WDLError(
                        f"task {t.task!r}: unknown dependency {dep!r}",
                        task=t.task, keyword="after")
            for mname, cap in t.capture.items():
                source = getattr(cap, "source", "stdout")
                if source.startswith("outfile:") \
                        and source[len("outfile:"):] not in t.outfiles:
                    raise WDLError(
                        f"task {t.task!r}: capture {mname!r} reads "
                        f"{source!r} but the task declares no such "
                        f"outfile (declared: {sorted(t.outfiles) or 'none'})",
                        task=t.task, keyword=f"capture.{mname}.source")
            for group in t.fixed:
                params = t.parameters()
                lens = []
                for pname in group:
                    if pname not in params:
                        # allow bare names matching a unique tail
                        matches = [k for k in params if k == pname or k.endswith(":" + pname)]
                        if len(matches) != 1:
                            raise WDLError(
                                f"task {t.task!r}: fixed refers to unknown/ambiguous "
                                f"parameter {pname!r}",
                                task=t.task, keyword="fixed")
                        pname = matches[0]
                    lens.append(len(params[pname]))
                if len(set(lens)) > 1:
                    raise WDLError(
                        f"task {t.task!r}: fixed group {group} has mismatched "
                        f"value counts {lens} (bijection requires equal lengths)",
                        task=t.task, keyword="fixed")


def _parse_task(name: str, body: Mapping[str, Any]) -> TaskSpec:
    if not isinstance(body, Mapping):
        raise WDLError(
            f"task {name!r}: body must be a mapping, got "
            f"{type(body).__name__}", task=str(name))
    spec = TaskSpec(task=str(name))
    for kw_raw, val in body.items():
        kw = str(kw_raw)
        try:
            _parse_keyword(spec, name, kw, val)
        except WDLError as e:
            # inner sites may know a deeper path (capture.gflops.regex);
            # default to the keyword being dispatched
            raise e.with_context(task=str(name), keyword=kw)
    return spec


def _parse_keyword(spec: TaskSpec, name: str, kw: str, val: Any) -> None:
    if kw == "command":
        if not isinstance(val, str):
            raise WDLError(f"task {name!r}: command must be a string")
        spec.command = val
    elif kw == "name":
        spec.name = str(val)
    elif kw == "environ":
        if not isinstance(val, Mapping):
            raise WDLError(f"task {name!r}: environ must be a mapping")
        spec.environ = {str(k): _expand_values(v) for k, v in val.items()}
    elif kw == "after":
        spec.after = [str(v) for v in (val if isinstance(val, list) else [val])]
    elif kw in ("infiles", "outfiles"):
        if not isinstance(val, Mapping):
            raise WDLError(f"task {name!r}: {kw} must be a mapping")
        getattr(spec, kw).update({str(k): str(v) for k, v in val.items()})
    elif kw == "substitute":
        if not isinstance(val, Mapping):
            raise WDLError(f"task {name!r}: substitute must be a mapping")
        spec.substitute = {str(k): _expand_values(v) for k, v in val.items()}
    elif kw == "parallel":
        spec.parallel = str(val)
    elif kw == "batch":
        spec.batch = str(val)
    elif kw in ("nnodes", "ppnode"):
        setattr(spec, kw, int(val))
    elif kw == "hosts":
        spec.hosts = [str(v) for v in (val if isinstance(val, list) else [val])]
    elif kw == "fixed":
        if isinstance(val, list) and val and isinstance(val[0], list):
            spec.fixed = [[str(p) for p in grp] for grp in val]
        elif isinstance(val, list):
            spec.fixed = [[str(p) for p in val]]
        else:
            raise WDLError(f"task {name!r}: fixed must be a list")
    elif kw == "timeout":
        try:
            spec.timeout = float(val)
        except (TypeError, ValueError) as e:
            raise WDLError(f"task {name!r}: timeout must be a number") from e
        if spec.timeout <= 0:
            raise WDLError(f"task {name!r}: timeout must be positive")
    elif kw == "allow_nonzero":
        spec.allow_nonzero = (
            val if isinstance(val, bool)
            else str(val).strip().lower() in ("1", "true", "yes", "on"))
    elif kw == "straggler_quantile":
        txt = str(val).strip().lower()
        try:
            # "p90"/"P99" shorthand or a plain fraction like 0.9
            q = float(txt[1:]) / 100.0 if txt.startswith("p") \
                else float(txt)
        except (TypeError, ValueError) as e:
            raise WDLError(
                f"task {name!r}: straggler_quantile must be a "
                f"fraction in (0, 1) or 'pNN' (e.g. p90), "
                f"got {val!r}") from e
        if not 0.0 < q < 1.0:
            raise WDLError(
                f"task {name!r}: straggler_quantile must be in "
                f"(0, 1), got {q!r}")
        spec.straggler_quantile = q
    elif kw == "capture":
        from .results import CaptureError, parse_captures

        try:
            spec.capture = parse_captures(name, val)
        except CaptureError as e:
            # CaptureError knows the deep path (capture.gflops.regex)
            raise WDLError(str(e),
                           keyword=getattr(e, "keyword", None)) from e
    elif kw == "baseline":
        if not isinstance(val, Mapping):
            raise WDLError(
                f"task {name!r}: baseline must be a mapping of "
                f"parameter (or captured metric) to reference value")
        spec.baseline = {}
        for k, v in val.items():
            iv = infer_value(v)
            if isinstance(iv, list):
                raise WDLError(
                    f"task {name!r}: baseline value for {k!r} must be "
                    f"a scalar, got {v!r}")
            spec.baseline[str(k)] = iv
    elif kw == "retry":
        spec.retry = _parse_retry_block(name, val)
    elif kw == "sampling":
        if isinstance(val, str):
            spec.sampling = {"method": val}
        elif isinstance(val, Mapping):
            spec.sampling = {str(k): v for k, v in val.items()}
        else:
            raise WDLError(f"task {name!r}: sampling must be a string or mapping")
    else:
        # user-defined keyword: scalar, list, or one more level of k/v
        if isinstance(val, Mapping):
            spec.user[kw] = {str(k): _expand_values(v) for k, v in val.items()}
        else:
            spec.user[kw] = {None: _expand_values(val)}


#: recognized keys of a task's ``retry:`` block.
_RETRY_KEYS = frozenset(
    {"max", "backoff", "base", "jitter", "max_delay", "retry_on"})
#: failure kinds ``retry_on:`` may list (scheduler.classify_failure).
_RETRY_ON = ("nonzero", "timeout", "host", "error")


def _parse_retry_block(name: str, val: Any) -> dict[str, Any]:
    """Validate a task's ``retry:`` block into the plain mapping the
    scheduler's ``RetryPolicy.from_any`` consumes."""
    if not isinstance(val, Mapping):
        raise WDLError(
            f"task {name!r}: retry must be a mapping "
            f"(keys: {', '.join(sorted(_RETRY_KEYS))})")
    out: dict[str, Any] = {}
    for k_raw, v in val.items():
        k = str(k_raw)
        if k not in _RETRY_KEYS:
            raise WDLError(
                f"task {name!r}: unknown retry key {k!r} "
                f"(valid: {', '.join(sorted(_RETRY_KEYS))})",
                keyword=f"retry.{k}")
        if k == "max":
            try:
                out["max"] = int(v)
            except (TypeError, ValueError) as e:
                raise WDLError(
                    f"task {name!r}: retry max must be an integer",
                    keyword="retry.max") from e
            if out["max"] < 0:
                raise WDLError(
                    f"task {name!r}: retry max must be >= 0",
                    keyword="retry.max")
        elif k == "backoff":
            b = str(v).strip().lower()
            if b not in ("exponential", "fixed"):
                raise WDLError(
                    f"task {name!r}: retry backoff must be "
                    f"'exponential' or 'fixed', got {v!r}",
                    keyword="retry.backoff")
            out["backoff"] = b
        elif k in ("base", "jitter", "max_delay"):
            try:
                out[k] = float(v)
            except (TypeError, ValueError) as e:
                raise WDLError(
                    f"task {name!r}: retry {k} must be a number",
                    keyword=f"retry.{k}") from e
            if out[k] < 0 or (k == "jitter" and out[k] > 1):
                raise WDLError(
                    f"task {name!r}: retry {k} must be "
                    f"{'in [0, 1]' if k == 'jitter' else '>= 0'}, "
                    f"got {v!r}", keyword=f"retry.{k}")
        else:   # retry_on
            kinds = v if isinstance(v, list) else [v]
            norm = [str(x).strip().lower() for x in kinds]
            bad = sorted(set(norm) - set(_RETRY_ON))
            if bad:
                raise WDLError(
                    f"task {name!r}: unknown retry_on kind(s) "
                    f"{', '.join(bad)} (valid: {', '.join(_RETRY_ON)})",
                    keyword="retry.retry_on")
            out["retry_on"] = norm
    return out


#: recognized keys of the top-level ``lint:`` block.
_LINT_KEYS = frozenset({"suppress", "max_runtime_days", "slots"})


def _parse_lint_block(val: Any) -> dict[str, Any]:
    """Parse the top-level ``lint:`` block (study-local lint policy)."""
    if val is None:
        return {}
    if not isinstance(val, Mapping):
        raise WDLError("lint: must be a mapping", keyword="lint")
    out: dict[str, Any] = {}
    for k_raw, v in val.items():
        k = str(k_raw)
        if k not in _LINT_KEYS:
            raise WDLError(
                f"lint: unknown key {k!r} "
                f"(valid: {', '.join(sorted(_LINT_KEYS))})",
                keyword=f"lint.{k}")
        if k == "suppress":
            out[k] = [str(s) for s in (v if isinstance(v, list) else [v])]
        elif k == "max_runtime_days":
            try:
                out[k] = float(v)
            except (TypeError, ValueError) as e:
                raise WDLError("lint: max_runtime_days must be a number",
                               keyword="lint.max_runtime_days") from e
        elif k == "slots":
            try:
                out[k] = int(v)
            except (TypeError, ValueError) as e:
                raise WDLError("lint: slots must be an integer",
                               keyword="lint.slots") from e
    return out


def _attach_origin(e: WDLError, origin: Mapping[str, Any] | None) -> WDLError:
    """Fill an error's file/line from a parse origin (line lookup walks
    the longest known prefix of the task.keyword path)."""
    if not origin:
        return e
    lines: Mapping[tuple, int] = origin.get("lines") or {}
    parts: list[str] = []
    if e.task:
        parts.append(e.task)
    if e.keyword:
        parts.extend(e.keyword.split("."))
    line = None
    for n in range(len(parts), 0, -1):
        line = lines.get(tuple(parts[:n]))
        if line is not None:
            break
    return e.with_context(file=origin.get("file"), line=line)


def parse_dict(doc: Mapping[str, Any], validate: bool = True, *,
               origin: Mapping[str, Any] | None = None) -> StudySpec:
    """Parse an already-deserialized study document.

    ``validate=False`` skips ``StudySpec.validate()`` — tools that want
    to collect *all* diagnostics instead of aborting at the first (the
    linter) parse unvalidated and run the rule packs themselves.
    """
    if not isinstance(doc, Mapping) or not doc:
        raise _attach_origin(
            WDLError("study document must be a non-empty mapping of tasks"),
            origin)
    tasks: dict[str, TaskSpec] = {}
    lint_block: dict[str, Any] = {}
    for tname, body in doc.items():
        tname = str(tname)
        try:
            if tname == "lint":
                lint_block = _parse_lint_block(body)
            else:
                tasks[tname] = _parse_task(tname, body or {})
        except WDLError as e:
            raise _attach_origin(e, origin)
    if not tasks:
        raise _attach_origin(
            WDLError("study document declares no tasks"), origin)
    spec = StudySpec(tasks=tasks, lint=lint_block,
                     origin=dict(origin) if origin else {})
    if validate:
        try:
            spec.validate()
        except WDLError as e:
            raise _attach_origin(e, origin)
    return spec


def _yaml_line_map(text: str) -> dict[tuple, int]:
    """(task,), (task, kw), (task, kw, sub) → 1-based source line."""
    try:
        root = yaml.compose(io.StringIO(text))
    except yaml.YAMLError:  # parse error surfaces via safe_load
        return {}
    lines: dict[tuple, int] = {}
    if not isinstance(root, yaml.MappingNode):
        return lines
    for tkey, tval in root.value:
        tname = str(tkey.value)
        lines[(tname,)] = tkey.start_mark.line + 1
        if not isinstance(tval, yaml.MappingNode):
            continue
        for kkey, kval in tval.value:
            kname = str(kkey.value)
            lines[(tname, kname)] = kkey.start_mark.line + 1
            if not isinstance(kval, yaml.MappingNode):
                continue
            for skey, _sval in kval.value:
                lines[(tname, kname, str(skey.value))] = \
                    skey.start_mark.line + 1
    return lines


def _ini_line_map(text: str) -> dict[tuple, int]:
    """Best-effort section/key → line scan for the INI flavor."""
    lines: dict[tuple, int] = {}
    section: str | None = None
    for i, raw in enumerate(text.splitlines(), 1):
        s = raw.strip()
        if not s or s.startswith(("#", ";")):
            continue
        if s.startswith("[") and s.endswith("]"):
            section = s[1:-1].strip()
            lines.setdefault((section,), i)
        elif section is not None and ("=" in s or ":" in s):
            key = re.split(r"[=:]", s, 1)[0].strip()
            if not key:
                continue
            top, _, sub = key.partition(".")
            lines.setdefault((section, top), i)
            if sub:
                lines.setdefault((section, top, sub), i)
    return lines


def parse_yaml(text: str, validate: bool = True,
               filename: str | None = None) -> StudySpec:
    try:
        doc = yaml.safe_load(io.StringIO(text))
    except yaml.YAMLError as e:  # pragma: no cover - passthrough
        raise WDLError(f"YAML parse error: {e}", file=filename) from e
    origin = {"file": filename, "lines": _yaml_line_map(text)}
    return parse_dict(doc or {}, validate, origin=origin)


def parse_json(text: str, validate: bool = True,
               filename: str | None = None) -> StudySpec:
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as e:
        raise WDLError(f"JSON parse error: {e}", file=filename) from e
    return parse_dict(doc, validate,
                      origin={"file": filename, "lines": {}})


def parse_ini(text: str, validate: bool = True,
              filename: str | None = None) -> StudySpec:
    """INI-like flavor: sections are tasks; dotted keys give 2nd level;
    comma-separated values are lists."""
    cp = configparser.ConfigParser(interpolation=None, comment_prefixes=("#", ";"))
    try:
        cp.read_string(text)
    except configparser.Error as e:
        raise WDLError(f"INI parse error: {e}", file=filename) from e
    doc: dict[str, dict[str, Any]] = {}
    for section in cp.sections():
        body: dict[str, Any] = {}
        for key, raw in cp.items(section):
            value: Any = [v.strip() for v in raw.split(",")] if "," in raw else raw
            if "." in key:
                top, sub = key.split(".", 1)
                body.setdefault(top, {})[sub] = value
            else:
                body[key] = value
        doc[section] = body
    return parse_dict(doc, validate,
                      origin={"file": filename, "lines": _ini_line_map(text)})


def parse_file(path: str | Path, validate: bool = True) -> StudySpec:
    """Parse a parameter file, dispatching on extension."""
    path = Path(path)
    text = path.read_text()
    suffix = path.suffix.lower()
    if suffix == ".json":
        return parse_json(text, validate, filename=str(path))
    if suffix in (".ini", ".cfg"):
        return parse_ini(text, validate, filename=str(path))
    return parse_yaml(text, validate, filename=str(path))


def merge(*specs: StudySpec) -> StudySpec:
    """Compose a study from multiple parameter files (paper §4.1: a
    workflow description may be divided across files).

    Two specs declaring the *same* task field-merge (dicts union, lists
    concatenate, scalars overwrite).  Contradictory singletons raise:
    two different ``baseline:`` or ``retry:`` blocks for one task
    (matching the treatment of conflicting ``sampling`` blocks at
    space-construction time), and two different scalar values for one
    ``lint:`` policy key (``suppress`` lists union)."""
    tasks: dict[str, TaskSpec] = {}
    lint: dict[str, Any] = {}
    for spec in specs:
        for key, v in (spec.lint or {}).items():
            if key == "suppress":
                cur = lint.setdefault("suppress", [])
                cur.extend(s for s in v if s not in cur)
            elif key in lint and lint[key] != v:
                raise WDLError(
                    f"conflicting lint.{key} in merged specs: "
                    f"{lint[key]!r} vs {v!r}", keyword=f"lint.{key}")
            else:
                lint[key] = v
        for tname, t in spec.tasks.items():
            if tname in tasks:
                base = tasks[tname]
                if base.baseline and t.baseline \
                        and base.baseline != t.baseline:
                    raise WDLError(
                        f"task {tname!r}: conflicting baseline blocks in "
                        f"merged specs: {base.baseline!r} vs "
                        f"{t.baseline!r} — a study has one reference "
                        f"point", task=tname, keyword="baseline")
                if base.retry and t.retry and base.retry != t.retry:
                    raise WDLError(
                        f"task {tname!r}: conflicting retry blocks in "
                        f"merged specs: {base.retry!r} vs {t.retry!r} "
                        f"— a task has one retry policy",
                        task=tname, keyword="retry")
                for f in dataclasses.fields(TaskSpec):
                    val = getattr(t, f.name)
                    if f.name == "task":
                        continue
                    if isinstance(val, dict):
                        merged = dict(getattr(base, f.name))
                        for k, v in val.items():
                            if (k in merged and isinstance(v, dict)
                                    and isinstance(merged[k], dict)):
                                merged[k] = {**merged[k], **v}
                            else:
                                merged[k] = v
                        setattr(base, f.name, merged)
                    elif isinstance(val, list):
                        setattr(base, f.name, list(getattr(base, f.name)) + list(val))
                    elif val not in (None, ""):
                        setattr(base, f.name, val)
            else:
                tasks[tname] = dataclasses.replace(t)
    out = StudySpec(tasks=tasks, lint=lint)
    out.validate()
    return out
