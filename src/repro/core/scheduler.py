"""Task scheduling (paper §4.2/§4.3).

Two modes share one ready-queue engine:

* **execute** — run node payloads (callables) on a bounded pool of
  "slots" (the analogue of `nnodes × ppnode`), with retries, failure
  isolation, straggler detection, and checkpoint journaling.
* **simulate** — given per-node durations, compute start/stop times under
  a submission/scheduling policy.  This reproduces the paper's Fig. 1
  regimes (*optimal*, *serial*, *common*) and the Fig. 3/4 grouping
  comparison without wall-clock waiting.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
import time
from typing import Any, Callable, Mapping

from .dag import TaskDAG, TaskNode


@dataclasses.dataclass
class TaskResult:
    id: str
    status: str                 # ok | failed | skipped
    runtime: float
    started: float
    finished: float
    attempts: int = 1
    value: Any = None
    error: str | None = None
    slot: int = -1
    speculative: bool = False


@dataclasses.dataclass
class ScheduleEvent:
    """One simulated execution record (for Fig. 1/3/4 reproductions)."""

    id: str
    slot: int
    start: float
    stop: float


class Scheduler:
    """Ready-queue scheduler over a TaskDAG."""

    def __init__(
        self,
        slots: int = 1,
        max_retries: int = 1,
        straggler_factor: float = 3.0,
        clock: Callable[[], float] = time.monotonic,
        order: str = "breadth",
    ) -> None:
        """``order``: "breadth" finishes each task level across all
        workflow instances first; "depth" completes one instance's whole
        task chain before starting the next (paper §9 future work)."""
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if order not in ("breadth", "depth"):
            raise ValueError(f"unknown order {order!r}")
        self.slots = slots
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.clock = clock
        self.order = order

    # ------------------------------------------------------------------
    def execute(
        self,
        dag: TaskDAG,
        runner: Callable[[TaskNode], Any],
        completed: set[str] | None = None,
        on_result: Callable[[TaskResult], None] | None = None,
    ) -> dict[str, TaskResult]:
        """Run every node once its deps are satisfied.

        ``completed`` marks nodes already finished (checkpoint restart):
        they are skipped and treated as satisfied dependencies.  Failed
        nodes are retried up to ``max_retries`` times; their transitive
        successors are marked ``skipped`` rather than aborting the study
        (fault isolation, paper §4.1 checkpoint-restart semantics).
        """
        dag.validate()
        completed = set(completed or ())
        succ = dag.successors()
        indeg = {nid: len(n.deps) for nid, n in dag.nodes.items()}
        results: dict[str, TaskResult] = {}
        runtimes: list[float] = []

        ready = [nid for nid, n in dag.nodes.items()
                 if all(d in completed for d in n.deps)]
        # nodes whose deps are already checkpoint-complete but are
        # themselves complete get skipped outright
        for nid in sorted(dag.nodes):
            if nid in completed:
                results[nid] = TaskResult(
                    id=nid, status="ok", runtime=0.0, started=0.0,
                    finished=0.0, attempts=0, value=None)
        ready = sorted(set(ready) - completed)

        failed_closure: set[str] = set()

        def _mark_failed_closure(root: str) -> None:
            stack = [root]
            while stack:
                cur = stack.pop()
                for s in succ[cur]:
                    if s not in failed_closure:
                        failed_closure.add(s)
                        stack.append(s)

        pending = set(dag.nodes) - completed
        while ready or pending - set(results):
            if not ready:
                # nothing ready but work pending → only failed-closure left
                remaining = sorted(pending - set(results))
                for nid in remaining:
                    results[nid] = TaskResult(
                        id=nid, status="skipped", runtime=0.0,
                        started=self.clock(), finished=self.clock(),
                        error="dependency failed")
                break
            nid = ready.pop(0)
            node = dag.nodes[nid]
            if nid in failed_closure:
                results[nid] = TaskResult(
                    id=nid, status="skipped", runtime=0.0,
                    started=self.clock(), finished=self.clock(),
                    error="dependency failed")
            else:
                attempts = 0
                last_err: str | None = None
                value: Any = None
                t0 = self.clock()
                while attempts <= self.max_retries:
                    attempts += 1
                    try:
                        value = runner(node)
                        last_err = None
                        break
                    except Exception as e:  # noqa: BLE001 — fault isolation
                        last_err = f"{type(e).__name__}: {e}"
                t1 = self.clock()
                if last_err is None:
                    rt = t1 - t0
                    runtimes.append(rt)
                    med = sorted(runtimes)[len(runtimes) // 2]
                    res = TaskResult(
                        id=nid, status="ok", runtime=rt, started=t0,
                        finished=t1, attempts=attempts, value=value)
                    if med > 0 and rt > self.straggler_factor * med and len(runtimes) >= 5:
                        res.speculative = True  # flagged straggler
                    results[nid] = res
                else:
                    results[nid] = TaskResult(
                        id=nid, status="failed", runtime=t1 - t0, started=t0,
                        finished=t1, attempts=attempts, error=last_err)
                    _mark_failed_closure(nid)
            if on_result:
                on_result(results[nid])
            # release successors
            for s in succ[nid]:
                indeg[s] -= 1
                if indeg[s] == 0 and s not in results:
                    ready.append(s)
            if self.order == "depth":
                # instance-major: ids are "<task>@<combo>" — sort by
                # combo first so one workflow finishes before the next
                ready.sort(key=lambda i: (i.split("@")[-1], i))
            else:
                ready.sort()
        return results

    # ------------------------------------------------------------------
    def simulate(
        self,
        dag: TaskDAG,
        durations: Mapping[str, float],
        policy: str = "optimal",
        seed: int = 0,
        queue_delay: float = 0.0,
    ) -> list[ScheduleEvent]:
        """Event-driven simulation of the paper's Fig. 1 regimes.

        * ``optimal`` — as many slots as jobs; all start at t=0.
        * ``serial``  — one slot, back-to-back.
        * ``common``  — ``self.slots`` slots, random per-dispatch delays
          (models multi-tenant scheduler jitter + queueing).
        * ``grouped`` — ``self.slots`` slots, no dispatch delay (PaPaS
          batched dispatch: one cluster job hosts all tasks).
        """
        dag.validate()
        order = [n.id for n in dag.topological()]
        rng = random.Random(seed)
        nslots = {
            "optimal": max(1, len(order)),
            "serial": 1,
            "common": self.slots,
            "grouped": self.slots,
        }.get(policy)
        if nslots is None:
            raise ValueError(f"unknown policy {policy!r}")
        finish: dict[str, float] = {}
        events: list[ScheduleEvent] = []
        # slot heap: (free_at, slot_id)
        heap = [(0.0, s) for s in range(nslots)]
        heapq.heapify(heap)
        for nid in order:
            node = dag.nodes[nid]
            dep_ready = max((finish[d] for d in node.deps), default=0.0)
            free_at, slot = heapq.heappop(heap)
            start = max(dep_ready, free_at)
            if policy == "common":
                # scheduler interaction cost per dispatch + jitter
                start += queue_delay + rng.expovariate(1.0) * queue_delay
            stop = start + float(durations[nid])
            finish[nid] = stop
            events.append(ScheduleEvent(id=nid, slot=slot, start=start, stop=stop))
            heapq.heappush(heap, (stop, slot))
        return events


def makespan(events: list[ScheduleEvent]) -> float:
    return max((e.stop for e in events), default=0.0)


def dispatch_count(events: list[ScheduleEvent]) -> int:
    """Scheduler interactions = one start/stop pair per event."""
    return len(events)
