"""The unified execution engine (paper §4.2/§4.3).

One slot-occupancy event loop drives every execution mode.  The loop
keeps a sorted ready queue over the task DAG, dispatches batches onto
numbered slots claimed from a ``WorkerPool`` backend, then blocks on the
pool's completion stream — handling retries, failure closure, per-task
timeouts, and speculative straggler duplicates as events arrive in *any*
order.  The three historical code paths are now configurations of this
single loop:

* **execute** — live runs on a pluggable backend (``InlinePool`` for
  determinism, ``ThreadWorkerPool``/``ProcessWorkerPool`` for real
  parallelism, ``GangPool`` for batched dispatch).  ``TaskResult.slot``
  is the real slot the task occupied; ``started``/``finished`` are true
  per-slot occupancy times measured by the backend.
* **simulate** — the same loop over a ``VirtualPool`` event source that
  advances an injected virtual clock instead of waiting, reproducing the
  paper's Fig. 1 regimes (*optimal*, *serial*, *common*, *grouped*) and
  the Fig. 3/4 grouping comparison with zero wall-clock cost.
* **gang** — ``ParameterStudy.run(gang=...)`` routes through the same
  loop with a ``GangPool``, so batched dispatch shares the retry,
  closure, and journal machinery.

Concurrency-relevant semantics:

* a node failing after ``max_retries`` re-dispatches marks its whole
  transitive successor closure ``skipped`` (fault isolation, §4.1);
* a per-node ``timeout`` (from the WDL ``timeout`` keyword, carried in
  ``node.payload``) bounds each attempt; a gang batch gets the *sum* of
  its members' timeouts as its wall-clock budget (one launch hosting N
  tasks earns N tasks' allowance).  Overdue dispatches are failed and
  their late completions discarded — the slot stays occupied until the
  zombie worker actually finishes, so queued work never times out
  behind it;
* with ``speculate=True``, a running task whose elapsed time exceeds
  ``straggler_factor ×`` the median completed runtime gets a duplicate
  dispatch; the first finisher wins (``TaskResult.speculative`` marks a
  duplicate win) and the loser is abandoned.

Streaming admission: ``execute(..., source=…, window=N)`` turns the
whole-DAG loop into a bounded frontier.  ``source.next_subdag()`` yields
one *self-contained* instance sub-DAG at a time (all deps internal to
the batch); the loop admits a sub-DAG only when it fits within the
``slots + window`` live-node budget, and retires each node's
``TaskNode`` the moment it resolves — so live graph state stays
O(slots + window) no matter how many combinations the study spans.
Retries, failure closure, timeouts, and speculation all apply unchanged;
the eager path (``source=None``) is byte-for-byte the old behavior.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import itertools
import random
import time
from typing import Any, Callable, Mapping

from . import telemetry
from .dag import DAGError, TaskDAG, TaskNode
from .executors import CompletionEvent, InlinePool, WorkerPool
from .stats import StreamingMedian as _StreamingMedian  # noqa: F401 (back-compat)
from .stats import StreamingQuantile


@dataclasses.dataclass
class TaskResult:
    id: str
    status: str                 # ok | failed | skipped
    runtime: float
    started: float
    finished: float
    attempts: int = 1
    value: Any = None
    error: str | None = None
    slot: int = -1              # real slot occupied (execute and simulate)
    speculative: bool = False   # won by a speculative duplicate dispatch
    host: str | None = None     # executing host / allocation (remote pools)
    metrics: dict[str, Any] | None = None   # captured metrics (results layer)


@dataclasses.dataclass
class ScheduleEvent:
    """One simulated execution record (for Fig. 1/3/4 reproductions)."""

    id: str
    slot: int
    start: float
    stop: float


#: failure kinds a retry policy can match (WDL ``retry_on:``)
RETRY_KINDS = ("nonzero", "timeout", "host", "error")


def classify_failure(error: str | None) -> str:
    """Map an attempt's error string onto a retry-policy failure kind:
    ``timeout`` (deadline or budget expiry), ``nonzero`` (exit status),
    ``host`` (infrastructure — unreachable host, dead lane, drained
    pool), ``error`` (anything else: runner exceptions, classification
    failures)."""
    e = error or ""
    if e.startswith("timeout"):
        return "timeout"
    if e.startswith("nonzero exit"):
        return "nonzero"
    if (e.startswith("host ") or e.startswith("no live hosts")
            or "lane worker" in e or "unreachable" in e):
        return "host"
    return "error"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How failed attempts re-enter the ready queue (WDL ``retry:``).

    ``max`` of None defers to the scheduler's ``max_retries``.  The
    delay before re-dispatching after failed attempt *k* is ``base``
    (``backoff: fixed``) or ``base * 2**(k-1)`` (``backoff:
    exponential``), capped at ``max_delay`` and spread by ±``jitter``
    (a fraction, derived deterministically from the node id so runs
    stay reproducible).  Only failures whose ``classify_failure`` kind
    is in ``retry_on`` are retried at all; the rest fail immediately
    with their successor closure.

    The default policy retries every kind with a 50 ms exponential
    backoff — the smallest delay that still breaks the instant-retry
    storm (a node failing deterministically in under a millisecond used
    to burn its whole retry budget inside one loop iteration)."""

    max: int | None = None
    backoff: str = "exponential"
    base: float = 0.05
    jitter: float = 0.0
    max_delay: float = 30.0
    retry_on: frozenset = frozenset(RETRY_KINDS)

    @classmethod
    def from_any(cls, spec: Any = None) -> "RetryPolicy":
        """Build from a WDL ``retry:`` mapping (or pass a policy
        through; None → the default policy)."""
        if spec is None:
            return cls()
        if isinstance(spec, RetryPolicy):
            return spec
        known = {f.name for f in dataclasses.fields(cls)}
        bad = sorted(set(spec) - known)
        if bad:
            raise ValueError(f"unknown retry key(s): {', '.join(bad)}")
        kw: dict[str, Any] = {}
        if spec.get("max") is not None:
            kw["max"] = int(spec["max"])
            if kw["max"] < 0:
                raise ValueError("retry max must be >= 0")
        if spec.get("backoff") is not None:
            b = str(spec["backoff"]).strip().lower()
            if b not in ("exponential", "fixed"):
                raise ValueError(
                    f"retry backoff must be 'exponential' or 'fixed', "
                    f"got {b!r}")
            kw["backoff"] = b
        for k in ("base", "jitter", "max_delay"):
            if spec.get(k) is not None:
                kw[k] = float(spec[k])
                if kw[k] < 0:
                    raise ValueError(f"retry {k} must be >= 0")
        if spec.get("retry_on") is not None:
            kinds = spec["retry_on"]
            if isinstance(kinds, str):
                kinds = [kinds]
            norm = frozenset(str(k).strip().lower() for k in kinds)
            bad_kinds = sorted(norm - set(RETRY_KINDS))
            if bad_kinds:
                raise ValueError(
                    f"unknown retry_on kind(s): {', '.join(bad_kinds)} "
                    f"(valid: {', '.join(RETRY_KINDS)})")
            kw["retry_on"] = norm
        return cls(**kw)

    def retries(self, default: int) -> int:
        return default if self.max is None else self.max

    def should_retry(self, error: str | None) -> bool:
        return classify_failure(error) in self.retry_on

    def delay(self, attempt: int, key: str = "") -> float:
        """Backoff before re-dispatching after failed attempt
        ``attempt`` (1-based)."""
        if self.backoff == "fixed":
            d = self.base
        else:
            d = self.base * (2.0 ** max(0, attempt - 1))
        d = min(d, self.max_delay)
        if self.jitter:
            u = random.Random(f"{key}#{attempt}").random()
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(0.0, d)

    def ceiling(self, default_retries: int = 1) -> float:
        """Worst-case single backoff the policy can impose — what lint
        W701 compares against the task timeout."""
        n = self.retries(default_retries)
        if n < 1:
            return 0.0
        if self.backoff == "fixed":
            d = self.base
        else:
            d = self.base * (2.0 ** max(0, n - 1))
        return min(d, self.max_delay) * (1.0 + self.jitter)


class VirtualClock:
    """Injectable event-time source for wall-clock-free simulation."""

    def __init__(self, start: float = 0.0) -> None:
        self.now = start

    def __call__(self) -> float:
        return self.now


class VirtualPool(WorkerPool):
    """Virtual-time backend: completions come from a duration table (or
    ``fn(node_id, n_prior_dispatches)``) ordered on a min-heap, and
    ``next_event`` advances the injected clock to each finish time.
    With ``call_runner=True`` the runner still executes (instantly in
    virtual time) so tests can exercise real failure paths under a
    deterministic fake clock."""

    kind = "virtual"

    def __init__(
        self,
        durations: Mapping[str, float] | Callable[[str, int], float],
        clock: VirtualClock,
        delay_fn: Callable[[], float] | None = None,
        call_runner: bool = False,
    ) -> None:
        self.durations = durations
        self.clock = clock
        self.delay_fn = delay_fn
        self.call_runner = call_runner
        self._heap: list[tuple[float, int, int, float, Any, str | None]] = []
        self._seq = 0
        self._dispatched: dict[str, int] = {}

    def _duration(self, nid: str) -> float:
        k = self._dispatched.get(nid, 0)
        self._dispatched[nid] = k + 1
        if callable(self.durations):
            return float(self.durations(nid, k))
        return float(self.durations[nid])

    def submit(self, token: int, runner: Any,
               nodes: list[TaskNode]) -> None:
        (node,) = nodes   # virtual dispatch is per-node
        start = self.clock.now + (self.delay_fn() if self.delay_fn else 0.0)
        stop = start + self._duration(node.id)
        value, error = None, None
        if self.call_runner and runner is not None:
            try:
                value = runner(node)
            except Exception as e:  # noqa: BLE001
                error = f"{type(e).__name__}: {e}"
        heapq.heappush(self._heap,
                       (stop, self._seq, token, start, value, error))
        self._seq += 1

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        if not self._heap:
            return None
        if timeout is not None and self._heap[0][0] > self.clock.now + timeout:
            self.clock.now += timeout   # sleep through a quiet interval
            return None
        stop, _, token, start, value, error = heapq.heappop(self._heap)
        if stop > self.clock.now:
            self.clock.now = stop
        return CompletionEvent(token, [value], [error], start, stop)


class AdaptiveWindow:
    """Completion-rate-driven streaming window (``run(window="auto")``).

    The window bounds live nodes at ``slots + current``.  Instead of a
    hand-tuned constant, the controller measures the resolution rate
    over short intervals and sizes the window to hold roughly
    ``horizon`` seconds of work: fast no-op sweeps grow toward
    ``max_window`` (admission never starves the pool), slow studies
    shrink toward ``slots`` (live state stays tiny).  Moves are smoothed
    50/50 toward the target so one noisy interval cannot thrash the
    admission bound."""

    def __init__(self, slots: int = 1, min_window: int | None = None,
                 max_window: int = 4096, horizon: float = 0.5) -> None:
        self.min = max(1, min_window if min_window is not None else slots)
        self.max = max(self.min, max_window)
        self.horizon = horizon
        #: current window size (live bound is ``slots + current``)
        self.current = self.min
        self._t0: float | None = None
        self._n0 = 0

    def observe(self, now: float, n_resolved: int) -> None:
        """Feed the controller the loop's clock + resolution counter."""
        if self._t0 is None:
            self._t0, self._n0 = now, n_resolved
            return
        dt = now - self._t0
        if dt < self.horizon / 4:
            return
        rate = (n_resolved - self._n0) / dt
        target = int(rate * self.horizon)
        self.current = max(self.min,
                           min(self.max, (self.current + target + 1) // 2))
        self._t0, self._n0 = now, n_resolved


@dataclasses.dataclass
class _Dispatch:
    """One in-flight batch occupying a slot."""

    token: int
    nids: list[str]
    slot: int
    dispatched: float           # engine clock at submit
    budget: float | None        # wall-clock allowance for the whole batch
    deadline: float | None      # dispatched + budget
    speculative: bool


class Scheduler:
    """Slot-occupancy event loop over a TaskDAG."""

    def __init__(
        self,
        slots: int = 1,
        max_retries: int = 1,
        straggler_factor: float = 3.0,
        clock: Callable[[], float] = time.monotonic,
        order: str = "breadth",
        speculate: bool = False,
        straggler_quantile: float | None = None,
        retry_policy: Any = None,
    ) -> None:
        """``order``: "breadth" finishes each task level across all
        workflow instances first; "depth" completes one instance's whole
        task chain before starting the next (paper §9 future work).
        ``speculate``: launch a duplicate of any running task slower than
        the straggler cutoff (≥ 5 samples) when a slot is idle; only
        enable for idempotent runners.  The cutoff is
        ``straggler_factor ×`` the median runtime, or — when
        ``straggler_quantile`` is set (e.g. 0.9 for p90, the WDL
        ``straggler_quantile:`` keyword) — the running q-quantile of
        completed runtimes directly, no factor applied.
        ``retry_policy``: a ``RetryPolicy`` (or WDL ``retry:``-shaped
        mapping) governing when and after what backoff failed attempts
        re-dispatch; a per-node ``retry`` payload entry overrides it."""
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if order not in ("breadth", "depth"):
            raise ValueError(f"unknown order {order!r}")
        if straggler_quantile is not None \
                and not 0.0 < straggler_quantile < 1.0:
            raise ValueError(
                f"straggler_quantile must be in (0, 1), "
                f"got {straggler_quantile!r}")
        self.slots = slots
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.straggler_quantile = straggler_quantile
        self.clock = clock
        self.order = order
        self.speculate = speculate
        self.retry_policy = RetryPolicy.from_any(retry_policy)
        self._retry_cache: dict[str, RetryPolicy] = {}
        #: observability seam, captured once (None when disarmed — the
        #: loop then pays one identity check per event and nothing else)
        self._telemetry = telemetry.current()
        #: live-node high-water mark of the last run (streaming admission
        #: bounds it near ``slots + window``; eager runs see the full DAG)
        self.peak_live_nodes = 0

    # ------------------------------------------------------------------
    def _order_key(self, nid: str) -> tuple[str, ...]:
        if self.order == "depth":
            # instance-major: ids are "<task>@<combo>" — sort by combo
            # first so one workflow finishes before the next
            return (nid.split("@")[-1], nid)
        return (nid,)

    def _sort_ready(self, ready: list[str]) -> None:
        ready.sort(key=self._order_key)

    @staticmethod
    def _payload(node: TaskNode) -> Mapping[str, Any]:
        return node.payload if isinstance(node.payload, Mapping) else {}

    @classmethod
    def _classify(cls, node: TaskNode, value: Any) -> str | None:
        """Post-completion failure classification: a ShellResult-like
        value with a nonzero returncode fails the attempt unless the task
        sets ``allow_nonzero``."""
        rc = getattr(value, "returncode", None)
        if isinstance(rc, int) and rc != 0:
            if not cls._payload(node).get("allow_nonzero"):
                stderr = (getattr(value, "stderr", "") or "")[-2000:]
                return f"nonzero exit {rc}: {stderr}"
        return None

    def _node_retry_policy(self, node: TaskNode) -> RetryPolicy:
        """The effective retry policy for a node: its WDL ``retry:``
        payload entry if present (parsed once per task section),
        otherwise the scheduler-wide policy."""
        spec = self._payload(node).get("retry")
        if not spec:
            return self.retry_policy
        if isinstance(spec, RetryPolicy):
            return spec
        pol = self._retry_cache.get(node.task)
        if pol is None:
            pol = self._retry_cache[node.task] = RetryPolicy.from_any(spec)
        return pol

    def _wait_until(self, t: float) -> None:
        """Advance to time ``t`` when nothing is in flight: virtual
        clocks jump (``.now`` duck-typing, the ``VirtualClock``
        contract), wall clocks nap in bounded slices so the loop stays
        responsive to completions and interrupts."""
        clk = self.clock
        now_attr = getattr(clk, "now", None)
        if now_attr is not None:
            clk.now = max(now_attr, t)
        else:
            time.sleep(max(0.0, min(t - clk(), 0.05)))

    # ------------------------------------------------------------------
    def execute(
        self,
        dag: TaskDAG,
        runner: Callable[[TaskNode], Any] | None,
        completed: set[str] | None = None,
        on_result: Callable[[TaskResult], None] | None = None,
        pool: WorkerPool | None = None,
        source: Any = None,
        window: int | AdaptiveWindow | None = None,
        keep_results: bool = True,
        classify: Callable[[TaskNode, Any], str | None] | None = None,
    ) -> dict[str, TaskResult]:
        """Run every node once its deps are satisfied.

        ``completed`` marks nodes already finished (checkpoint restart):
        they are skipped and treated as satisfied dependencies.  Failed
        attempts are retried up to ``max_retries`` times; nodes failing
        for good have their transitive successors marked ``skipped``
        rather than aborting the study (fault isolation, paper §4.1).
        ``pool`` selects the backend (default: a fresh ``InlinePool``);
        ``on_result`` fires on the event-loop thread as nodes resolve.

        ``source`` + ``window`` enable streaming admission: ``source``
        must expose ``next_subdag() -> (nodes, done_ids) | None``
        yielding one self-contained instance sub-DAG per call (every dep
        internal to the batch or listed in ``done_ids``), and the loop
        keeps at most ``slots + window`` unresolved nodes live — a
        fetched sub-DAG that would overflow the budget waits until
        resolved nodes retire.  (Sole exception: when one sub-DAG is
        bigger than the whole budget it is still admitted, whole, once
        nothing else is live — progress beats the bound.)  ``on_result``
        fires before its node is retired, so callbacks may still read
        ``dag.nodes[res.id]``.  ``self.peak_live_nodes`` records the
        high-water mark after a run.

        ``keep_results=False`` turns the run into a pure result stream:
        ``on_result`` still fires per resolution, but ``TaskResult``\\ s
        are not accumulated and the returned dict is empty — combined
        with streaming admission, engine memory stays O(slots + window)
        end to end instead of O(N_W).

        ``classify`` is an extra post-completion classifier applied
        after the built-in nonzero-exit check: given ``(node, value)``
        it returns an error string to fail the attempt (retries and
        failure closure apply, exactly like a nonzero exit) or ``None``
        to accept it — the seam the results layer uses to fail attempts
        whose *required* captured metrics are missing.  A raising
        classifier fails the attempt rather than the study.
        """
        if (source is None) != (window is None):
            raise ValueError("source and window must be passed together")
        if window is not None and not isinstance(window, AdaptiveWindow) \
                and window < 1:
            raise ValueError("window must be >= 1")
        dag.validate()
        completed = set(completed or ())
        own_pool = pool is None
        if pool is None:
            pool = InlinePool()
        try:
            return self._event_loop(dag, runner, completed, on_result, pool,
                                    source, window, keep_results, classify)
        finally:
            if own_pool:
                pool.shutdown()

    # ------------------------------------------------------------------
    def _event_loop(
        self,
        dag: TaskDAG,
        runner: Callable[[TaskNode], Any] | None,
        completed: set[str],
        on_result: Callable[[TaskResult], None] | None,
        pool: WorkerPool,
        source: Any = None,
        window: int | AdaptiveWindow | None = None,
        keep_results: bool = True,
        classify: Callable[[TaskNode, Any], str | None] | None = None,
    ) -> dict[str, TaskResult]:
        streaming = source is not None
        win_ctrl = window if isinstance(window, AdaptiveWindow) else None
        tel = self._telemetry
        if tel is not None:
            # resolve series handles once: armed steady-state cost is a
            # lock + add per event, never a registry lookup
            mtr = tel.metrics
            m_admitted = mtr.counter("papas_nodes_admitted_total")
            m_dispatched = mtr.counter("papas_tasks_dispatched_total")
            m_completed = mtr.counter("papas_tasks_completed_total")
            m_failed = mtr.counter("papas_tasks_failed_total")
            m_skipped = mtr.counter("papas_tasks_skipped_total")
            m_abandoned = mtr.counter("papas_dispatches_abandoned_total")
            m_expired = mtr.counter("papas_dispatches_expired_total")
            g_running = mtr.gauge("papas_tasks_running")
            g_retrying = mtr.gauge("papas_tasks_retrying")
            g_ready = mtr.gauge("papas_ready_depth")
            g_slots = mtr.gauge("papas_slots_busy")
            h_runtime = mtr.histogram("papas_task_runtime_seconds")
        succ = dag.successors()
        indeg = {nid: sum(1 for d in n.deps if d not in completed)
                 for nid, n in dag.nodes.items()}
        results: dict[str, TaskResult] = {}
        resolved_ids: set[str] = set()      # live membership (see _retire)
        n_resolved = 0
        for nid in sorted(dag.nodes):
            if nid in completed:
                resolved_ids.add(nid)
                n_resolved += 1
                if keep_results:
                    results[nid] = TaskResult(
                        id=nid, status="ok", runtime=0.0, started=0.0,
                        finished=0.0, attempts=0, value=None)

        ready = [nid for nid in dag.nodes
                 if nid not in completed and indeg[nid] == 0]
        self._sort_ready(ready)

        #: every admitted node eventually resolves exactly once
        expected = len(dag.nodes)
        exhausted = not streaming
        self.peak_live_nodes = len(dag.nodes)

        failed_closure: set[str] = set()
        attempts: dict[str, int] = {}
        first_started: dict[str, float] = {}
        runtimes = StreamingQuantile(self.straggler_quantile
                                     if self.straggler_quantile is not None
                                     else 0.5)
        free: list[int] = list(range(self.slots))
        heapq.heapify(free)
        running: dict[int, _Dispatch] = {}
        live_tokens: dict[str, set[int]] = {}   # node id → in-flight tokens
        abandoned: dict[int, int] = {}          # zombie token → held slot
        tokens = itertools.count()
        # incremental deadline/straggler tracking: min-heaps with lazy
        # invalidation (an entry whose token left ``running`` is stale),
        # replacing per-event O(running) scans
        deadline_heap: list[tuple[float, int]] = []   # (deadline, token)
        strag_heap: list[tuple[float, int]] = []      # (dispatched, token)
        retry_heap: list[tuple[float, str]] = []      # (due, node id)

        def _mark_failed_closure(root: str) -> None:
            stack = [root]
            while stack:
                cur = stack.pop()
                for s in succ[cur]:
                    if s not in failed_closure:
                        failed_closure.add(s)
                        stack.append(s)

        def _retire(nid: str) -> None:
            # streaming only: a resolved node's TaskNode leaves the live
            # graph so admission can refill the freed window capacity
            if not streaming:
                return
            dag.nodes.pop(nid, None)
            succ.pop(nid, None)
            indeg.pop(nid, None)
            if not keep_results:
                # a retired node can never resolve again (late events die
                # in the ``abandoned`` branch), so its membership record
                # is droppable too — state stays O(slots + window)
                resolved_ids.discard(nid)

        def _resolve(res: TaskResult) -> None:
            nonlocal n_resolved
            resolved_ids.add(res.id)
            n_resolved += 1
            if keep_results:
                results[res.id] = res
            if res.status == "ok":
                runtimes.add(res.runtime)
            if tel is not None:
                if res.status == "ok":
                    m_completed.inc()
                    h_runtime.observe(res.runtime)
                elif res.status == "failed":
                    m_failed.inc()
                else:
                    m_skipped.inc()
            if on_result:
                on_result(res)      # node still live: dag.nodes[res.id] ok
            for s in succ[res.id]:
                indeg[s] -= 1
                if indeg[s] == 0 and s not in resolved_ids:
                    bisect.insort(ready, s, key=self._order_key)
            _retire(res.id)

        pending: list[Any] = []     # fetched sub-DAG awaiting window room

        def _admit(force: bool = False) -> bool:
            """Pull instance sub-DAGs from the source while they fit in
            the ``slots + window`` live-node budget; a fetched sub-DAG
            that does not fit waits in ``pending`` so the bound stays
            strict.  ``force`` admits one batch regardless (progress
            guarantee when the whole budget is smaller than one
            instance).  Returns True when anything was admitted."""
            nonlocal expected, exhausted, n_resolved
            admitted_any = False
            while not (exhausted and not pending):
                if not pending:
                    item = source.next_subdag()
                    if item is None:
                        exhausted = True
                        break
                    pending.append(item)
                nodes, done_ids = pending[0]
                live_after = len(dag.nodes) + sum(
                    1 for n in nodes if n.id not in done_ids)
                wsize = win_ctrl.current if win_ctrl is not None else window
                if live_after > self.slots + wsize and not (
                        force and not admitted_any):
                    break
                pending.pop(0)
                for node in nodes:
                    dag.add(node)
                    succ[node.id] = []
                for node in nodes:
                    for d in node.deps:
                        if d not in succ:
                            raise DAGError(
                                f"streamed node {node.id!r}: dependency "
                                f"{d!r} is outside its instance sub-DAG")
                        succ[d].append(node.id)
                    indeg[node.id] = sum(
                        1 for d in node.deps
                        if d not in done_ids and d not in completed)
                expected += len(nodes)
                admitted_any = True
                if tel is not None:
                    m_admitted.inc(len(nodes))
                for node in nodes:
                    if node.id in done_ids:
                        # already complete (resume): resolved silently,
                        # exactly like eager pre-completed nodes
                        resolved_ids.add(node.id)
                        n_resolved += 1
                        if keep_results:
                            results[node.id] = TaskResult(
                                id=node.id, status="ok", runtime=0.0,
                                started=0.0, finished=0.0, attempts=0)
                        _retire(node.id)
                    elif indeg[node.id] == 0:
                        bisect.insort(ready, node.id, key=self._order_key)
                self.peak_live_nodes = max(self.peak_live_nodes,
                                           len(dag.nodes))
            return admitted_any

        def _abandon(token: int) -> None:
            # The worker may still be busy: the slot stays occupied until
            # the abandoned dispatch's completion event actually arrives,
            # so later work never queues behind a zombie and times out.
            # ``pool.cancel`` lets remote backends kill the dispatch so
            # the *host* resource is released too, not just the slot.
            d = running.pop(token, None)
            if d is None:
                return
            abandoned[token] = d.slot
            if tel is not None:
                m_abandoned.inc()
                g_running.add(-len(d.nids))
                tel.trace.end(f"slot{d.slot}", self.clock(), cat="dispatch",
                              args={"outcome": "abandoned"})
            for nid in d.nids:
                live_tokens.get(nid, set()).discard(token)
            pool.cancel(token)

        def _skip(nid: str) -> None:
            now = self.clock()
            _resolve(TaskResult(
                id=nid, status="skipped", runtime=0.0, started=now,
                finished=now, error="dependency failed"))

        def _dispatch(nids: list[str], speculative: bool) -> None:
            nodes = [dag.nodes[n] for n in nids]
            token = next(tokens)
            slot = heapq.heappop(free)
            now = self.clock()
            # the batch budget is the sum of member timeouts: a gang
            # launch hosting N tasks gets N tasks' worth of wall clock.
            # A member without a timeout leaves the batch unbounded.
            tmos = [self._payload(n).get("timeout") for n in nodes]
            budget = (sum(float(t) for t in tmos)
                      if tmos and all(t for t in tmos) else None)
            deadline = now + budget if budget else None
            if not speculative:
                for nid in nids:
                    attempts[nid] = attempts.get(nid, 0) + 1
            for nid in nids:
                live_tokens.setdefault(nid, set()).add(token)
            running[token] = _Dispatch(token, nids, slot, now, budget,
                                       deadline, speculative)
            if tel is not None:
                m_dispatched.inc(len(nids))
                g_running.add(len(nids))
                g_slots.set(self.slots - len(free))
                g_ready.set(len(ready))
                label = (nids[0] if len(nids) == 1
                         else f"{nodes[0].task} x{len(nids)}")
                tel.trace.begin(
                    f"slot{slot}", label, now, cat="dispatch",
                    args={"tasks": len(nids), "speculative": speculative,
                          "attempt": attempts.get(nids[0], 0)})
            if deadline is not None:
                heapq.heappush(deadline_heap, (deadline, token))
                # lazy-invalidated entries can pile up below a long-lived
                # top; compact when mostly stale so streaming runs keep
                # their O(slots + window) state bound
                if len(deadline_heap) > 2 * len(running) + 16:
                    deadline_heap[:] = [e for e in deadline_heap
                                        if e[1] in running]
                    heapq.heapify(deadline_heap)
            if self.speculate and not speculative and len(nids) == 1:
                heapq.heappush(strag_heap, (now, token))
                if len(strag_heap) > 2 * len(running) + 16:
                    strag_heap[:] = [e for e in strag_heap
                                     if e[1] in running]
                    heapq.heapify(strag_heap)
            pool.submit(token, runner, nodes)

        def _handle_outcome(d: _Dispatch, nid: str, value: Any,
                            error: str | None, started: float,
                            finished: float, host: str | None = None) -> None:
            live_tokens.get(nid, set()).discard(d.token)
            if nid in resolved_ids:     # duplicate copy lost the race
                return
            node = dag.nodes[nid]
            if (error is None and d.budget
                    and (finished - started) > d.budget):
                error = (f"timeout: attempt ran {finished - started:.3f}s, "
                         f"budget {d.budget}s")
            if error is None:
                error = self._classify(node, value)
            if error is None and classify is not None:
                # user-level classifier (e.g. required-capture checks):
                # a crash in it fails the attempt, not the study
                try:
                    error = classify(node, value)
                except Exception as e:  # noqa: BLE001 — fault isolation
                    error = f"classification error: {type(e).__name__}: {e}"
            if error is not None and d.speculative:
                # failed duplicate: the primary still runs — make it a
                # straggler candidate again (its heap entry was consumed
                # when this duplicate launched)
                for t in live_tokens.get(nid, ()):
                    pd = running.get(t)
                    if pd is not None and not pd.speculative \
                            and len(pd.nids) == 1:
                        heapq.heappush(strag_heap, (pd.dispatched, t))
                return
            fs = first_started.setdefault(nid, started)
            if error is not None:
                policy = self._node_retry_policy(node)
                n_attempt = attempts.get(nid, 0)
                if (n_attempt <= policy.retries(self.max_retries)
                        and policy.should_retry(error)):
                    # backoff instead of instant re-insort: a
                    # deterministic sub-millisecond failure must not
                    # burn its whole retry budget in one loop iteration
                    delay = policy.delay(n_attempt, key=nid)
                    if tel is not None:
                        tel.metrics.counter(
                            "papas_retries_total",
                            kind=classify_failure(error)).inc()
                    if delay > 0.0:
                        now_r = self.clock()
                        heapq.heappush(retry_heap, (now_r + delay, nid))
                        if tel is not None:
                            g_retrying.add(1)
                            tel.trace.async_begin(
                                "retry-wait", nid, f"{nid}#{n_attempt}",
                                now_r, args={"delay": delay,
                                             "attempt": n_attempt})
                    else:
                        bisect.insort(ready, nid, key=self._order_key)
                    return
            for t in list(live_tokens.get(nid, ())):
                _abandon(t)         # first finisher wins; drop other copies
            if error is not None:
                _mark_failed_closure(nid)
                _resolve(TaskResult(
                    id=nid, status="failed", runtime=finished - fs,
                    started=fs, finished=finished,
                    attempts=attempts.get(nid, 1), error=error, slot=d.slot,
                    host=host))
            else:
                _resolve(TaskResult(
                    id=nid, status="ok", runtime=finished - fs, started=fs,
                    finished=finished, attempts=attempts.get(nid, 1),
                    value=value, slot=d.slot, speculative=d.speculative,
                    host=host))

        def _expire(d: _Dispatch, now: float) -> None:
            if tel is not None:
                m_expired.inc()
            _abandon(d.token)
            limit = (d.deadline or now) - d.dispatched
            for nid in d.nids:
                _handle_outcome(d, nid, None,
                                f"timeout: no completion within {limit:.3f}s",
                                d.dispatched, now)

        def _strag_elapsed() -> float | None:
            """Elapsed-time cutoff past which a running task counts as a
            straggler: ``straggler_factor × median``, or the tracked
            runtime quantile directly in ``straggler_quantile`` mode."""
            if len(runtimes) < 5:
                return None
            v = runtimes.quantile()
            if v <= 0:
                return None
            if self.straggler_quantile is not None:
                return v
            return self.straggler_factor * v

        while True:
            if win_ctrl is not None:
                win_ctrl.observe(self.clock(), n_resolved)
            _admit()
            if retry_heap:
                # re-queue nodes whose backoff has elapsed
                now = self.clock()
                while retry_heap and retry_heap[0][0] <= now:
                    _, rnid = heapq.heappop(retry_heap)
                    if tel is not None:
                        g_retrying.add(-1)
                        tel.trace.async_end(
                            "retry-wait", rnid,
                            f"{rnid}#{attempts.get(rnid, 0)}", now)
                    if rnid not in resolved_ids:
                        bisect.insort(ready, rnid, key=self._order_key)
            if exhausted and not pending and n_resolved >= expected:
                break
            # resolve failure-closure nodes without occupying slots.
            # Skipped entirely on clean runs: the O(ready) rescan per
            # event was the single largest engine cost at 10^4 tasks.
            if failed_closure:
                while True:
                    doomed = [nid for nid in ready if nid in failed_closure]
                    ready[:] = [nid for nid in ready
                                if nid not in failed_closure
                                and nid not in resolved_ids]
                    if not doomed:
                        break
                    for nid in doomed:
                        if nid not in resolved_ids:
                            _skip(nid)

            while free and ready:
                batch = pool.take(ready, dag)
                if not batch:
                    break
                # a retried node can resolve via a speculative duplicate
                # while its retry entry still sits in ``ready`` — filter
                # at take time instead of rescanning the whole queue
                batch = [nid for nid in batch if nid not in resolved_ids]
                if not batch:
                    continue
                _dispatch(batch, speculative=False)

            # speculative straggler duplicates on leftover slots: pop the
            # earliest-dispatched candidates past the cutoff (entries are
            # lazily invalidated; a consumed-but-still-running primary is
            # re-pushed if its duplicate fails)
            limit = _strag_elapsed() if self.speculate else None
            if limit is not None and free and strag_heap:
                now = self.clock()
                cutoff = now - limit
                while free and strag_heap and strag_heap[0][0] <= cutoff:
                    _, tok = heapq.heappop(strag_heap)
                    d = running.get(tok)
                    if d is None or d.speculative or len(d.nids) != 1:
                        continue    # stale entry
                    nid = d.nids[0]
                    if nid in resolved_ids \
                            or len(live_tokens.get(nid, ())) > 1:
                        continue    # resolved or already duplicated
                    _dispatch([nid], speculative=True)

            if not running and not abandoned:
                if ready:
                    continue
                if retry_heap:
                    # every live node is backing off: advance to the
                    # earliest retry instead of declaring deadlock
                    self._wait_until(retry_heap[0][0])
                    continue
                if _admit(force=True):
                    continue        # window was full of doomed/blocked work
                # nothing running, ready, or admittable → remaining deps
                # unsatisfiable
                for nid in sorted(set(dag.nodes) - resolved_ids):
                    if nid not in resolved_ids:
                        _skip(nid)
                break

            # expire overdue dispatches before (and instead of) waiting —
            # earliest-deadline-first off the heap, not an O(running) scan
            now = self.clock()
            expired_any = False
            while deadline_heap and deadline_heap[0][0] <= now:
                _, tok = heapq.heappop(deadline_heap)
                d = running.get(tok)
                if d is not None:
                    _expire(d, now)
                    expired_any = True
            if expired_any:
                continue

            wait: float | None = None
            horizons = []
            while deadline_heap and deadline_heap[0][1] not in running:
                heapq.heappop(deadline_heap)    # stale: dispatch finished
            if deadline_heap:
                horizons.append(deadline_heap[0][0])
            if retry_heap:
                horizons.append(retry_heap[0][0])
            if limit is not None:
                # earliest still-eligible straggler candidate bounds the
                # next speculation horizon
                while strag_heap:
                    t0s, tok = strag_heap[0]
                    d = running.get(tok)
                    if (d is None or d.speculative or len(d.nids) != 1
                            or len(live_tokens.get(d.nids[0], ())) != 1):
                        heapq.heappop(strag_heap)
                        continue
                    horizons.append(t0s + limit)
                    break
            future = [h for h in horizons if h > now]
            if future:
                wait = max(1e-4, min(future) - now)

            ev = pool.next_event(wait)
            if ev is None:
                continue            # re-check deadlines / stragglers
            if ev.token in abandoned:
                # late completion of a loser/expired copy: the worker is
                # finally idle, so its slot returns to service only now
                heapq.heappush(free, abandoned.pop(ev.token))
                continue
            d = running.pop(ev.token)
            heapq.heappush(free, d.slot)
            if tel is not None:
                g_running.add(-len(d.nids))
                g_slots.set(self.slots - len(free))
                tel.trace.end(f"slot{d.slot}", self.clock(), cat="dispatch",
                              args={"host": ev.host or ""})
            for nid, value, error in zip(d.nids, ev.values, ev.errors):
                _handle_outcome(d, nid, value, error, ev.started, ev.finished,
                                host=ev.host)

        if tel is not None:
            # close any slices a breakout left open (deadlock skip with
            # dispatches still in flight) so every B has its E
            now = self.clock()
            for d in running.values():
                tel.trace.end(f"slot{d.slot}", now, cat="dispatch",
                              args={"outcome": "unresolved"})
            for _, rnid in retry_heap:
                # stale backoff entries (node resolved by a duplicate)
                tel.trace.async_end("retry-wait", rnid,
                                    f"{rnid}#{attempts.get(rnid, 0)}", now)
            g_running.set(0)
            g_slots.set(0)
            g_ready.set(0)
        return results

    # ------------------------------------------------------------------
    def simulate(
        self,
        dag: TaskDAG,
        durations: Mapping[str, float],
        policy: str = "optimal",
        seed: int = 0,
        queue_delay: float = 0.0,
    ) -> list[ScheduleEvent]:
        """Virtual-clock run of the paper's Fig. 1 regimes on the same
        event loop as ``execute`` (a ``VirtualPool`` replaces the live
        backend, so policy orderings carry over to real runs).

        * ``optimal`` — as many slots as jobs; all start at t=0.
        * ``serial``  — one slot, back-to-back.
        * ``common``  — ``self.slots`` slots, random per-dispatch delays
          (models multi-tenant scheduler jitter + queueing).
        * ``grouped`` — ``self.slots`` slots, no dispatch delay (PaPaS
          batched dispatch: one cluster job hosts all tasks).
        """
        dag.validate()
        nslots = {
            "optimal": max(1, len(dag.nodes)),
            "serial": 1,
            "common": self.slots,
            "grouped": self.slots,
        }.get(policy)
        if nslots is None:
            raise ValueError(f"unknown policy {policy!r}")
        rng = random.Random(seed)
        delay_fn = None
        if policy == "common":
            # scheduler interaction cost per dispatch + jitter
            delay_fn = lambda: queue_delay + rng.expovariate(1.0) * queue_delay  # noqa: E731
        clock = VirtualClock()
        pool = VirtualPool(durations, clock, delay_fn=delay_fn)
        engine = Scheduler(slots=nslots, max_retries=0, clock=clock,
                           order="breadth")
        results = engine.execute(dag, runner=None, pool=pool)
        events = [ScheduleEvent(id=r.id, slot=r.slot, start=r.started,
                                stop=r.finished)
                  for r in results.values()]
        events.sort(key=lambda e: (e.start, e.id))
        return events


def makespan(events: list[ScheduleEvent]) -> float:
    return max((e.stop for e in events), default=0.0)


def dispatch_count(events: list[ScheduleEvent]) -> int:
    """Scheduler interactions = one start/stop pair per event."""
    return len(events)
