"""Streaming order statistics shared across the engine.

One dual-heap tracker serves three consumers: the scheduler's straggler
cutoffs (median × factor, or a direct ``straggler_quantile`` such as
p90), the lane pool's adaptive batch controller (median + p90 of
per-frame durations), and the results layer's per-group medians.  All of
them need an O(log n)-insert running quantile over an unbounded sample
stream without retaining a sorted list.

This lives in its own module because ``scheduler`` imports ``executors``
— a tracker defined in either would leave the other unable to import it.
"""
from __future__ import annotations

import heapq

__all__ = ["StreamingQuantile", "StreamingMedian"]


class StreamingQuantile:
    """Running q-quantile over a stream via two heaps.

    ``quantile()`` returns ``sorted(samples)[int(q * n)]`` (clamped to
    the last element) — the same upper-median convention the scheduler
    has always used for q=0.5.  The lower heap (a max-heap of negated
    values) holds the ``int(q*n)`` smallest samples; the upper heap's
    root is the answer.
    """

    __slots__ = ("q", "_lo", "_hi")

    def __init__(self, q: float = 0.5) -> None:
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q!r}")
        self.q = q
        self._lo: list[float] = []      # max-heap (negated): smallest q·n
        self._hi: list[float] = []      # min-heap: the rest; root = answer

    def add(self, x: float) -> None:
        if self._lo and x <= -self._lo[0]:
            heapq.heappush(self._lo, -x)
        else:
            heapq.heappush(self._hi, x)
        n = len(self._lo) + len(self._hi)
        target = min(int(self.q * n), n - 1)
        while len(self._lo) > target:
            heapq.heappush(self._hi, -heapq.heappop(self._lo))
        while len(self._lo) < target:
            heapq.heappush(self._lo, -heapq.heappop(self._hi))

    def quantile(self) -> float:
        if not self._hi:
            raise ValueError("no samples")
        return self._hi[0]

    def __len__(self) -> int:
        return len(self._lo) + len(self._hi)


class StreamingMedian(StreamingQuantile):
    """Backward-compatible running (upper) median."""

    __slots__ = ()

    def __init__(self) -> None:
        super().__init__(0.5)

    def median(self) -> float:
        return self.quantile()
