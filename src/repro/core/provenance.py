"""Per-study provenance database (paper §4.1/§4.2).

A study directory holds: the expanded configuration, one JSONL record per
task attempt (status, runtime, metrics), and the study journal used for
checkpoint/restart.  Plain files — no external DB — keeping the framework
portable and user-space, as the paper requires.  The ``metrics`` field
carries the results subsystem's captured values (WDL ``capture:``, see
``repro.core.results``); ``repro.launch.report`` rebuilds any live
aggregation table offline from this stream.

Like the journal, the record stream supports *group commit*: by default
every ``record`` is an open+write+close (durable per attempt); under the
``group_commit()`` context manager records buffer against one long-lived
handle and flush per batch (``flush_count`` entries / ``flush_interval``
seconds), with two hard guarantees — a non-``ok`` record flushes its
batch immediately (failure forensics never wait), and exiting the
context (normally or via an exception) flushes everything.

For high-rate dispatch the stream can additionally *shard*
(``set_shards``): records round-robin over per-shard append segments
(``records.jsonl`` + ``records.jsonl.s<k>``) so no single buffered
handle serializes completions, and ``records()`` k-way-merges the
segments by timestamp back into one ordered stream.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import json
import os
import threading
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Any, Iterator, Mapping

from .groupcommit import ShardedGroupCommit, iter_jsonl
from .locklint import make_lock


def config_hash(obj: Any) -> str:
    blob = json.dumps(obj, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


class StudyDB:
    """Append-only provenance store for one parameter study."""

    def __init__(self, root: str | Path, study: str, flush_count: int = 1,
                 flush_interval: float | None = None,
                 shards: int = 1) -> None:
        self.dir = Path(root) / study
        self.dir.mkdir(parents=True, exist_ok=True)
        self.records_path = self.dir / "records.jsonl"
        self.meta_path = self.dir / "study.json"
        self._writer = ShardedGroupCommit(self.records_path, flush_count,
                                          flush_interval, shards)
        self._lock = make_lock("studydb")

    def set_shards(self, shards: int) -> None:
        """Split (or re-merge) the record stream across ``shards``
        append segments (``records.jsonl`` + ``records.jsonl.s<k>``) so
        high-rate dispatch never serializes on one buffered handle.
        ``records()`` merges segments by timestamp, so readers see the
        same stream order as the single-handle world."""
        with self._lock:
            self._writer.set_shards(shards)

    # the DB rides along when a bound runner is pickled to a process
    # pool; the lock is process-local state (the writer drops its own
    # handle and buffer — the parent keeps, and flushes, the originals)
    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = make_lock("studydb")

    # -- group-commit machinery ------------------------------------------
    @property
    def n_appends(self) -> int:
        """Records handed to ``record()``."""
        return self._writer.n_appends

    @property
    def n_flushes(self) -> int:
        """Group flushes actually performed."""
        return self._writer.n_flushes

    def flush(self) -> None:
        """Force buffered records to disk now."""
        with self._lock:
            self._writer.flush()

    def close(self) -> None:
        """Flush and release the long-lived record handle."""
        with self._lock:
            self._writer.close()

    @contextmanager
    def group_commit(self, flush_count: int = 64,
                     flush_interval: float | None = 0.2):
        """Batch records for the enclosed block; flush-on-exit holds for
        normal returns and raised exceptions alike."""
        with self._lock:
            prev = self._writer.set_policy(flush_count, flush_interval)
        try:
            yield self
        finally:
            with self._lock:
                self._writer.set_policy(*prev)
                self._writer.close()

    # -- study-level metadata -------------------------------------------
    def write_meta(self, meta: Mapping[str, Any]) -> None:
        tmp = self.meta_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(dict(meta), indent=2, default=str))
        os.replace(tmp, self.meta_path)

    def read_meta(self) -> dict[str, Any]:
        if not self.meta_path.exists():
            return {}
        return json.loads(self.meta_path.read_text())

    # -- task records ----------------------------------------------------
    def record(
        self,
        task_id: str,
        status: str,
        runtime: float,
        combo: Mapping[str, Any] | None = None,
        metrics: Mapping[str, Any] | None = None,
        index: int | None = None,
        **extra: Any,
    ) -> None:
        """Append one attempt record.  ``index`` is the instance's space
        index (streaming runs) — it lets downstream tooling address the
        combination without re-expanding the space."""
        rec = {
            "task_id": task_id,
            "status": status,
            "runtime": runtime,
            "combo": dict(combo) if combo else None,
            "metrics": dict(metrics) if metrics else None,
            "timestamp": time.time(),
            **extra,
        }
        if index is not None:
            rec["index"] = int(index)
        line = json.dumps(rec, default=str, separators=(",", ":")) + "\n"
        with self._lock:
            # a failed attempt flushes its whole batch immediately:
            # post-mortems must never wait on a group-commit window
            self._writer.append(line, force=status != "ok")

    def records(self) -> Iterator[dict[str, Any]]:
        self.flush()
        paths = self._writer.segment_paths()
        if not paths:
            return iter(())

        def _it(path: Path) -> Iterator[dict[str, Any]]:
            # corruption-tolerant: a torn tail (crash mid-write) warns
            # and drops that record instead of refusing the whole DB
            yield from iter_jsonl(path, "provenance")
        if len(paths) == 1:
            return _it(paths[0])
        # per-segment streams are timestamp-ordered (appends are
        # monotonic within a shard), so a k-way merge restores the
        # global stream order of the single-handle world — later
        # attempts still shadow earlier ones for every reader
        return heapq.merge(*(_it(p) for p in paths),
                           key=lambda r: r.get("timestamp") or 0.0)

    def completed_ids(self) -> set[str]:
        return {r["task_id"] for r in self.records() if r["status"] == "ok"}

    def completed_indices(self) -> dict[str, set[int]]:
        """Task name → completed instance space indices (streaming runs
        record the index per attempt; eager records carry none)."""
        out: dict[str, set[int]] = {}
        for r in self.records():
            if r["status"] == "ok" and r.get("index") is not None:
                task = r["task_id"].partition("@")[0]
                out.setdefault(task, set()).add(int(r["index"]))
        return out

    def shard_counters(self) -> list[dict[str, Any]]:
        """Per-segment group-commit counters (telemetry snapshot)."""
        return self._writer.shard_counters()

    # -- profiler summary --------------------------------------------------
    def runtime_summary(self, by: str | None = None) -> dict[str, Any]:
        """Runtime statistics over the ok records.

        ``by=None`` (default) returns one whole-study summary dict;
        ``by="task"`` / ``by="host"`` returns ``{group: summary}`` —
        the per-task / per-host table ``launch/report.py`` renders.
        """
        if by is None:
            return _times_summary(
                [r["runtime"] for r in self.records()
                 if r["status"] == "ok"])
        if by not in ("task", "host"):
            raise ValueError(f"runtime_summary by must be 'task' or "
                             f"'host', got {by!r}")
        groups: dict[str, list[float]] = {}
        for r in self.records():
            if r["status"] != "ok":
                continue
            key = (r["task_id"].partition("@")[0] if by == "task"
                   else str(r.get("host") or "local"))
            groups.setdefault(key, []).append(r["runtime"])
        return {k: _times_summary(v) for k, v in sorted(groups.items())}


def _times_summary(times: list[float]) -> dict[str, Any]:
    if not times:
        return {"count": 0}
    times.sort()
    return {
        "count": len(times),
        "total": sum(times),
        "min": times[0],
        "median": times[len(times) // 2],
        "max": times[-1],
    }
