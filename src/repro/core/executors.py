"""Execution backends (paper §4.3 cluster engine, adapted to TPU).

The paper's cluster engine groups many small user jobs into one cluster
allocation (MPI task dispatcher).  On SPMD TPU hardware the same insight
maps to three backends:

* ``serial``      — one task at a time (the paper's *serial* regime).
* ``subprocess``  — black-box shell tasks (`command:` keyword), with env
  propagation; parity with the paper's process dispatcher.
* ``gang``        — group stackable instances and run each group through
  a single callable (the vmap-stack / mesh-slice pack).  The JAX-level
  packing itself lives in ``repro.train.ensemble``; this layer only does
  the grouping, dispatch accounting, and result scatter.
"""
from __future__ import annotations

import dataclasses
import os
import shlex
import subprocess
import time
from typing import Any, Callable, Hashable, Mapping, Sequence

from .dag import TaskNode


@dataclasses.dataclass
class ShellResult:
    returncode: int
    stdout: str
    stderr: str
    runtime: float


def run_subprocess(
    command: str,
    env: Mapping[str, str] | None = None,
    timeout: float | None = None,
    cwd: str | None = None,
) -> ShellResult:
    """Run one black-box task; measures runtime (the paper's task
    profiler: "the application is not mandated to have an internal
    timer")."""
    full_env = dict(os.environ)
    if env:
        full_env.update({k: str(v) for k, v in env.items()})
    t0 = time.monotonic()
    proc = subprocess.run(
        shlex.split(command),
        capture_output=True,
        text=True,
        env=full_env,
        timeout=timeout,
        cwd=cwd,
        check=False,
    )
    t1 = time.monotonic()
    if proc.returncode != 0:
        raise RuntimeError(
            f"command failed ({proc.returncode}): {command!r}\n{proc.stderr[-2000:]}"
        )
    return ShellResult(proc.returncode, proc.stdout, proc.stderr, t1 - t0)


# ---------------------------------------------------------------------------
# Gang packing
# ---------------------------------------------------------------------------

GroupKeyFn = Callable[[TaskNode], Hashable]
GangRunner = Callable[[Sequence[TaskNode]], Sequence[Any]]


@dataclasses.dataclass
class GangStats:
    """Dispatch accounting — the quantity the paper's Figs. 3/4 compare."""

    groups: int = 0
    tasks: int = 0
    dispatches: int = 0  # one per compiled-program launch

    @property
    def batching_factor(self) -> float:
        return self.tasks / max(1, self.dispatches)


class GangExecutor:
    """Group task instances by a stackability key and dispatch each group
    once.  One dispatch per group is the TPU analogue of "grouping
    intra/inter-workflow tasks as a single batch job" (paper §4.3)."""

    def __init__(self, group_key: GroupKeyFn, gang_runner: GangRunner,
                 max_group: int | None = None) -> None:
        self.group_key = group_key
        self.gang_runner = gang_runner
        self.max_group = max_group
        self.stats = GangStats()

    def run(self, nodes: Sequence[TaskNode]) -> dict[str, Any]:
        groups: dict[Hashable, list[TaskNode]] = {}
        for n in nodes:
            groups.setdefault(self.group_key(n), []).append(n)
        results: dict[str, Any] = {}
        for _, members in sorted(groups.items(), key=lambda kv: str(kv[0])):
            chunks = (
                [members[i:i + self.max_group]
                 for i in range(0, len(members), self.max_group)]
                if self.max_group else [members]
            )
            for chunk in chunks:
                values = self.gang_runner(chunk)
                if len(values) != len(chunk):
                    raise RuntimeError(
                        f"gang runner returned {len(values)} results for "
                        f"{len(chunk)} tasks")
                for node, value in zip(chunk, values):
                    results[node.id] = value
                self.stats.groups += 1
                self.stats.dispatches += 1
                self.stats.tasks += len(chunk)
        return results


def stackable_key(node: TaskNode) -> Hashable:
    """Default stackability: nodes of the same task whose combos share
    the same *keys* (values may differ — they become per-member arrays).
    Shape-affecting parameters must be embedded in the task name by the
    study author (or use mesh-slice instead)."""
    return (node.task, tuple(sorted(node.combo.keys())))
