"""Worker pools — the execution backends behind the unified engine.

The scheduler (``repro.core.scheduler``) is a single slot-occupancy event
loop; everything backend-specific lives here behind the ``WorkerPool``
interface.  A pool decides *which* ready nodes to claim (``take``), runs
them (``submit``), and reports completions (``next_event``) — the paper's
"cluster engine" (§4.3) reduced to three methods.  Backends:

* ``InlinePool``   — runs each task synchronously at dispatch time.
  Fully deterministic; the default for tests and small studies.
* ``ThreadWorkerPool``  — ``concurrent.futures`` thread pool; real wall-
  clock parallelism for I/O- and subprocess-bound tasks.
* ``ProcessWorkerPool`` — process pool for CPU-bound Python tasks
  (runner and nodes must be picklable).
* ``GangPool``     — batched dispatch: claims a whole stackability group
  from the ready queue and launches it as ONE program (the paper's
  single-cluster-job technique, §4.3).  Wraps a ``GangExecutor``.

``run_subprocess`` runs black-box shell tasks and always returns a
``ShellResult`` — a nonzero exit is *data*, classified by the scheduler's
retry/failure-closure logic (respecting the task's ``allow_nonzero``
keyword), not an exception.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import shlex
import subprocess
import time
from collections import deque
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Any, Callable, Hashable, Mapping, Sequence, TYPE_CHECKING

from .dag import TaskNode

if TYPE_CHECKING:  # pragma: no cover
    from .dag import TaskDAG


@dataclasses.dataclass
class ShellResult:
    returncode: int
    stdout: str
    stderr: str
    runtime: float

    @property
    def ok(self) -> bool:
        return self.returncode == 0


def merged_env(env: Mapping[str, str] | None) -> dict[str, str]:
    """The task environment: the ambient process env overlaid with the
    instance's rendered variables (paper §5 ``environ``)."""
    full_env = dict(os.environ)
    if env:
        full_env.update({k: str(v) for k, v in env.items()})
    return full_env


def run_subprocess(
    command: str,
    env: Mapping[str, str] | None = None,
    timeout: float | None = None,
    cwd: str | None = None,
    shell: bool = False,
) -> ShellResult:
    """Run one black-box task; measures runtime (the paper's task
    profiler: "the application is not mandated to have an internal
    timer").

    Always returns a ``ShellResult`` — including on nonzero exit.  The
    scheduler classifies the returncode (see ``Scheduler._classify``),
    so retries and failure closure apply uniformly to shell tasks.  A
    ``timeout`` propagates to ``subprocess.run``; expiry raises
    ``subprocess.TimeoutExpired``, which the scheduler records as a
    failed attempt.  ``shell=True`` runs the command through ``sh -c``
    (pipes/redirects honored) instead of splitting it into argv.
    """
    t0 = time.monotonic()
    proc = subprocess.run(
        ["sh", "-c", command] if shell else shlex.split(command),
        capture_output=True,
        text=True,
        env=merged_env(env),
        timeout=timeout,
        cwd=cwd,
        check=False,
    )
    t1 = time.monotonic()
    return ShellResult(proc.returncode, proc.stdout, proc.stderr, t1 - t0)


# ---------------------------------------------------------------------------
# Worker pools
# ---------------------------------------------------------------------------

#: runner signature shared by every pool: one node in, one value out.
Runner = Callable[[TaskNode], Any]


@dataclasses.dataclass
class CompletionEvent:
    """One finished dispatch: per-node outcomes plus true start/stop."""

    token: int
    values: list[Any]             # aligned with the dispatched nodes
    errors: list[str | None]      # non-None marks that node's attempt failed
    started: float
    finished: float
    host: str | None = None       # executing host / allocation (remote pools)


def _run_nodes(runner: Runner, nodes: Sequence[TaskNode]
               ) -> tuple[list[Any], list[str | None], float, float]:
    """Worker-side body: run each node, capture per-node exceptions, and
    measure true occupancy with a clock local to the worker."""
    t0 = time.monotonic()
    values: list[Any] = []
    errors: list[str | None] = []
    for node in nodes:
        try:
            values.append(runner(node))
            errors.append(None)
        except Exception as e:  # noqa: BLE001 — fault isolation
            values.append(None)
            errors.append(f"{type(e).__name__}: {e}")
    t1 = time.monotonic()
    return values, errors, t0, t1


class WorkerPool:
    """Backend interface for the scheduler's event loop."""

    kind = "base"

    @property
    def dispatch_slots(self) -> int:
        """How many concurrent dispatches the scheduler should drive.
        Defaults to the pool's slot count (one task per dispatch);
        grouped backends (batch allocations) override this — each
        dispatch already hosts a whole group, so driving ``slots``
        dispatches would over-subscribe the declared capacity."""
        return int(getattr(self, "slots", 1) or 1)

    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        """Claim the next batch of node ids from the (sorted) ready
        queue, removing them.  Default: one node per dispatch."""
        return [ready.pop(0)]

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        raise NotImplementedError

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        """Block for the next completion; ``None`` signals the timeout
        elapsed (the loop then checks deadlines and stragglers)."""
        raise NotImplementedError

    def cancel(self, token: int) -> None:
        """Release backend resources held by an abandoned dispatch (a
        speculative duplicate that lost the race, or an expired
        attempt).  The pool must still deliver a completion event for
        the token so the scheduler can return its slot to service.
        Default: no-op — local pools just let the worker finish."""

    def shutdown(self) -> None:
        pass

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()


class _SyncPool(WorkerPool):
    """Base for synchronous backends: ``submit`` runs the batch in place
    and queues its event, so completions arrive in dispatch order."""

    def __init__(self) -> None:
        self._events: deque[CompletionEvent] = deque()

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]
                   ) -> tuple[list[Any], list[str | None], float, float]:
        raise NotImplementedError

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        values, errors, t0, t1 = self._run_batch(runner, nodes)
        self._events.append(CompletionEvent(token, values, errors, t0, t1))

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        return self._events.popleft() if self._events else None


class InlinePool(_SyncPool):
    """Synchronous per-node backend — deterministic; the default."""

    kind = "inline"

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]):
        return _run_nodes(runner, nodes)


class _FuturePool(WorkerPool):
    """Shared machinery for executor-backed pools: completions funnel
    through a queue fed by done-callbacks."""

    def __init__(self, slots: int) -> None:
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self._q: "queue.Queue[CompletionEvent]" = queue.Queue()
        self._ex = self._make_executor(slots)

    def _make_executor(self, slots: int):  # pragma: no cover - abstract
        raise NotImplementedError

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        fut = self._ex.submit(_run_nodes, runner, list(nodes))
        n = len(nodes)
        fut.add_done_callback(lambda f, t=token, k=n: self._collect(t, k, f))

    def _collect(self, token: int, n: int, fut: Any) -> None:
        if fut.cancelled():
            return      # shutdown cancelled it before it ever ran
        exc = fut.exception()
        if exc is not None:
            now = time.monotonic()
            msg = f"{type(exc).__name__}: {exc}"
            ev = CompletionEvent(token, [None] * n, [msg] * n, now, now)
        else:
            values, errors, t0, t1 = fut.result()
            ev = CompletionEvent(token, values, errors, t0, t1)
        self._q.put(ev)

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def shutdown(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


class ThreadWorkerPool(_FuturePool):
    """Thread-pool backend: true wall-clock overlap for subprocess- and
    I/O-bound tasks (and anything releasing the GIL)."""

    kind = "thread"

    def _make_executor(self, slots: int) -> ThreadPoolExecutor:
        return ThreadPoolExecutor(max_workers=slots,
                                  thread_name_prefix="papas-slot")


class ProcessWorkerPool(_FuturePool):
    """Process-pool backend for CPU-bound Python tasks.  The runner and
    every node (including payloads) must be picklable."""

    kind = "process"

    def _make_executor(self, slots: int) -> ProcessPoolExecutor:
        return ProcessPoolExecutor(max_workers=slots)


#: every kind ``make_pool`` accepts (remote kinds live in ``remote.py``).
VALID_POOL_KINDS = ("inline", "thread", "process", "ssh", "slurm", "pbs")


def make_pool(kind: str, slots: int = 1, **remote_kwargs: Any) -> WorkerPool:
    """Construct a pool by name.

    Local kinds: ``inline``, ``thread``, ``process`` (``slots``
    workers).  Remote kinds: ``ssh`` (requires ``hosts``; optional
    ``ppnode``, ``transport``, ``render``) and ``slurm`` / ``pbs``
    (optional ``nnodes``, ``ppnode``, ``submitter``, ``render``,
    ``spool_root``) — their slot count is ``hosts × ppnode`` /
    ``nnodes × ppnode``, not ``slots``.  An unknown kind raises a
    ``ValueError`` naming every valid kind.
    """
    if kind == "inline":
        return InlinePool()
    if kind == "thread":
        return ThreadWorkerPool(slots)
    if kind == "process":
        return ProcessWorkerPool(slots)
    if kind == "ssh":
        from .remote import SSHWorkerPool

        hosts = remote_kwargs.pop("hosts", None)
        if not hosts:
            raise ValueError(
                "pool kind 'ssh' requires a non-empty host list "
                "(WDL 'hosts:' keyword or --hosts)")
        remote_kwargs.pop("nnodes", None)
        remote_kwargs.pop("submitter", None)
        remote_kwargs.pop("spool_root", None)
        return SSHWorkerPool(
            hosts, ppnode=remote_kwargs.pop("ppnode", None) or 1,
            **remote_kwargs)
    if kind in ("slurm", "pbs"):
        from .remote import BatchWorkerPool

        remote_kwargs.pop("hosts", None)
        remote_kwargs.pop("transport", None)
        return BatchWorkerPool(
            batch=kind,
            nnodes=remote_kwargs.pop("nnodes", None) or 1,
            ppnode=remote_kwargs.pop("ppnode", None) or 1,
            **remote_kwargs)
    raise ValueError(
        f"unknown pool kind {kind!r}; valid kinds: "
        + ", ".join(VALID_POOL_KINDS))


# ---------------------------------------------------------------------------
# Gang packing
# ---------------------------------------------------------------------------

GroupKeyFn = Callable[[TaskNode], Hashable]
GangRunner = Callable[[Sequence[TaskNode]], Sequence[Any]]


@dataclasses.dataclass
class GangStats:
    """Dispatch accounting — the quantity the paper's Figs. 3/4 compare."""

    groups: int = 0
    tasks: int = 0
    dispatches: int = 0  # one per compiled-program launch

    @property
    def batching_factor(self) -> float:
        return self.tasks / max(1, self.dispatches)


class GangExecutor:
    """Group task instances by a stackability key and dispatch each group
    once.  One dispatch per group is the TPU analogue of "grouping
    intra/inter-workflow tasks as a single batch job" (paper §4.3)."""

    def __init__(self, group_key: GroupKeyFn, gang_runner: GangRunner,
                 max_group: int | None = None) -> None:
        self.group_key = group_key
        self.gang_runner = gang_runner
        self.max_group = max_group
        self.stats = GangStats()

    def run_group(self, chunk: Sequence[TaskNode]) -> list[Any]:
        """Dispatch one stackable chunk as a single program launch."""
        values = list(self.gang_runner(chunk))
        if len(values) != len(chunk):
            raise RuntimeError(
                f"gang runner returned {len(values)} results for "
                f"{len(chunk)} tasks")
        self.stats.groups += 1
        self.stats.dispatches += 1
        self.stats.tasks += len(chunk)
        return values

    def run(self, nodes: Sequence[TaskNode]) -> dict[str, Any]:
        """Group and dispatch a node set directly (no scheduler)."""
        groups: dict[Hashable, list[TaskNode]] = {}
        for n in nodes:
            groups.setdefault(self.group_key(n), []).append(n)
        results: dict[str, Any] = {}
        for _, members in sorted(groups.items(), key=lambda kv: str(kv[0])):
            chunks = (
                [members[i:i + self.max_group]
                 for i in range(0, len(members), self.max_group)]
                if self.max_group else [members]
            )
            for chunk in chunks:
                for node, value in zip(chunk, self.run_group(chunk)):
                    results[node.id] = value
        return results


class GangPool(_SyncPool):
    """Gang dispatch as a pool policy: ``take`` claims an entire
    stackability group from the ready queue and ``submit`` launches it as
    one program.  Replaces the old separate level-synchronous loop — gang
    studies now share the scheduler's retry/closure/journal machinery."""

    kind = "gang"

    def __init__(self, gang: GangExecutor) -> None:
        super().__init__()
        self.gang = gang

    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        groups: dict[str, list[str]] = {}
        for nid in ready:
            groups.setdefault(str(self.gang.group_key(dag.nodes[nid])),
                              []).append(nid)
        members = groups[sorted(groups)[0]]
        if self.gang.max_group:
            members = members[: self.gang.max_group]
        for nid in members:
            ready.remove(nid)
        return members

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]):
        t0 = time.monotonic()
        try:
            values = self.gang.run_group(nodes)
            errors: list[str | None] = [None] * len(nodes)
        except Exception as e:  # noqa: BLE001 — whole-batch failure
            msg = f"{type(e).__name__}: {e}"
            values = [None] * len(nodes)
            errors = [msg] * len(nodes)
        t1 = time.monotonic()
        return values, errors, t0, t1


def stackable_key(node: TaskNode) -> Hashable:
    """Default stackability: nodes of the same task whose combos share
    the same *keys* (values may differ — they become per-member arrays).
    Shape-affecting parameters must be embedded in the task name by the
    study author (or use mesh-slice instead)."""
    return (node.task, tuple(sorted(node.combo.keys())))
