"""Worker pools — the execution backends behind the unified engine.

The scheduler (``repro.core.scheduler``) is a single slot-occupancy event
loop; everything backend-specific lives here behind the ``WorkerPool``
interface.  A pool decides *which* ready nodes to claim (``take``), runs
them (``submit``), and reports completions (``next_event``) — the paper's
"cluster engine" (§4.3) reduced to three methods.  Backends:

* ``InlinePool``   — runs each task synchronously at dispatch time.
  Fully deterministic; the default for tests and small studies.
* ``ThreadWorkerPool``  — ``concurrent.futures`` thread pool; real wall-
  clock parallelism for I/O- and subprocess-bound tasks.
* ``ProcessWorkerPool`` — process pool for CPU-bound Python tasks
  (runner and nodes must be picklable).
* ``GangPool``     — batched dispatch: claims a whole stackability group
  from the ready queue and launches it as ONE program (the paper's
  single-cluster-job technique, §4.3).  Wraps a ``GangExecutor``.
* ``LaneWorkerPool`` — the short-task throughput path: one long-lived
  ``sh`` worker per slot, fed rendered commands over a pipe protocol.
  Process spawn is amortized across thousands of tasks (a shell builtin
  like ``true`` never forks at all), ``take`` claims gang-style chunks
  so one pipe write carries a whole batch, and per-task environment
  overlays ride the command line — no per-task ``os.environ`` copy.
  Its ``run_gang`` method is a drop-in ``GangRunner``, so a
  ``GangExecutor``/``GangPool`` can fuse its batches onto the lanes.

``run_subprocess`` runs black-box shell tasks and always returns a
``ShellResult`` — a nonzero exit is *data*, classified by the scheduler's
retry/failure-closure logic (respecting the task's ``allow_nonzero``
keyword), not an exception.  ``merged_env`` accepts a pre-snapshotted
base environment so a pool or run copies ``os.environ`` once, not once
per task.
"""
from __future__ import annotations

import dataclasses
import itertools
import locale
import os
import queue
import re
import select
import selectors
import shlex
import shutil
import signal
import subprocess
import tempfile
import threading
import time
from collections import deque
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Callable, Hashable, Mapping, Sequence, TYPE_CHECKING

from . import chaos
from . import telemetry
from .dag import TaskNode
from .locklint import make_lock

if TYPE_CHECKING:  # pragma: no cover
    from .dag import TaskDAG


@dataclasses.dataclass
class ShellResult:
    returncode: int
    stdout: str
    stderr: str
    runtime: float

    @property
    def ok(self) -> bool:
        return self.returncode == 0


def merged_env(env: Mapping[str, str] | None,
               base: Mapping[str, str] | None = None) -> dict[str, str]:
    """The task environment: the ambient process env overlaid with the
    instance's rendered variables (paper §5 ``environ``).

    ``base`` is an optional pre-snapshotted ambient environment — pools
    and runs capture ``dict(os.environ)`` once and pass it here, so the
    per-task cost is one small dict copy instead of a full environ walk.
    """
    full_env = dict(base) if base is not None else dict(os.environ)
    if env:
        full_env.update({k: str(v) for k, v in env.items()})
    return full_env


#: whether the vfork-based fast spawn path is available on this platform
_HAS_POSIX_SPAWN = hasattr(os, "posix_spawnp") and hasattr(os, "pipe")


def _decode_text(data: bytes) -> str:
    """Match ``subprocess.run(text=True)``: locale decode + universal
    newline translation."""
    text = data.decode(locale.getpreferredencoding(False))
    return text.replace("\r\n", "\n").replace("\r", "\n")


def _posix_spawn_capture(argv: list[str], env: dict[str, str],
                         timeout: float | None) -> ShellResult:
    """The spawn-elimination fast path: ``os.posix_spawnp`` (vfork-based
    on glibc — no page-table copy of the Python interpreter) with two
    capture pipes drained by a ``select`` loop.  Raises
    ``FileNotFoundError`` for a missing binary and
    ``subprocess.TimeoutExpired`` on expiry, matching
    ``subprocess.run``."""
    r_out, w_out = os.pipe()
    r_err, w_err = os.pipe()
    t0 = time.monotonic()
    try:
        pid = os.posix_spawnp(argv[0], argv, env, file_actions=[
            (os.POSIX_SPAWN_DUP2, w_out, 1),
            (os.POSIX_SPAWN_DUP2, w_err, 2),
            (os.POSIX_SPAWN_CLOSE, r_out),
            (os.POSIX_SPAWN_CLOSE, r_err),
        ])
    except BaseException:
        for fd in (r_out, r_err, w_out, w_err):
            os.close(fd)
        raise
    os.close(w_out)
    os.close(w_err)
    bufs = {r_out: bytearray(), r_err: bytearray()}
    open_fds = [r_out, r_err]
    deadline = t0 + timeout if timeout else None
    try:
        while open_fds:
            if deadline is not None:
                wait = deadline - time.monotonic()
                rlist = (select.select(open_fds, [], [], wait)[0]
                         if wait > 0 else [])
                if not rlist:
                    try:
                        os.kill(pid, signal.SIGKILL)
                    except ProcessLookupError:
                        pass
                    os.waitpid(pid, 0)
                    raise subprocess.TimeoutExpired(
                        argv, timeout, output=bytes(bufs[r_out]),
                        stderr=bytes(bufs[r_err]))
            else:
                rlist = select.select(open_fds, [], [])[0]
            for fd in rlist:
                chunk = os.read(fd, 65536)
                if chunk:
                    bufs[fd] += chunk
                else:
                    open_fds.remove(fd)
                    os.close(fd)
    finally:
        for fd in open_fds:
            os.close(fd)
    _, status = os.waitpid(pid, 0)
    rc = os.waitstatus_to_exitcode(status)
    t1 = time.monotonic()
    return ShellResult(rc, _decode_text(bytes(bufs[r_out])),
                       _decode_text(bytes(bufs[r_err])), t1 - t0)


def run_subprocess(
    command: str,
    env: Mapping[str, str] | None = None,
    timeout: float | None = None,
    cwd: str | None = None,
    shell: bool = False,
    base_env: Mapping[str, str] | None = None,
    spawn: str = "auto",
) -> ShellResult:
    """Run one black-box task; measures runtime (the paper's task
    profiler: "the application is not mandated to have an internal
    timer").

    Always returns a ``ShellResult`` — including on nonzero exit.  The
    scheduler classifies the returncode (see ``Scheduler._classify``),
    so retries and failure closure apply uniformly to shell tasks.  A
    ``timeout`` bounds the attempt; expiry raises
    ``subprocess.TimeoutExpired``, which the scheduler records as a
    failed attempt.  ``shell=True`` runs the command through ``sh -c``
    (pipes/redirects honored) instead of splitting it into argv.
    ``base_env`` is the run-level ambient environment snapshot forwarded
    to ``merged_env`` (None: snapshot ``os.environ`` per call).

    ``spawn`` selects the process-creation path: ``"auto"`` (default)
    uses ``os.posix_spawnp`` — vfork-based, no fork of the Python
    interpreter's address space — whenever the platform has it and no
    ``cwd`` is requested (``posix_spawn`` has no portable chdir file
    action), falling back to ``subprocess.run`` otherwise; ``"posix"``
    and ``"popen"`` force one path (benchmarks measure them against
    each other)."""
    argv = ["sh", "-c", command] if shell else shlex.split(command)
    if (spawn != "popen" and _HAS_POSIX_SPAWN and cwd is None and argv):
        return _posix_spawn_capture(argv, merged_env(env, base_env), timeout)
    if spawn == "posix":
        raise RuntimeError("posix spawn path unavailable "
                           "(no posix_spawnp, empty argv, or cwd set)")
    t0 = time.monotonic()
    proc = subprocess.run(
        argv,
        capture_output=True,
        text=True,
        env=merged_env(env, base_env),
        timeout=timeout,
        cwd=cwd,
        check=False,
    )
    t1 = time.monotonic()
    return ShellResult(proc.returncode, proc.stdout, proc.stderr, t1 - t0)


# ---------------------------------------------------------------------------
# Worker pools
# ---------------------------------------------------------------------------

#: runner signature shared by every pool: one node in, one value out.
Runner = Callable[[TaskNode], Any]


@dataclasses.dataclass
class CompletionEvent:
    """One finished dispatch: per-node outcomes plus true start/stop."""

    token: int
    values: list[Any]             # aligned with the dispatched nodes
    errors: list[str | None]      # non-None marks that node's attempt failed
    started: float
    finished: float
    host: str | None = None       # executing host / allocation (remote pools)


def _run_nodes(runner: Runner, nodes: Sequence[TaskNode]
               ) -> tuple[list[Any], list[str | None], float, float]:
    """Worker-side body: run each node, capture per-node exceptions, and
    measure true occupancy with a clock local to the worker."""
    t0 = time.monotonic()
    values: list[Any] = []
    errors: list[str | None] = []
    for node in nodes:
        try:
            values.append(runner(node))
            errors.append(None)
        except Exception as e:  # noqa: BLE001 — fault isolation
            values.append(None)
            errors.append(f"{type(e).__name__}: {e}")
    t1 = time.monotonic()
    return values, errors, t0, t1


class WorkerPool:
    """Backend interface for the scheduler's event loop."""

    kind = "base"

    #: whether ``CompletionEvent.host`` names a durable location worth
    #: folding into the journal's per-task host map (remote pools: yes).
    #: Pools whose hosts are transient local labels (worker lanes) keep
    #: host provenance in the per-attempt records only — a 10^5-task
    #: windowed run must not grow an O(N_W) journal host map out of
    #: lane indices.
    durable_hosts = True

    @property
    def dispatch_slots(self) -> int:
        """How many concurrent dispatches the scheduler should drive.
        Defaults to the pool's slot count (one task per dispatch);
        grouped backends (batch allocations) override this — each
        dispatch already hosts a whole group, so driving ``slots``
        dispatches would over-subscribe the declared capacity."""
        return int(getattr(self, "slots", 1) or 1)

    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        """Claim the next batch of node ids from the (sorted) ready
        queue, removing them.  Default: one node per dispatch."""
        return [ready.pop(0)]

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        raise NotImplementedError

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        """Block for the next completion; ``None`` signals the timeout
        elapsed (the loop then checks deadlines and stragglers)."""
        raise NotImplementedError

    def cancel(self, token: int) -> None:
        """Release backend resources held by an abandoned dispatch (a
        speculative duplicate that lost the race, or an expired
        attempt).  The pool must still deliver a completion event for
        the token so the scheduler can return its slot to service.
        Default: no-op — local pools just let the worker finish."""

    def shutdown(self) -> None:
        pass

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()


class _SyncPool(WorkerPool):
    """Base for synchronous backends: ``submit`` runs the batch in place
    and queues its event, so completions arrive in dispatch order."""

    def __init__(self) -> None:
        self._events: deque[CompletionEvent] = deque()

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]
                   ) -> tuple[list[Any], list[str | None], float, float]:
        raise NotImplementedError

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        values, errors, t0, t1 = self._run_batch(runner, nodes)
        self._events.append(CompletionEvent(token, values, errors, t0, t1))

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        return self._events.popleft() if self._events else None


class InlinePool(_SyncPool):
    """Synchronous per-node backend — deterministic; the default."""

    kind = "inline"

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]):
        return _run_nodes(runner, nodes)


class _FuturePool(WorkerPool):
    """Shared machinery for executor-backed pools: completions funnel
    through a queue fed by done-callbacks."""

    def __init__(self, slots: int) -> None:
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self._q: "queue.Queue[CompletionEvent]" = queue.Queue()
        self._ex = self._make_executor(slots)

    def _make_executor(self, slots: int):  # pragma: no cover - abstract
        raise NotImplementedError

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        fut = self._ex.submit(_run_nodes, runner, list(nodes))
        n = len(nodes)
        fut.add_done_callback(lambda f, t=token, k=n: self._collect(t, k, f))

    def _collect(self, token: int, n: int, fut: Any) -> None:
        if fut.cancelled():
            return      # shutdown cancelled it before it ever ran
        exc = fut.exception()
        if exc is not None:
            now = time.monotonic()
            msg = f"{type(exc).__name__}: {exc}"
            ev = CompletionEvent(token, [None] * n, [msg] * n, now, now)
        else:
            values, errors, t0, t1 = fut.result()
            ev = CompletionEvent(token, values, errors, t0, t1)
        self._q.put(ev)

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def shutdown(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


class ThreadWorkerPool(_FuturePool):
    """Thread-pool backend: true wall-clock overlap for subprocess- and
    I/O-bound tasks (and anything releasing the GIL)."""

    kind = "thread"

    def _make_executor(self, slots: int) -> ThreadPoolExecutor:
        return ThreadPoolExecutor(max_workers=slots,
                                  thread_name_prefix="papas-slot")


class ProcessWorkerPool(_FuturePool):
    """Process-pool backend for CPU-bound Python tasks.  The runner and
    every node (including payloads) must be picklable."""

    kind = "process"

    def _make_executor(self, slots: int) -> ProcessPoolExecutor:
        return ProcessPoolExecutor(max_workers=slots)


# ---------------------------------------------------------------------------
# Persistent worker lanes (short-task throughput path)
# ---------------------------------------------------------------------------

#: renders one node to its shell form: ``node -> (command | None, env)``.
LaneRenderFn = Callable[[TaskNode], "tuple[str | None, Mapping[str, Any]]"]

_ENV_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")


def _sq(s: str) -> str:
    """POSIX single-quote."""
    return "'" + s.replace("'", "'\\''") + "'"


class _LaneJob:
    """One claimed chunk in flight on a lane (mux-internal)."""

    __slots__ = ("token", "nodes", "values", "errors", "stanzas", "spools",
                 "pending", "t0", "stalls", "cycle_len", "ends",
                 "head_started", "head_deadline")

    def __init__(self, token: int, nodes: list[TaskNode]) -> None:
        self.token = token
        self.nodes = nodes
        n = len(nodes)
        self.values: list[Any] = [None] * n
        self.errors: list[str | None] = ["lane batch aborted"] * n
        self.stanzas: dict[int, tuple[str, float | None]] = {}
        self.spools: dict[int, Path] = {}
        self.pending: list[int] = []
        self.t0 = 0.0
        self.stalls = 0
        self.cycle_len = 0
        #: absolute per-lane flush offsets marking each stanza's end —
        #: a head deadline arms only once its stanza fully left the pipe
        self.ends: dict[int, int] = {}
        self.head_started = 0.0
        self.head_deadline: float | None = None


class _Lane:
    """One persistent worker shell multiplexed by the mux thread."""

    __slots__ = ("idx", "proc", "buf", "outbox", "job", "dying",
                 "death_msg", "want_write", "flushed", "enqueued",
                 "err_path", "err_file")

    def __init__(self, idx: int) -> None:
        self.idx = idx
        self.proc: subprocess.Popen | None = None
        self.buf = bytearray()          # incremental stdout frame buffer
        self.outbox = bytearray()       # unflushed stdin bytes
        self.job: _LaneJob | None = None
        self.dying = False              # killed; waiting for stdout EOF
        self.death_msg = "lane worker died"
        self.want_write = False         # stdin registered for EVENT_WRITE
        self.flushed = 0                # bytes written since (re)spawn
        self.enqueued = 0               # bytes ever queued since (re)spawn
        self.err_path: Path | None = None
        self.err_file: Any = None       # reused O_APPEND stderr spool


@dataclasses.dataclass
class LaneStats:
    """Dispatch accounting for the lane pool (mirrors ``GangStats``)."""

    tasks: int = 0
    dispatches: int = 0     # one per pipe-fed batch
    respawns: int = 0       # worker shells restarted (timeout/cancel/crash)

    @property
    def batching_factor(self) -> float:
        return self.tasks / max(1, self.dispatches)


class LaneWorkerPool(WorkerPool):
    """Persistent worker lanes: one long-lived ``sh`` process per slot,
    multiplexed by a single selector-based front-end thread.

    Where ``ThreadWorkerPool`` + ``run_subprocess`` pays a fresh process
    spawn, a full environment copy, and executor/future bookkeeping per
    task, a lane pays them once per *worker*: each task is one stanza
    down the worker's stdin (``VAR=… command eval '<cmd>'`` followed by
    an rc sentinel), so a shell builtin runs with zero forks and a real
    command forks from a tiny ``sh`` instead of the Python interpreter.

    The mux thread owns every lane pipe through one
    ``selectors.DefaultSelector``: it drains all lane stdouts as they
    become readable, parses rc-sentinel frames *incrementally* per lane
    (a sentinel split across pipe reads is just a partial buffer — no
    frame is ever mis-framed), trickles outgoing stanza bytes through
    non-blocking stdins, and arms per-head-node deadlines that bound the
    ``select`` timeout.  One thread for N lanes replaces the old
    thread-per-lane readers, which convoyed on the GIL past ~8 lanes.

    ``take`` claims a same-task chunk of the ready queue.  With
    ``batch="auto"`` (default) the chunk size adapts: a streaming
    median/p90 of observed per-frame durations grows batches while tasks
    are much cheaper than dispatch overhead and shrinks them under
    straggler pressure, clamped so one batch stays under ~0.25 s of
    per-lane latency.  An explicit integer pins the old static size.

    Task stdout flows back inline over the pipe, framed by a per-pool
    random sentinel.  stderr spools to a file read back only when the
    command exits nonzero: with ``capture_stderr=False`` every command
    on a lane shares one preallocated ``O_APPEND`` spool fd inherited at
    spawn (zero per-command opens; truncated between batches), while
    ``capture_stderr=True`` keeps per-batch-index spool files so each
    task's stderr reads back exactly.

    ``render`` maps a node to ``(command, env)`` — usually
    ``ParameterStudy.render_node``.  Without a render fn the node's
    payload ``command`` key is used; a node with neither fails its
    attempt (in-process registry callables cannot be piped to a shell).
    Per-task env vars are scoped to the single command (``VAR=v command
    eval …`` does not persist in the lane), layered over the environment
    snapshot taken once when the lane spawns.

    ``cancel`` kills the lane hosting the abandoned dispatch (releasing
    a stuck command) and the lane respawns for the next batch, so
    scheduler-driven timeouts compose.  A timeout or dead lane fails the
    node at the read head, harvests any later frames still sitting in
    the dying pipe, respawns the worker, and resends only the commands
    that never ran.  ``run_gang`` runs one fused node batch across all
    lanes synchronously — signature-compatible with ``GangRunner``, so
    ``GangExecutor(stackable_key, lanes.run_gang)`` dispatches gang
    groups through the persistent workers.
    """

    kind = "lane"
    durable_hosts = False   # lane ids are transient labels, not hosts

    #: adaptive batching bounds: warm up at the old static size, grow so
    #: one batch stays under ~BATCH_LATENCY seconds of per-lane latency
    WARMUP_BATCH = 8
    MAX_BATCH = 256
    BATCH_LATENCY = 0.25

    def __init__(
        self,
        slots: int,
        render: LaneRenderFn | None = None,
        batch: int | str = "auto",
        cwd: str | None = None,
        capture_stderr: bool = False,
        reuse_spool: bool | None = None,
    ) -> None:
        """``capture_stderr=True`` reads the per-task stderr spool back
        even on success — required when a ``capture:`` extractor sources
        stderr (the results layer asks for it via the study's pool
        wiring); the default keeps the success path's
        two-fewer-file-round-trips economy.  ``batch`` is ``"auto"``
        (duration-adaptive chunk sizing) or a pinned integer.
        ``reuse_spool`` toggles the preallocated per-lane stderr fd
        (default: on exactly when ``capture_stderr`` is off)."""
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if batch != "auto":
            if not isinstance(batch, int) or isinstance(batch, bool) \
                    or batch < 1:
                raise ValueError("batch must be >= 1 or 'auto'")
        self.slots = slots
        self.render = render
        self.batch = batch
        self.cwd = cwd
        self.capture_stderr = capture_stderr
        self.reuse_spool = (not capture_stderr if reuse_spool is None
                            else reuse_spool)
        self.stats = LaneStats()
        # chaos/telemetry capture at construction (the make_lock
        # pattern): when nothing is armed these are None and the frame
        # hot path pays one identity check each
        self._chaos = chaos.current()
        self._telemetry = telemetry.current()
        self._base_env = dict(os.environ)   # snapshot once per pool
        # per-pool random rc sentinel: task stdout flows back inline over
        # the lane pipe, framed by a marker real output cannot guess
        self._sent = f"__papas_{os.urandom(8).hex()}_rc="
        self._marker = b"\n" + self._sent.encode()
        self._spool = Path(tempfile.mkdtemp(prefix="papas-lanes-"))
        self._workq: deque[tuple[int, list[TaskNode]]] = deque()
        self._events: "queue.Queue[CompletionEvent]" = queue.Queue()
        self._lock = make_lock("lane.pool")
        self._cancelled: set[int] = set()
        self._active: dict[int, subprocess.Popen] = {}  # token → lane shell
        self._gang_tokens = itertools.count(-1, -1)     # never collide with
        self._gang_out: dict[int, tuple[list, list]] = {}  # scheduler tokens
        self._gang_cv = threading.Condition(self._lock)
        self._shutdown = False
        # streaming per-frame duration stats feeding the batch controller
        from .stats import StreamingQuantile
        self._dur_med = StreamingQuantile(0.5)
        self._dur_p90 = StreamingQuantile(0.9)
        self._wake_r, self._wake_w = os.pipe()
        os.set_blocking(self._wake_r, False)
        os.set_blocking(self._wake_w, False)
        self._mux_thread = threading.Thread(
            target=self._mux, name="papas-lane-mux", daemon=True)
        self._mux_thread.start()

    # -- scheduler interface -------------------------------------------
    def _batch_now(self) -> int:
        """Current batch cap: duration-adaptive unless pinned."""
        if self.batch != "auto":
            return self.batch
        with self._lock:
            n = len(self._dur_med)
            if n < 2 * self.WARMUP_BATCH:
                return self.WARMUP_BATCH
            med = self._dur_med.quantile()
            p90 = self._dur_p90.quantile()
        if med <= 0:
            return self.MAX_BATCH
        target = self.BATCH_LATENCY / med
        if p90 > 4 * med:
            # straggler pressure: bound worst-case batch latency too
            target = min(target, max(1.0, self.BATCH_LATENCY / p90))
        return max(1, min(self.MAX_BATCH, int(target)))

    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        """Gang-style chunk claim: the longest same-task prefix of the
        ready queue, capped at the (possibly adaptive) batch size — one
        pipe write per chunk.  The cap also adapts to queue depth
        (``len(ready) / slots``) so a shallow queue spreads across every
        lane instead of serializing full chunks on a few; deep sweeps
        still get full batches."""
        k = min(self._batch_now(), len(ready),
                max(1, len(ready) // self.slots))
        if k > 1:
            task0 = dag.nodes[ready[0]].task
            j = 1
            while j < k and dag.nodes[ready[j]].task == task0:
                j += 1
            k = j
        out = ready[:k]
        del ready[:k]
        if self._telemetry is not None and out:
            self._telemetry.metrics.histogram(
                "papas_lane_batch_size").observe(len(out))
        return out

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        with self._lock:
            self._workq.append((token, list(nodes)))
        self._wake()

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        try:
            return self._events.get(timeout=timeout)
        except queue.Empty:
            return None

    def cancel(self, token: int) -> None:
        """Kill the lane hosting an abandoned dispatch so a stuck command
        releases its slot promptly; the lane respawns for the next
        batch."""
        with self._lock:
            self._cancelled.add(token)
            proc = self._active.get(token)
        if proc is not None:
            self._kill(proc)
        self._wake()

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            procs = list(self._active.values())
            self._gang_cv.notify_all()
        for p in procs:
            self._kill(p)
        self._wake()
        self._mux_thread.join(timeout=5.0)
        for fd in (self._wake_r, self._wake_w):
            try:
                os.close(fd)
            except OSError:
                pass
        shutil.rmtree(self._spool, ignore_errors=True)

    def _wake(self) -> None:
        try:
            os.write(self._wake_w, b"x")
        except (BlockingIOError, OSError):
            pass    # wake pipe full or closed: the mux is waking anyway

    # -- gang integration ----------------------------------------------
    def run_gang(self, nodes: Sequence[TaskNode]) -> list[Any]:
        """Run one fused batch across every lane and return per-node
        values in order — a ``GangRunner``, so gang studies dispatch
        their groups through the persistent workers.  A lane-level
        failure raises (gang semantics: the whole group's attempt
        fails); per-command nonzero exits stay data in the returned
        ``ShellResult``\\ s."""
        nodes = list(nodes)
        if not nodes:
            return []
        per = -(-len(nodes) // self.slots)      # ceil
        chunks = [nodes[i:i + per] for i in range(0, len(nodes), per)]
        toks: list[int] = []
        with self._lock:
            for _ in chunks:
                toks.append(next(self._gang_tokens))
        with self._lock:
            for tok, chunk in zip(toks, chunks):
                self._workq.append((tok, chunk))
        self._wake()
        with self._gang_cv:
            while any(t not in self._gang_out for t in toks):
                if self._shutdown:
                    raise RuntimeError("lane pool shut down mid-gang")
                self._gang_cv.wait(timeout=0.5)
            outs = [self._gang_out.pop(t) for t in toks]
        values: list[Any] = []
        for chunk, (vals, errs) in zip(chunks, outs):
            bad = [e for e in errs if e is not None]
            if bad:
                raise RuntimeError(
                    f"lane gang batch failed: {bad[0]}"
                    + (f" (+{len(bad) - 1} more)" if len(bad) > 1 else ""))
            values.extend(vals)
        return values

    # -- mux machinery -------------------------------------------------
    @staticmethod
    def _kill(proc: subprocess.Popen) -> None:
        try:
            os.killpg(proc.pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass

    def _node_command(self, node: TaskNode
                      ) -> tuple[str | None, Mapping[str, Any]]:
        if self.render is not None:
            return self.render(node)
        payload = node.payload if isinstance(node.payload, Mapping) else {}
        return payload.get("command"), payload.get("env") or {}

    @staticmethod
    def _slurp(path: Path | None) -> str:
        if path is None:
            return ""
        try:
            return path.read_text(errors="replace")
        except FileNotFoundError:
            return ""

    def _render_line(self, node: TaskNode, err_p: Path | None
                     ) -> tuple[str, float | None]:
        """One node's protocol stanza: env overlay + eval + rc sentinel.
        Task stdout flows back inline over the pipe; stderr spools to a
        per-batch-index file (``err_p``) or, when the lane reuses one
        preallocated spool fd, is simply inherited from the shell."""
        cmd, env = self._node_command(node)
        if cmd is None:
            raise RuntimeError(
                f"task {node.task!r} has no shell command; lane workers "
                "cannot run in-process registry callables")
        prefix = ""
        for k, v in (env or {}).items():
            if not _ENV_NAME_RE.match(str(k)):
                raise RuntimeError(f"invalid environment name {k!r}")
            prefix += f"{k}={_sq(str(v))} "
        timeout = payload_timeout(node)
        redir = "" if err_p is None else f"2>{_sq(str(err_p))} "
        # stdin dups from fd 3 (/dev/null, opened once per shell) so a
        # command never eats the protocol stream — one dup2 instead of a
        # per-command open of /dev/null
        line = (f"{prefix}command eval {_sq(cmd)} {redir}<&3\n"
                f"printf '\\n{self._sent}%d\\n' $?\n")
        return line, float(timeout) if timeout else None

    def _observe(self, runtime: float) -> None:
        with self._lock:
            self._dur_med.add(runtime)
            self._dur_p90.add(runtime)
        if self._telemetry is not None:
            self._telemetry.metrics.histogram(
                "papas_lane_frame_seconds").observe(runtime)

    # -- mux event loop ------------------------------------------------
    def _mux(self) -> None:
        """The single front-end thread: multiplexes every lane pipe
        through one selector, parses frames incrementally, arms per-head
        deadlines, and handles respawn/harvest on lane death."""
        sel = selectors.DefaultSelector()
        sel.register(self._wake_r, selectors.EVENT_READ, ("wake", None))
        lanes = [_Lane(i) for i in range(self.slots)]
        idle: deque[_Lane] = deque(lanes)
        try:
            while True:
                with self._lock:
                    if self._shutdown:
                        break
                self._assign_work(sel, idle)
                timeout = None
                now = time.monotonic()
                for lane in lanes:
                    job = lane.job
                    if job is not None and job.head_deadline is not None:
                        t = max(0.0, job.head_deadline - now)
                        timeout = t if timeout is None else min(timeout, t)
                events = sel.select(timeout)
                now = time.monotonic()
                for key, _mask in events:
                    kind, lane = key.data
                    if kind == "wake":
                        try:
                            while os.read(self._wake_r, 4096):
                                pass
                        except (BlockingIOError, OSError):
                            pass
                    elif kind == "out":
                        self._on_readable(sel, lane, idle, now, key.fileobj)
                    else:   # "in": lane stdin drained some outbox room
                        self._on_writable(sel, lane, now, key.fileobj)
                now = time.monotonic()
                for lane in lanes:
                    job = lane.job
                    if (job is not None and job.head_deadline is not None
                            and now >= job.head_deadline):
                        if lane.dying:
                            # EOF grace expired (e.g. a detached grand-
                            # child still holds the pipe): force the
                            # death path without waiting for EOF
                            self._on_lane_dead(sel, lane, idle, now)
                        else:
                            self._timeout_head(lane, now)
        finally:
            self._teardown(sel, lanes)

    def _assign_work(self, sel: selectors.BaseSelector,
                     idle: "deque[_Lane]") -> None:
        while idle:
            with self._lock:
                if not self._workq:
                    return
                token, nodes = self._workq.popleft()
                cancelled = token in self._cancelled or self._shutdown
            lane = idle[0]
            job = _LaneJob(token, nodes)
            job.t0 = time.monotonic()
            for i, node in enumerate(nodes):
                err_p = None
                if not self.reuse_spool:
                    err_p = self._spool / f"lane{lane.idx}.{i}.err"
                    job.spools[i] = err_p
                try:
                    job.stanzas[i] = self._render_line(node, err_p)
                except Exception as e:  # noqa: BLE001 — per-node isolation
                    job.errors[i] = f"{type(e).__name__}: {e}"
            job.pending = [i for i in range(len(nodes)) if i in job.stanzas]
            if cancelled or not job.pending:
                for i in job.pending:
                    job.errors[i] = "cancelled: dispatch abandoned"
                job.pending = []
                self._account_and_emit(job, lane.idx, time.monotonic())
                continue
            idle.popleft()
            lane.job = job
            self._ensure_proc(sel, lane)
            with self._lock:
                self._active[job.token] = lane.proc
            self._send_pending(sel, lane, time.monotonic())

    def _ensure_proc(self, sel: selectors.BaseSelector, lane: _Lane) -> None:
        if lane.proc is not None and lane.proc.poll() is None:
            return
        self._spawn_lane(sel, lane)

    def _spawn_lane(self, sel: selectors.BaseSelector, lane: _Lane) -> None:
        if lane.proc is not None:
            self._close_proc(sel, lane)
        stderr_target: Any = subprocess.DEVNULL
        if self.reuse_spool:
            if lane.err_file is None:
                # one preallocated O_APPEND spool per lane, inherited by
                # the shell at spawn: child writes always land at EOF, so
                # truncating between batches is race-free and no command
                # ever pays a per-task open
                lane.err_path = self._spool / f"lane{lane.idx}.err"
                lane.err_file = open(lane.err_path, "ab", buffering=0)
            stderr_target = lane.err_file
        proc = subprocess.Popen(
            ["sh"], stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=stderr_target, cwd=self.cwd, env=self._base_env,
            start_new_session=True)
        os.set_blocking(proc.stdout.fileno(), False)
        os.set_blocking(proc.stdin.fileno(), False)
        lane.proc = proc
        lane.buf = bytearray()
        lane.outbox = bytearray(b"exec 3</dev/null\n")
        lane.enqueued = len(lane.outbox)
        lane.flushed = 0
        lane.dying = False
        lane.death_msg = "lane worker exited"
        lane.want_write = False
        sel.register(proc.stdout, selectors.EVENT_READ, ("out", lane))
        self.stats.respawns += 1
        if self._telemetry is not None:
            self._telemetry.metrics.counter(
                "papas_lane_respawns_total").inc()

    def _close_proc(self, sel: selectors.BaseSelector, lane: _Lane) -> None:
        proc = lane.proc
        if proc is None:
            return
        self._kill(proc)
        try:
            sel.unregister(proc.stdout)
        except (KeyError, ValueError):
            pass
        if lane.want_write:
            try:
                sel.unregister(proc.stdin)
            except (KeyError, ValueError):
                pass
            lane.want_write = False
        for f in (proc.stdout, proc.stdin):
            try:
                f.close()
            except (BrokenPipeError, OSError):
                pass
        proc.wait()
        lane.proc = None
        lane.buf = bytearray()
        lane.outbox = bytearray()

    def _send_pending(self, sel: selectors.BaseSelector, lane: _Lane,
                      now: float) -> None:
        """Queue every pending stanza for the lane in one enqueue; bytes
        trickle out through the non-blocking stdin as the pipe drains."""
        job = lane.job
        pos = lane.enqueued
        parts = []
        for i in job.pending:
            b = job.stanzas[i][0].encode()
            parts.append(b)
            pos += len(b)
            job.ends[i] = pos
        lane.outbox += b"".join(parts)
        lane.enqueued = pos
        job.cycle_len = len(job.pending)
        job.head_started = now
        job.head_deadline = None
        self._flush_out(sel, lane, now)

    def _flush_out(self, sel: selectors.BaseSelector, lane: _Lane,
                   now: float) -> None:
        proc = lane.proc
        if proc is None:
            return
        while lane.outbox:
            try:
                n = os.write(proc.stdin.fileno(), lane.outbox)
            except BlockingIOError:
                break
            except (BrokenPipeError, OSError) as e:
                lane.death_msg = str(e) or "lane worker died"
                lane.outbox.clear()
                self._kill(proc)    # stdout EOF follows; death path runs
                break
            del lane.outbox[:n]
            lane.flushed += n
        if lane.outbox and not lane.want_write:
            sel.register(proc.stdin, selectors.EVENT_WRITE, ("in", lane))
            lane.want_write = True
        elif not lane.outbox and lane.want_write:
            try:
                sel.unregister(proc.stdin)
            except (KeyError, ValueError):
                pass
            lane.want_write = False
        self._arm_deadline(lane, now)

    def _arm_deadline(self, lane: _Lane, now: float) -> None:
        """Arm the head node's timeout once its stanza fully left the
        pipe (a deadline for a command the shell cannot have started yet
        would fire spuriously)."""
        job = lane.job
        if job is None or lane.dying or not job.pending:
            return
        if job.head_deadline is not None:
            return
        head = job.pending[0]
        t = job.stanzas[head][1]
        if t is not None and lane.flushed >= job.ends.get(head, 0):
            job.head_deadline = now + t

    def _on_writable(self, sel: selectors.BaseSelector, lane: _Lane,
                     now: float, fileobj: Any) -> None:
        if lane.proc is None or fileobj is not lane.proc.stdin:
            return      # stale event for a respawned lane
        self._flush_out(sel, lane, now)

    def _on_readable(self, sel: selectors.BaseSelector, lane: _Lane,
                     idle: "deque[_Lane]", now: float, fileobj: Any) -> None:
        if lane.proc is None or fileobj is not lane.proc.stdout:
            return      # stale event for a respawned lane
        fd = lane.proc.stdout.fileno()
        eof = False
        while True:
            try:
                chunk = os.read(fd, 65536)
            except BlockingIOError:
                break
            except OSError:
                eof = True
                break
            if not chunk:
                eof = True
                break
            lane.buf += chunk
            if len(chunk) < 65536:
                break
        self._pump(sel, lane, idle, now)
        if eof:
            self._on_lane_dead(sel, lane, idle, now)

    def _pump(self, sel: selectors.BaseSelector, lane: _Lane,
              idle: "deque[_Lane]", now: float) -> None:
        """Parse complete rc-sentinel frames out of the lane's incremental
        buffer.  A sentinel split across pipe reads is simply an
        incomplete buffer — parsing resumes when the rest arrives, so
        frames survive arbitrary read fragmentation (including on a
        dying pipe during harvest)."""
        job = lane.job
        if job is None:
            lane.buf.clear()    # stray output with no active batch
            return
        marker = self._marker
        while job.pending:
            pos = lane.buf.find(marker)
            if pos < 0:
                break
            end = lane.buf.find(b"\n", pos + len(marker))
            if end < 0:
                break           # rc digits still in flight
            rc = int(lane.buf[pos + len(marker):end])
            out = bytes(lane.buf[:pos])
            del lane.buf[:end + 1]
            i = job.pending.pop(0)
            runtime = 0.0 if lane.dying else now - job.head_started
            stderr = ""
            if rc != 0 or self.capture_stderr:
                stderr = self._slurp(job.spools.get(i, lane.err_path))
            job.values[i] = ShellResult(rc, out.decode(errors="replace"),
                                        stderr, runtime)
            job.errors[i] = None
            if not lane.dying:
                self._observe(runtime)
                job.head_started = now
                job.head_deadline = None
                if self._chaos is not None \
                        and self._chaos.lane_frame(lane.idx) \
                        and lane.proc is not None:
                    # injected lane death: SIGKILL the worker mid-batch
                    # and let the existing death path (_on_lane_dead)
                    # harvest flushed frames, charge the read head, and
                    # respawn — the exact recovery a real crash takes
                    lane.death_msg = "lane worker died"
                    self._kill(lane.proc)
                    break
                self._arm_deadline(lane, now)
        if not job.pending and not lane.dying:
            self._finish_lane_job(sel, lane, idle, now)

    def _timeout_head(self, lane: _Lane, now: float) -> None:
        """Per-node timeout at the read head: charge the head, kill the
        worker, and let the death path harvest any later frames still
        sitting in the dying pipe."""
        job = lane.job
        head = job.pending.pop(0)
        job.errors[head] = (f"timeout: lane command exceeded "
                            f"{job.stanzas[head][1]}s")
        job.values[head] = None
        lane.dying = True
        lane.death_msg = "lane worker died"
        # grace period for the pipe EOF after SIGKILL; a detached
        # grandchild holding the write end cannot wedge the lane
        job.head_deadline = now + 1.0
        if lane.proc is not None:
            self._kill(lane.proc)

    def _on_lane_dead(self, sel: selectors.BaseSelector, lane: _Lane,
                      idle: "deque[_Lane]", now: float) -> None:
        """Lane shell died (timeout kill, cancel kill, or crash): close
        it out, charge the read head if its command had been sent,
        respawn, and resend only the survivors that never ran."""
        was_dying = lane.dying
        flushed = lane.flushed
        self._close_proc(sel, lane)
        job = lane.job
        if job is None:
            return              # idle lane's shell died: respawn lazily
        job.head_deadline = None
        lane.dying = False
        with self._lock:
            cancelled = job.token in self._cancelled or self._shutdown
        if cancelled:
            for i in job.pending:
                job.errors[i] = "cancelled: dispatch abandoned"
            job.pending = []
            self._finish_lane_job(sel, lane, idle, now)
            return
        msg = lane.death_msg
        if not was_dying and job.pending:
            head = job.pending[0]
            if flushed >= job.ends.get(head, float("inf")):
                job.pending.pop(0)
                job.errors[head] = msg
                job.values[head] = None
        survivors = job.pending
        progress = len(survivors) < job.cycle_len
        job.stalls = 0 if progress else job.stalls + 1
        if not survivors:
            self._finish_lane_job(sel, lane, idle, now)
        elif job.stalls >= 3:   # lane keeps dying without progress
            for i in survivors:
                job.errors[i] = msg
                job.values[i] = None
            job.pending = []
            self._finish_lane_job(sel, lane, idle, now)
        else:
            self._spawn_lane(sel, lane)
            with self._lock:
                self._active[job.token] = lane.proc
            self._send_pending(sel, lane, now)

    def _finish_lane_job(self, sel: selectors.BaseSelector, lane: _Lane,
                         idle: "deque[_Lane]", now: float) -> None:
        job = lane.job
        lane.job = None
        lane.dying = False
        with self._lock:
            self._active.pop(job.token, None)
        self._account_and_emit(job, lane.idx, now)
        if self.reuse_spool and lane.err_file is not None:
            try:
                os.ftruncate(lane.err_file.fileno(), 0)
            except OSError:
                pass
        idle.append(lane)

    def _account_and_emit(self, job: _LaneJob, idx: int, t1: float) -> None:
        self.stats.dispatches += 1
        self.stats.tasks += len(job.nodes)
        tel = self._telemetry
        if tel is not None:
            # retroactive frame slice: both ends known, one lane track
            # per index (the tid survives respawns — keyed by name)
            tel.trace.complete(
                f"lane{idx}", f"{job.nodes[0].task} x{len(job.nodes)}",
                job.t0, t1, cat="lane", args={"tasks": len(job.nodes)})
        self._emit(job.token, job.values, job.errors, job.t0, t1,
                   f"lane{idx}")

    def _teardown(self, sel: selectors.BaseSelector,
                  lanes: list[_Lane]) -> None:
        now = time.monotonic()
        for lane in lanes:
            if lane.job is not None:
                job = lane.job
                lane.job = None
                for i in job.pending:
                    job.errors[i] = "cancelled: dispatch abandoned"
                job.pending = []
                with self._lock:
                    self._active.pop(job.token, None)
                self._account_and_emit(job, lane.idx, now)
        while True:
            with self._lock:
                if not self._workq:
                    break
                token, nodes = self._workq.popleft()
            job = _LaneJob(token, nodes)
            job.t0 = now
            job.errors = ["cancelled: dispatch abandoned"] * len(nodes)
            self._account_and_emit(job, 0, now)
        for lane in lanes:
            if lane.proc is not None:
                self._close_proc(sel, lane)
            if lane.err_file is not None:
                try:
                    lane.err_file.close()
                except OSError:
                    pass
        try:
            sel.unregister(self._wake_r)
        except (KeyError, ValueError):
            pass
        sel.close()

    def _emit(self, token: int, values: list[Any],
              errors: list[str | None], t0: float, t1: float,
              host: str) -> None:
        if token < 0:       # run_gang internal dispatch
            with self._gang_cv:
                self._gang_out[token] = (values, errors)
                self._gang_cv.notify_all()
            return
        self._events.put(
            CompletionEvent(token, values, errors, t0, t1, host=host))


def payload_timeout(node: TaskNode) -> Any:
    """A node's WDL ``timeout`` keyword, if any."""
    payload = node.payload if isinstance(node.payload, Mapping) else {}
    return payload.get("timeout")


#: every kind ``make_pool`` accepts (remote kinds live in ``remote.py``).
VALID_POOL_KINDS = ("inline", "thread", "process", "lane", "ssh", "slurm",
                    "pbs")


def make_pool(kind: str, slots: int = 1, **remote_kwargs: Any) -> WorkerPool:
    """Construct a pool by name.

    Local kinds: ``inline``, ``thread``, ``process`` (``slots``
    workers), and ``lane`` (``slots`` persistent shell workers; optional
    ``render``, ``batch``, ``cwd`` — the short-task throughput path).
    Remote kinds: ``ssh`` (requires ``hosts``; optional ``ppnode``,
    ``transport``, ``render``) and ``slurm`` / ``pbs`` (optional
    ``nnodes``, ``ppnode``, ``submitter``, ``render``, ``spool_root``)
    — their slot count is ``hosts × ppnode`` / ``nnodes × ppnode``, not
    ``slots``.  An unknown kind raises a ``ValueError`` naming every
    valid kind.
    """
    if kind == "inline":
        return InlinePool()
    if kind == "thread":
        return ThreadWorkerPool(slots)
    if kind == "process":
        return ProcessWorkerPool(slots)
    if kind == "lane":
        for k in ("hosts", "nnodes", "ppnode", "transport", "submitter",
                  "spool_root"):
            remote_kwargs.pop(k, None)
        return LaneWorkerPool(slots, **remote_kwargs)
    if kind == "ssh":
        from .remote import SSHWorkerPool

        hosts = remote_kwargs.pop("hosts", None)
        if not hosts:
            raise ValueError(
                "pool kind 'ssh' requires a non-empty host list "
                "(WDL 'hosts:' keyword or --hosts)")
        remote_kwargs.pop("nnodes", None)
        remote_kwargs.pop("submitter", None)
        remote_kwargs.pop("spool_root", None)
        return SSHWorkerPool(
            hosts, ppnode=remote_kwargs.pop("ppnode", None) or 1,
            **remote_kwargs)
    if kind in ("slurm", "pbs"):
        from .remote import BatchWorkerPool

        remote_kwargs.pop("hosts", None)
        remote_kwargs.pop("transport", None)
        return BatchWorkerPool(
            batch=kind,
            nnodes=remote_kwargs.pop("nnodes", None) or 1,
            ppnode=remote_kwargs.pop("ppnode", None) or 1,
            **remote_kwargs)
    raise ValueError(
        f"unknown pool kind {kind!r}; valid kinds: "
        + ", ".join(VALID_POOL_KINDS))


# ---------------------------------------------------------------------------
# Gang packing
# ---------------------------------------------------------------------------

GroupKeyFn = Callable[[TaskNode], Hashable]
GangRunner = Callable[[Sequence[TaskNode]], Sequence[Any]]


@dataclasses.dataclass
class GangStats:
    """Dispatch accounting — the quantity the paper's Figs. 3/4 compare."""

    groups: int = 0
    tasks: int = 0
    dispatches: int = 0  # one per compiled-program launch

    @property
    def batching_factor(self) -> float:
        return self.tasks / max(1, self.dispatches)


class GangExecutor:
    """Group task instances by a stackability key and dispatch each group
    once.  One dispatch per group is the TPU analogue of "grouping
    intra/inter-workflow tasks as a single batch job" (paper §4.3)."""

    def __init__(self, group_key: GroupKeyFn, gang_runner: GangRunner,
                 max_group: int | None = None) -> None:
        self.group_key = group_key
        self.gang_runner = gang_runner
        self.max_group = max_group
        self.stats = GangStats()

    def run_group(self, chunk: Sequence[TaskNode]) -> list[Any]:
        """Dispatch one stackable chunk as a single program launch."""
        values = list(self.gang_runner(chunk))
        if len(values) != len(chunk):
            raise RuntimeError(
                f"gang runner returned {len(values)} results for "
                f"{len(chunk)} tasks")
        self.stats.groups += 1
        self.stats.dispatches += 1
        self.stats.tasks += len(chunk)
        return values

    def run(self, nodes: Sequence[TaskNode]) -> dict[str, Any]:
        """Group and dispatch a node set directly (no scheduler)."""
        groups: dict[Hashable, list[TaskNode]] = {}
        for n in nodes:
            groups.setdefault(self.group_key(n), []).append(n)
        results: dict[str, Any] = {}
        for _, members in sorted(groups.items(), key=lambda kv: str(kv[0])):
            chunks = (
                [members[i:i + self.max_group]
                 for i in range(0, len(members), self.max_group)]
                if self.max_group else [members]
            )
            for chunk in chunks:
                for node, value in zip(chunk, self.run_group(chunk)):
                    results[node.id] = value
        return results


class GangPool(_SyncPool):
    """Gang dispatch as a pool policy: ``take`` claims an entire
    stackability group from the ready queue and ``submit`` launches it as
    one program.  Replaces the old separate level-synchronous loop — gang
    studies now share the scheduler's retry/closure/journal machinery."""

    kind = "gang"

    def __init__(self, gang: GangExecutor) -> None:
        super().__init__()
        self.gang = gang

    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        groups: dict[str, list[str]] = {}
        for nid in ready:
            groups.setdefault(str(self.gang.group_key(dag.nodes[nid])),
                              []).append(nid)
        members = groups[sorted(groups)[0]]
        if self.gang.max_group:
            members = members[: self.gang.max_group]
        for nid in members:
            ready.remove(nid)
        return members

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]):
        t0 = time.monotonic()
        try:
            values = self.gang.run_group(nodes)
            errors: list[str | None] = [None] * len(nodes)
        except Exception as e:  # noqa: BLE001 — whole-batch failure
            msg = f"{type(e).__name__}: {e}"
            values = [None] * len(nodes)
            errors = [msg] * len(nodes)
        t1 = time.monotonic()
        return values, errors, t0, t1


def stackable_key(node: TaskNode) -> Hashable:
    """Default stackability: nodes of the same task whose combos share
    the same *keys* (values may differ — they become per-member arrays).
    Shape-affecting parameters must be embedded in the task name by the
    study author (or use mesh-slice instead)."""
    return (node.task, tuple(sorted(node.combo.keys())))
