"""Worker pools — the execution backends behind the unified engine.

The scheduler (``repro.core.scheduler``) is a single slot-occupancy event
loop; everything backend-specific lives here behind the ``WorkerPool``
interface.  A pool decides *which* ready nodes to claim (``take``), runs
them (``submit``), and reports completions (``next_event``) — the paper's
"cluster engine" (§4.3) reduced to three methods.  Backends:

* ``InlinePool``   — runs each task synchronously at dispatch time.
  Fully deterministic; the default for tests and small studies.
* ``ThreadWorkerPool``  — ``concurrent.futures`` thread pool; real wall-
  clock parallelism for I/O- and subprocess-bound tasks.
* ``ProcessWorkerPool`` — process pool for CPU-bound Python tasks
  (runner and nodes must be picklable).
* ``GangPool``     — batched dispatch: claims a whole stackability group
  from the ready queue and launches it as ONE program (the paper's
  single-cluster-job technique, §4.3).  Wraps a ``GangExecutor``.
* ``LaneWorkerPool`` — the short-task throughput path: one long-lived
  ``sh`` worker per slot, fed rendered commands over a pipe protocol.
  Process spawn is amortized across thousands of tasks (a shell builtin
  like ``true`` never forks at all), ``take`` claims gang-style chunks
  so one pipe write carries a whole batch, and per-task environment
  overlays ride the command line — no per-task ``os.environ`` copy.
  Its ``run_gang`` method is a drop-in ``GangRunner``, so a
  ``GangExecutor``/``GangPool`` can fuse its batches onto the lanes.

``run_subprocess`` runs black-box shell tasks and always returns a
``ShellResult`` — a nonzero exit is *data*, classified by the scheduler's
retry/failure-closure logic (respecting the task's ``allow_nonzero``
keyword), not an exception.  ``merged_env`` accepts a pre-snapshotted
base environment so a pool or run copies ``os.environ`` once, not once
per task.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import queue
import re
import select
import shlex
import shutil
import signal
import subprocess
import tempfile
import threading
import time
from collections import deque
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from pathlib import Path
from typing import Any, Callable, Hashable, Mapping, Sequence, TYPE_CHECKING

from .dag import TaskNode

if TYPE_CHECKING:  # pragma: no cover
    from .dag import TaskDAG


@dataclasses.dataclass
class ShellResult:
    returncode: int
    stdout: str
    stderr: str
    runtime: float

    @property
    def ok(self) -> bool:
        return self.returncode == 0


def merged_env(env: Mapping[str, str] | None,
               base: Mapping[str, str] | None = None) -> dict[str, str]:
    """The task environment: the ambient process env overlaid with the
    instance's rendered variables (paper §5 ``environ``).

    ``base`` is an optional pre-snapshotted ambient environment — pools
    and runs capture ``dict(os.environ)`` once and pass it here, so the
    per-task cost is one small dict copy instead of a full environ walk.
    """
    full_env = dict(base) if base is not None else dict(os.environ)
    if env:
        full_env.update({k: str(v) for k, v in env.items()})
    return full_env


def run_subprocess(
    command: str,
    env: Mapping[str, str] | None = None,
    timeout: float | None = None,
    cwd: str | None = None,
    shell: bool = False,
    base_env: Mapping[str, str] | None = None,
) -> ShellResult:
    """Run one black-box task; measures runtime (the paper's task
    profiler: "the application is not mandated to have an internal
    timer").

    Always returns a ``ShellResult`` — including on nonzero exit.  The
    scheduler classifies the returncode (see ``Scheduler._classify``),
    so retries and failure closure apply uniformly to shell tasks.  A
    ``timeout`` propagates to ``subprocess.run``; expiry raises
    ``subprocess.TimeoutExpired``, which the scheduler records as a
    failed attempt.  ``shell=True`` runs the command through ``sh -c``
    (pipes/redirects honored) instead of splitting it into argv.
    ``base_env`` is the run-level ambient environment snapshot forwarded
    to ``merged_env`` (None: snapshot ``os.environ`` per call).
    """
    t0 = time.monotonic()
    proc = subprocess.run(
        ["sh", "-c", command] if shell else shlex.split(command),
        capture_output=True,
        text=True,
        env=merged_env(env, base_env),
        timeout=timeout,
        cwd=cwd,
        check=False,
    )
    t1 = time.monotonic()
    return ShellResult(proc.returncode, proc.stdout, proc.stderr, t1 - t0)


# ---------------------------------------------------------------------------
# Worker pools
# ---------------------------------------------------------------------------

#: runner signature shared by every pool: one node in, one value out.
Runner = Callable[[TaskNode], Any]


@dataclasses.dataclass
class CompletionEvent:
    """One finished dispatch: per-node outcomes plus true start/stop."""

    token: int
    values: list[Any]             # aligned with the dispatched nodes
    errors: list[str | None]      # non-None marks that node's attempt failed
    started: float
    finished: float
    host: str | None = None       # executing host / allocation (remote pools)


def _run_nodes(runner: Runner, nodes: Sequence[TaskNode]
               ) -> tuple[list[Any], list[str | None], float, float]:
    """Worker-side body: run each node, capture per-node exceptions, and
    measure true occupancy with a clock local to the worker."""
    t0 = time.monotonic()
    values: list[Any] = []
    errors: list[str | None] = []
    for node in nodes:
        try:
            values.append(runner(node))
            errors.append(None)
        except Exception as e:  # noqa: BLE001 — fault isolation
            values.append(None)
            errors.append(f"{type(e).__name__}: {e}")
    t1 = time.monotonic()
    return values, errors, t0, t1


class WorkerPool:
    """Backend interface for the scheduler's event loop."""

    kind = "base"

    #: whether ``CompletionEvent.host`` names a durable location worth
    #: folding into the journal's per-task host map (remote pools: yes).
    #: Pools whose hosts are transient local labels (worker lanes) keep
    #: host provenance in the per-attempt records only — a 10^5-task
    #: windowed run must not grow an O(N_W) journal host map out of
    #: lane indices.
    durable_hosts = True

    @property
    def dispatch_slots(self) -> int:
        """How many concurrent dispatches the scheduler should drive.
        Defaults to the pool's slot count (one task per dispatch);
        grouped backends (batch allocations) override this — each
        dispatch already hosts a whole group, so driving ``slots``
        dispatches would over-subscribe the declared capacity."""
        return int(getattr(self, "slots", 1) or 1)

    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        """Claim the next batch of node ids from the (sorted) ready
        queue, removing them.  Default: one node per dispatch."""
        return [ready.pop(0)]

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        raise NotImplementedError

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        """Block for the next completion; ``None`` signals the timeout
        elapsed (the loop then checks deadlines and stragglers)."""
        raise NotImplementedError

    def cancel(self, token: int) -> None:
        """Release backend resources held by an abandoned dispatch (a
        speculative duplicate that lost the race, or an expired
        attempt).  The pool must still deliver a completion event for
        the token so the scheduler can return its slot to service.
        Default: no-op — local pools just let the worker finish."""

    def shutdown(self) -> None:
        pass

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()


class _SyncPool(WorkerPool):
    """Base for synchronous backends: ``submit`` runs the batch in place
    and queues its event, so completions arrive in dispatch order."""

    def __init__(self) -> None:
        self._events: deque[CompletionEvent] = deque()

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]
                   ) -> tuple[list[Any], list[str | None], float, float]:
        raise NotImplementedError

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        values, errors, t0, t1 = self._run_batch(runner, nodes)
        self._events.append(CompletionEvent(token, values, errors, t0, t1))

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        return self._events.popleft() if self._events else None


class InlinePool(_SyncPool):
    """Synchronous per-node backend — deterministic; the default."""

    kind = "inline"

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]):
        return _run_nodes(runner, nodes)


class _FuturePool(WorkerPool):
    """Shared machinery for executor-backed pools: completions funnel
    through a queue fed by done-callbacks."""

    def __init__(self, slots: int) -> None:
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.slots = slots
        self._q: "queue.Queue[CompletionEvent]" = queue.Queue()
        self._ex = self._make_executor(slots)

    def _make_executor(self, slots: int):  # pragma: no cover - abstract
        raise NotImplementedError

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        fut = self._ex.submit(_run_nodes, runner, list(nodes))
        n = len(nodes)
        fut.add_done_callback(lambda f, t=token, k=n: self._collect(t, k, f))

    def _collect(self, token: int, n: int, fut: Any) -> None:
        if fut.cancelled():
            return      # shutdown cancelled it before it ever ran
        exc = fut.exception()
        if exc is not None:
            now = time.monotonic()
            msg = f"{type(exc).__name__}: {exc}"
            ev = CompletionEvent(token, [None] * n, [msg] * n, now, now)
        else:
            values, errors, t0, t1 = fut.result()
            ev = CompletionEvent(token, values, errors, t0, t1)
        self._q.put(ev)

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            return None

    def shutdown(self) -> None:
        self._ex.shutdown(wait=False, cancel_futures=True)


class ThreadWorkerPool(_FuturePool):
    """Thread-pool backend: true wall-clock overlap for subprocess- and
    I/O-bound tasks (and anything releasing the GIL)."""

    kind = "thread"

    def _make_executor(self, slots: int) -> ThreadPoolExecutor:
        return ThreadPoolExecutor(max_workers=slots,
                                  thread_name_prefix="papas-slot")


class ProcessWorkerPool(_FuturePool):
    """Process-pool backend for CPU-bound Python tasks.  The runner and
    every node (including payloads) must be picklable."""

    kind = "process"

    def _make_executor(self, slots: int) -> ProcessPoolExecutor:
        return ProcessPoolExecutor(max_workers=slots)


# ---------------------------------------------------------------------------
# Persistent worker lanes (short-task throughput path)
# ---------------------------------------------------------------------------

#: renders one node to its shell form: ``node -> (command | None, env)``.
LaneRenderFn = Callable[[TaskNode], "tuple[str | None, Mapping[str, Any]]"]

_ENV_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")


def _sq(s: str) -> str:
    """POSIX single-quote."""
    return "'" + s.replace("'", "'\\''") + "'"


class _LaneGone(Exception):
    """The lane's worker shell died (cancelled, killed, or crashed)."""


class _LaneTimeout(Exception):
    """A lane command exceeded its per-node timeout."""


@dataclasses.dataclass
class LaneStats:
    """Dispatch accounting for the lane pool (mirrors ``GangStats``)."""

    tasks: int = 0
    dispatches: int = 0     # one per pipe-fed batch
    respawns: int = 0       # worker shells restarted (timeout/cancel/crash)

    @property
    def batching_factor(self) -> float:
        return self.tasks / max(1, self.dispatches)


class LaneWorkerPool(WorkerPool):
    """Persistent worker lanes: one long-lived ``sh`` process per slot,
    fed rendered shell commands over a pipe protocol.

    Where ``ThreadWorkerPool`` + ``run_subprocess`` pays a fresh process
    spawn, a full environment copy, and executor/future bookkeeping per
    task, a lane pays them once per *worker*: each task is one stanza
    down the worker's stdin (``VAR=… command eval '<cmd>'`` followed by
    an rc sentinel), so a shell builtin runs with zero forks and a real
    command forks from a tiny ``sh`` instead of the Python interpreter.
    ``take`` reuses the gang batching policy — it claims a same-task
    chunk of up to ``batch`` ready nodes — and the whole chunk goes down
    the pipe in ONE write, so the shell executes commands back-to-back
    while the lane thread drains results behind it.

    Task stdout flows back inline over the pipe, framed by a per-pool
    random sentinel; stderr spools to a per-batch-index file and is read
    back only when the command exits nonzero (``ShellResult.stderr`` is
    empty for successful lane tasks — the one semantic difference from
    ``run_subprocess``, traded for ~2 fewer file round-trips per task).

    ``render`` maps a node to ``(command, env)`` — usually
    ``ParameterStudy.render_node``.  Without a render fn the node's
    payload ``command`` key is used; a node with neither fails its
    attempt (in-process registry callables cannot be piped to a shell).
    Per-task env vars are scoped to the single command (``VAR=v command
    eval …`` does not persist in the lane), layered over the environment
    snapshot taken once when the lane spawns.

    ``cancel`` kills the lane hosting the abandoned dispatch (releasing
    a stuck command) and the lane respawns for the next batch, so
    scheduler-driven timeouts compose.  ``run_gang`` runs one fused node
    batch across all lanes synchronously — signature-compatible with
    ``GangRunner``, so ``GangExecutor(stackable_key, lanes.run_gang)``
    dispatches gang groups through the persistent workers.
    """

    kind = "lane"
    durable_hosts = False   # lane ids are transient labels, not hosts

    def __init__(
        self,
        slots: int,
        render: LaneRenderFn | None = None,
        batch: int = 8,
        cwd: str | None = None,
        capture_stderr: bool = False,
    ) -> None:
        """``capture_stderr=True`` reads the per-task stderr spool back
        even on success — required when a ``capture:`` extractor sources
        stderr (the results layer asks for it via the study's pool
        wiring); the default keeps the success path's
        two-fewer-file-round-trips economy."""
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if batch < 1:
            raise ValueError("batch must be >= 1")
        self.slots = slots
        self.render = render
        self.batch = batch
        self.cwd = cwd
        self.capture_stderr = capture_stderr
        self.stats = LaneStats()
        self._base_env = dict(os.environ)   # snapshot once per pool
        # per-pool random rc sentinel: task stdout flows back inline over
        # the lane pipe, framed by a marker real output cannot guess
        self._sent = f"__papas_{os.urandom(8).hex()}_rc="
        self._marker = b"\n" + self._sent.encode()
        self._spool = Path(tempfile.mkdtemp(prefix="papas-lanes-"))
        self._work: "queue.Queue[tuple[int, list[TaskNode]] | None]" = (
            queue.Queue())
        self._events: "queue.Queue[CompletionEvent]" = queue.Queue()
        self._lock = threading.Lock()
        self._cancelled: set[int] = set()
        self._active: dict[int, subprocess.Popen] = {}  # token → lane shell
        self._gang_tokens = itertools.count(-1, -1)     # never collide with
        self._gang_out: dict[int, tuple[list, list]] = {}  # scheduler tokens
        self._gang_cv = threading.Condition(self._lock)
        self._shutdown = False
        self._threads = [
            threading.Thread(target=self._worker, args=(i,),
                             name=f"papas-lane-{i}", daemon=True)
            for i in range(slots)
        ]
        for t in self._threads:
            t.start()

    # -- scheduler interface -------------------------------------------
    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        """Gang-style chunk claim: the longest same-task prefix of the
        ready queue, capped at ``batch`` — one pipe write per chunk.
        The cap adapts to queue depth (``len(ready) / slots``) so a
        shallow queue spreads across every lane instead of serializing
        full chunks on a few; deep sweeps still get full batches."""
        k = min(self.batch, len(ready), max(1, len(ready) // self.slots))
        if k > 1:
            task0 = dag.nodes[ready[0]].task
            j = 1
            while j < k and dag.nodes[ready[j]].task == task0:
                j += 1
            k = j
        out = ready[:k]
        del ready[:k]
        return out

    def submit(self, token: int, runner: Runner | None,
               nodes: Sequence[TaskNode]) -> None:
        self._work.put((token, list(nodes)))

    def next_event(self, timeout: float | None = None) -> CompletionEvent | None:
        try:
            return self._events.get(timeout=timeout)
        except queue.Empty:
            return None

    def cancel(self, token: int) -> None:
        """Kill the lane hosting an abandoned dispatch so a stuck command
        releases its slot promptly; the lane respawns for the next
        batch."""
        with self._lock:
            self._cancelled.add(token)
            proc = self._active.get(token)
        if proc is not None:
            self._kill(proc)

    def shutdown(self) -> None:
        self._shutdown = True
        for _ in self._threads:
            self._work.put(None)
        with self._lock:
            procs = list(self._active.values())
            self._gang_cv.notify_all()
        for p in procs:
            self._kill(p)
        for t in self._threads:
            t.join(timeout=2.0)
        shutil.rmtree(self._spool, ignore_errors=True)

    # -- gang integration ----------------------------------------------
    def run_gang(self, nodes: Sequence[TaskNode]) -> list[Any]:
        """Run one fused batch across every lane and return per-node
        values in order — a ``GangRunner``, so gang studies dispatch
        their groups through the persistent workers.  A lane-level
        failure raises (gang semantics: the whole group's attempt
        fails); per-command nonzero exits stay data in the returned
        ``ShellResult``\\ s."""
        nodes = list(nodes)
        if not nodes:
            return []
        per = -(-len(nodes) // self.slots)      # ceil
        chunks = [nodes[i:i + per] for i in range(0, len(nodes), per)]
        toks: list[int] = []
        with self._lock:
            for _ in chunks:
                toks.append(next(self._gang_tokens))
        for tok, chunk in zip(toks, chunks):
            self._work.put((tok, chunk))
        with self._gang_cv:
            while any(t not in self._gang_out for t in toks):
                if self._shutdown:
                    raise RuntimeError("lane pool shut down mid-gang")
                self._gang_cv.wait(timeout=0.5)
            outs = [self._gang_out.pop(t) for t in toks]
        values: list[Any] = []
        for chunk, (vals, errs) in zip(chunks, outs):
            bad = [e for e in errs if e is not None]
            if bad:
                raise RuntimeError(
                    f"lane gang batch failed: {bad[0]}"
                    + (f" (+{len(bad) - 1} more)" if len(bad) > 1 else ""))
            values.extend(vals)
        return values

    # -- worker machinery ----------------------------------------------
    def _spawn(self, idx: int) -> subprocess.Popen:
        return subprocess.Popen(
            ["sh"], stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=subprocess.DEVNULL, cwd=self.cwd, env=self._base_env,
            start_new_session=True)

    @staticmethod
    def _kill(proc: subprocess.Popen) -> None:
        try:
            os.killpg(proc.pid, signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass

    def _node_command(self, node: TaskNode
                      ) -> tuple[str | None, Mapping[str, Any]]:
        if self.render is not None:
            return self.render(node)
        payload = node.payload if isinstance(node.payload, Mapping) else {}
        return payload.get("command"), payload.get("env") or {}

    def _read_result(self, proc: subprocess.Popen, buf: bytearray,
                     timeout: float | None) -> tuple[int, bytes]:
        """Read lane stdout until the rc sentinel: returns ``(rc, task
        stdout bytes)``.  The sentinel printf always starts at a line
        boundary (it emits a leading newline of its own), so stdout is
        everything before the marker.  EOF means the lane died
        (cancelled or crashed)."""
        fd = proc.stdout.fileno()
        marker = self._marker
        deadline = (time.monotonic() + timeout) if timeout else None
        while True:
            pos = buf.find(marker)
            if pos >= 0:
                end = buf.find(b"\n", pos + len(marker))
                if end >= 0:
                    rc = int(buf[pos + len(marker):end])
                    out = bytes(buf[:pos])
                    del buf[:end + 1]
                    return rc, out
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise _LaneTimeout
                rlist, _, _ = select.select([fd], [], [], remaining)
                if not rlist:
                    continue
            else:
                select.select([fd], [], [])
            chunk = os.read(fd, 65536)
            if not chunk:
                raise _LaneGone("lane worker exited")
            buf += chunk

    @staticmethod
    def _slurp(path: Path) -> str:
        try:
            return path.read_text(errors="replace")
        except FileNotFoundError:
            return ""

    def _render_line(self, node: TaskNode, err_p: Path
                     ) -> tuple[str, float | None]:
        """One node's protocol stanza: env overlay + eval + rc sentinel.
        Task stdout flows back inline over the pipe; stderr spools to a
        per-batch-index file (read back only on failure)."""
        cmd, env = self._node_command(node)
        if cmd is None:
            raise RuntimeError(
                f"task {node.task!r} has no shell command; lane workers "
                "cannot run in-process registry callables")
        prefix = ""
        for k, v in (env or {}).items():
            if not _ENV_NAME_RE.match(str(k)):
                raise RuntimeError(f"invalid environment name {k!r}")
            prefix += f"{k}={_sq(str(v))} "
        timeout = payload_timeout(node)
        line = (f"{prefix}command eval {_sq(cmd)} 2>{_sq(str(err_p))} "
                f"</dev/null\n"
                f"printf '\\n{self._sent}%d\\n' $?\n")
        return line, float(timeout) if timeout else None

    def _run_batch(self, idx: int, token: int, nodes: list[TaskNode],
                   lane: dict) -> tuple[list[Any], list[str | None]]:
        """Run one claimed chunk through the lane, pipelined: every
        stanza goes down the pipe in ONE write, the shell executes the
        commands back-to-back, and this thread drains rc sentinels and
        spool files behind it — the pipe round-trip amortizes across the
        whole chunk.  A timeout or dead lane fails the node at the read
        head, respawns the worker, and resends the remainder."""
        n = len(nodes)
        values: list[Any] = [None] * n
        errors: list[str | None] = ["lane batch aborted"] * n
        spools = [self._spool / f"lane{idx}.{i}.err" for i in range(n)]
        stanzas: dict[int, tuple[str, float | None]] = {}
        for i, node in enumerate(nodes):
            try:
                stanzas[i] = self._render_line(node, spools[i])
            except Exception as e:  # noqa: BLE001 — per-node isolation
                errors[i] = f"{type(e).__name__}: {e}"
        pending = [i for i in range(n) if i in stanzas]
        stalls = 0
        while pending:
            with self._lock:
                if token in self._cancelled or self._shutdown:
                    for i in pending:
                        errors[i] = "cancelled: dispatch abandoned"
                    break
            proc = lane.get("proc")
            if proc is None or proc.poll() is not None:
                lane["buf"] = bytearray()
                proc = lane["proc"] = self._spawn(idx)
                self.stats.respawns += 1
            with self._lock:
                self._active[token] = proc
            buf = lane["buf"]
            done_k = 0
            sent = False
            try:
                blob = "".join(stanzas[i][0] for i in pending).encode()
                proc.stdin.write(blob)
                proc.stdin.flush()
                sent = True
                for k, i in enumerate(pending):
                    t0 = time.monotonic()
                    rc, out = self._read_result(proc, buf, stanzas[i][1])
                    t1 = time.monotonic()
                    stderr = (self._slurp(spools[i])
                              if rc != 0 or self.capture_stderr else "")
                    values[i] = ShellResult(rc, out.decode(errors="replace"),
                                            stderr, t1 - t0)
                    errors[i] = None
                    done_k = k + 1
                pending = []
            except (_LaneTimeout, _LaneGone, BrokenPipeError, OSError) as e:
                self._kill(proc)
                survivors = pending
                if sent and done_k < len(pending):
                    head = pending[done_k]
                    if isinstance(e, _LaneTimeout):
                        errors[head] = ("timeout: lane command exceeded "
                                        f"{stanzas[head][1]}s")
                    else:
                        errors[head] = str(e) or "lane worker died"
                    # commands past the read head may already have run:
                    # their sentinels (and per-index spool files) survive
                    # in the pipe buffer — harvest them so only nodes
                    # that never executed are resent
                    survivors = pending[done_k + 1:]
                    harvested = 0
                    for i in survivors:
                        try:
                            rc, out = self._read_result(proc, buf, 0.2)
                        except (_LaneTimeout, _LaneGone, OSError):
                            break
                        stderr = (self._slurp(spools[i])
                                  if rc != 0 or self.capture_stderr else "")
                        values[i] = ShellResult(
                            rc, out.decode(errors="replace"), stderr, 0.0)
                        errors[i] = None
                        harvested += 1
                    survivors = survivors[harvested:]
                proc.wait()
                lane["proc"] = None
                stalls = 0 if len(survivors) < len(pending) else stalls + 1
                if stalls >= 3:     # lane keeps dying without progress
                    for i in survivors:
                        errors[i] = str(e) or "lane worker died"
                    pending = []
                else:
                    pending = survivors
            finally:
                with self._lock:
                    self._active.pop(token, None)
        return values, errors

    def _worker(self, idx: int) -> None:
        lane: dict = {"proc": None, "buf": bytearray()}
        try:
            while True:
                item = self._work.get()
                if item is None:
                    return
                token, nodes = item
                t0 = time.monotonic()
                values, errors = self._run_batch(idx, token, nodes, lane)
                t1 = time.monotonic()
                self.stats.dispatches += 1
                self.stats.tasks += len(nodes)
                self._emit(token, values, errors, t0, t1, f"lane{idx}")
        finally:
            if lane.get("proc") is not None:
                self._kill(lane["proc"])

    def _emit(self, token: int, values: list[Any],
              errors: list[str | None], t0: float, t1: float,
              host: str) -> None:
        if token < 0:       # run_gang internal dispatch
            with self._gang_cv:
                self._gang_out[token] = (values, errors)
                self._gang_cv.notify_all()
            return
        self._events.put(
            CompletionEvent(token, values, errors, t0, t1, host=host))


def payload_timeout(node: TaskNode) -> Any:
    """A node's WDL ``timeout`` keyword, if any."""
    payload = node.payload if isinstance(node.payload, Mapping) else {}
    return payload.get("timeout")


#: every kind ``make_pool`` accepts (remote kinds live in ``remote.py``).
VALID_POOL_KINDS = ("inline", "thread", "process", "lane", "ssh", "slurm",
                    "pbs")


def make_pool(kind: str, slots: int = 1, **remote_kwargs: Any) -> WorkerPool:
    """Construct a pool by name.

    Local kinds: ``inline``, ``thread``, ``process`` (``slots``
    workers), and ``lane`` (``slots`` persistent shell workers; optional
    ``render``, ``batch``, ``cwd`` — the short-task throughput path).
    Remote kinds: ``ssh`` (requires ``hosts``; optional ``ppnode``,
    ``transport``, ``render``) and ``slurm`` / ``pbs`` (optional
    ``nnodes``, ``ppnode``, ``submitter``, ``render``, ``spool_root``)
    — their slot count is ``hosts × ppnode`` / ``nnodes × ppnode``, not
    ``slots``.  An unknown kind raises a ``ValueError`` naming every
    valid kind.
    """
    if kind == "inline":
        return InlinePool()
    if kind == "thread":
        return ThreadWorkerPool(slots)
    if kind == "process":
        return ProcessWorkerPool(slots)
    if kind == "lane":
        for k in ("hosts", "nnodes", "ppnode", "transport", "submitter",
                  "spool_root"):
            remote_kwargs.pop(k, None)
        return LaneWorkerPool(slots, **remote_kwargs)
    if kind == "ssh":
        from .remote import SSHWorkerPool

        hosts = remote_kwargs.pop("hosts", None)
        if not hosts:
            raise ValueError(
                "pool kind 'ssh' requires a non-empty host list "
                "(WDL 'hosts:' keyword or --hosts)")
        remote_kwargs.pop("nnodes", None)
        remote_kwargs.pop("submitter", None)
        remote_kwargs.pop("spool_root", None)
        return SSHWorkerPool(
            hosts, ppnode=remote_kwargs.pop("ppnode", None) or 1,
            **remote_kwargs)
    if kind in ("slurm", "pbs"):
        from .remote import BatchWorkerPool

        remote_kwargs.pop("hosts", None)
        remote_kwargs.pop("transport", None)
        return BatchWorkerPool(
            batch=kind,
            nnodes=remote_kwargs.pop("nnodes", None) or 1,
            ppnode=remote_kwargs.pop("ppnode", None) or 1,
            **remote_kwargs)
    raise ValueError(
        f"unknown pool kind {kind!r}; valid kinds: "
        + ", ".join(VALID_POOL_KINDS))


# ---------------------------------------------------------------------------
# Gang packing
# ---------------------------------------------------------------------------

GroupKeyFn = Callable[[TaskNode], Hashable]
GangRunner = Callable[[Sequence[TaskNode]], Sequence[Any]]


@dataclasses.dataclass
class GangStats:
    """Dispatch accounting — the quantity the paper's Figs. 3/4 compare."""

    groups: int = 0
    tasks: int = 0
    dispatches: int = 0  # one per compiled-program launch

    @property
    def batching_factor(self) -> float:
        return self.tasks / max(1, self.dispatches)


class GangExecutor:
    """Group task instances by a stackability key and dispatch each group
    once.  One dispatch per group is the TPU analogue of "grouping
    intra/inter-workflow tasks as a single batch job" (paper §4.3)."""

    def __init__(self, group_key: GroupKeyFn, gang_runner: GangRunner,
                 max_group: int | None = None) -> None:
        self.group_key = group_key
        self.gang_runner = gang_runner
        self.max_group = max_group
        self.stats = GangStats()

    def run_group(self, chunk: Sequence[TaskNode]) -> list[Any]:
        """Dispatch one stackable chunk as a single program launch."""
        values = list(self.gang_runner(chunk))
        if len(values) != len(chunk):
            raise RuntimeError(
                f"gang runner returned {len(values)} results for "
                f"{len(chunk)} tasks")
        self.stats.groups += 1
        self.stats.dispatches += 1
        self.stats.tasks += len(chunk)
        return values

    def run(self, nodes: Sequence[TaskNode]) -> dict[str, Any]:
        """Group and dispatch a node set directly (no scheduler)."""
        groups: dict[Hashable, list[TaskNode]] = {}
        for n in nodes:
            groups.setdefault(self.group_key(n), []).append(n)
        results: dict[str, Any] = {}
        for _, members in sorted(groups.items(), key=lambda kv: str(kv[0])):
            chunks = (
                [members[i:i + self.max_group]
                 for i in range(0, len(members), self.max_group)]
                if self.max_group else [members]
            )
            for chunk in chunks:
                for node, value in zip(chunk, self.run_group(chunk)):
                    results[node.id] = value
        return results


class GangPool(_SyncPool):
    """Gang dispatch as a pool policy: ``take`` claims an entire
    stackability group from the ready queue and ``submit`` launches it as
    one program.  Replaces the old separate level-synchronous loop — gang
    studies now share the scheduler's retry/closure/journal machinery."""

    kind = "gang"

    def __init__(self, gang: GangExecutor) -> None:
        super().__init__()
        self.gang = gang

    def take(self, ready: list[str], dag: "TaskDAG") -> list[str]:
        groups: dict[str, list[str]] = {}
        for nid in ready:
            groups.setdefault(str(self.gang.group_key(dag.nodes[nid])),
                              []).append(nid)
        members = groups[sorted(groups)[0]]
        if self.gang.max_group:
            members = members[: self.gang.max_group]
        for nid in members:
            ready.remove(nid)
        return members

    def _run_batch(self, runner: Runner | None, nodes: Sequence[TaskNode]):
        t0 = time.monotonic()
        try:
            values = self.gang.run_group(nodes)
            errors: list[str | None] = [None] * len(nodes)
        except Exception as e:  # noqa: BLE001 — whole-batch failure
            msg = f"{type(e).__name__}: {e}"
            values = [None] * len(nodes)
            errors = [msg] * len(nodes)
        t1 = time.monotonic()
        return values, errors, t0, t1


def stackable_key(node: TaskNode) -> Hashable:
    """Default stackability: nodes of the same task whose combos share
    the same *keys* (values may differ — they become per-member arrays).
    Shape-affecting parameters must be embedded in the task name by the
    study author (or use mesh-slice instead)."""
    return (node.task, tuple(sorted(node.combo.keys())))
