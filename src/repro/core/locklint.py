"""Lock-order auditor for the engine's concurrent hot path.

The scheduler event loop itself is single-threaded, but the code around
it is not: the lane-mux front-end thread, per-host SSH worker threads,
gang waiters on a ``Condition``, and the journal/provenance group-commit
writers all synchronize on a handful of named locks.  A deadlock there
is a *lock-order* bug — two threads acquiring the same pair of locks in
opposite orders — and exactly the class of defect that only surfaces
under production load, never in a quick local run.

This module applies the same rule-engine discipline ``repro.core.lint``
applies to studies to the engine itself:

* ``make_lock(name)`` is the factory every engine lock goes through.
  By default it returns a plain ``threading.Lock`` — zero overhead on
  the dispatch hot path.  With ``PAPAS_LOCKLINT=1`` in the environment
  (checked at lock *creation* time) it returns an
  :class:`InstrumentedLock` that reports every acquisition to the
  process-wide :class:`LockOrderAuditor`.
* The auditor maintains the **acquisition-order graph**: a directed
  edge ``A → B`` means some thread acquired ``B`` while holding ``A``.
  A cycle in that graph is a potential deadlock (threads could
  interleave the two orders); ``cycles()`` reports them and
  ``assert_no_cycles()`` raises :class:`LockOrderError`.
* With ``PAPAS_LOCKLINT_OUT=<path>`` the report is additionally written
  as JSON at interpreter exit — the CI concurrency smoke runs the
  lane-mux and group-commit suites under both variables and fails the
  gate on any cycle (see ``scripts/ci.sh``).

``InstrumentedLock`` is duck-type compatible with ``threading.Lock``
including use as the lock of a ``threading.Condition`` (the gang
coordination path): ``Condition`` only needs ``acquire``/``release``,
and the default ``_is_owned`` probe's try-acquire shows up as a
balanced acquire/release pair in the trace.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
from typing import Any

__all__ = [
    "InstrumentedLock",
    "LockOrderAuditor",
    "LockOrderError",
    "enabled",
    "get_auditor",
    "make_lock",
]


class LockOrderError(RuntimeError):
    """Raised by ``assert_no_cycles`` when the acquisition-order graph
    contains a cycle (a potential deadlock)."""


class LockOrderAuditor:
    """Process-wide acquisition-order recorder.

    State is tiny — a set of lock names and a set of ordered name pairs
    with occurrence counts — so auditing a 10^4-task run costs one dict
    update per acquisition.  The per-thread held stack lives in
    thread-local storage; the auditor's own mutex is a *plain* lock and
    is always a leaf (nothing is acquired under it), so the auditor can
    never introduce the deadlocks it hunts.
    """

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._tls = threading.local()
        #: (held name, acquired name) → times observed
        self.edges: dict[tuple[str, str], int] = {}
        self.locks: set[str] = set()
        self.n_acquisitions = 0

    # -- per-thread held stack -----------------------------------------
    def _held(self) -> list[str]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    # -- instrumentation callbacks -------------------------------------
    def note_acquire(self, name: str) -> None:
        held = self._held()
        with self._mu:
            self.locks.add(name)
            self.n_acquisitions += 1
            for h in held:
                if h != name:
                    edge = (h, name)
                    self.edges[edge] = self.edges.get(edge, 0) + 1
        held.append(name)

    def note_release(self, name: str) -> None:
        held = self._held()
        # release order need not be LIFO (Condition.wait, hand-over-hand)
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                break

    # -- analysis -------------------------------------------------------
    def cycles(self) -> list[list[str]]:
        """Every elementary cycle root found by DFS over the name graph
        (each reported once, rotated to start at its smallest name)."""
        adj: dict[str, list[str]] = {}
        for a, b in self.edges:
            adj.setdefault(a, []).append(b)
        seen_cycles: set[tuple[str, ...]] = set()
        out: list[list[str]] = []
        visited: set[str] = set()

        def dfs(node: str, stack: list[str], on_stack: set[str]) -> None:
            visited.add(node)
            stack.append(node)
            on_stack.add(node)
            for nxt in adj.get(node, ()):
                if nxt in on_stack:
                    cyc = stack[stack.index(nxt):]
                    k = min(range(len(cyc)), key=lambda i: cyc[i])
                    canon = tuple(cyc[k:] + cyc[:k])
                    if canon not in seen_cycles:
                        seen_cycles.add(canon)
                        out.append(list(canon))
                elif nxt not in visited:
                    dfs(nxt, stack, on_stack)
            stack.pop()
            on_stack.discard(node)

        for root in sorted(adj):
            if root not in visited:
                dfs(root, [], set())
        return out

    def report(self) -> dict[str, Any]:
        """The acquisition-order graph as a JSON-friendly document."""
        with self._mu:
            edges = sorted(self.edges.items())
            locks = sorted(self.locks)
            n = self.n_acquisitions
        return {
            "locks": locks,
            "n_acquisitions": n,
            "edges": [{"from": a, "to": b, "count": c}
                      for (a, b), c in edges],
            "cycles": self.cycles(),
        }

    def assert_no_cycles(self) -> None:
        cycles = self.cycles()
        if cycles:
            raise LockOrderError(
                f"lock acquisition-order cycle(s) detected — potential "
                f"deadlock: {[' -> '.join(c + [c[0]]) for c in cycles]}")

    def reset(self) -> None:
        with self._mu:
            self.edges.clear()
            self.locks.clear()
            self.n_acquisitions = 0


_AUDITOR = LockOrderAuditor()


def get_auditor() -> LockOrderAuditor:
    """The process-wide auditor (shared by every instrumented lock)."""
    return _AUDITOR


def enabled() -> bool:
    """True when ``PAPAS_LOCKLINT`` asks for instrumented locks."""
    return os.environ.get("PAPAS_LOCKLINT", "") not in ("", "0")


class InstrumentedLock:
    """A ``threading.Lock`` wrapper reporting to the auditor.

    Compatible wherever the engine uses a plain lock: ``with`` blocks,
    explicit ``acquire``/``release``, and as the backing lock of a
    ``threading.Condition``.
    """

    __slots__ = ("name", "_lock", "_auditor")

    def __init__(self, name: str,
                 auditor: LockOrderAuditor | None = None) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._auditor = auditor if auditor is not None else _AUDITOR

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            self._auditor.note_acquire(self.name)
        return ok

    def release(self) -> None:
        self._auditor.note_release(self.name)
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: Any) -> None:
        self.release()

    def __repr__(self) -> str:    # pragma: no cover - debugging aid
        state = "locked" if self._lock.locked() else "unlocked"
        return f"<InstrumentedLock {self.name!r} {state}>"


_atexit_registered = False


def _write_report_atexit() -> None:    # pragma: no cover - exit hook
    out = os.environ.get("PAPAS_LOCKLINT_OUT")
    if not out:
        return
    try:
        with open(out, "w") as f:
            json.dump(_AUDITOR.report(), f, indent=2, sort_keys=True)
    except OSError:
        pass


def make_lock(name: str) -> "threading.Lock | InstrumentedLock":
    """The engine's lock factory: a plain ``threading.Lock`` normally,
    an :class:`InstrumentedLock` reporting to the process auditor when
    ``PAPAS_LOCKLINT=1`` (checked now, at creation time — a pool or
    journal built after flipping the variable is instrumented, existing
    locks are not)."""
    global _atexit_registered
    if not enabled():
        return threading.Lock()
    if not _atexit_registered:
        _atexit_registered = True
        atexit.register(_write_report_atexit)
    return InstrumentedLock(name, _AUDITOR)
