"""Results subsystem — declarative metric capture + streaming aggregation.

The paper's performance studies (§6: the OpenMP matmul sweep) end in a
*table*: metrics extracted from every task's output, aggregated over the
swept space into speedup/efficiency curves.  This module is that layer:

* **Declarative extractors** (``CaptureSpec`` / ``CaptureSet``) — the
  WDL ``capture:`` task keyword names metrics and says where each one
  comes from: a regex group over stdout/stderr/an output file, a JSON or
  CSV field path, or a built-in the engine already measures (``rc``,
  ``duration``, ``host``, ``slot``).  Extracted text is type-inferred
  like WDL scalar values.  A metric is ``required`` or optional: a
  missing *required* metric classifies the attempt as a task failure
  (same machinery as a nonzero exit — retries and failure closure
  apply), a missing optional metric records ``null``.
* **Streaming aggregation** (``ResultsAggregator``) — consumes the
  engine's per-completion result stream (``ParameterStudy.run(
  aggregator=…, keep_results=False)``), grouping by any parameter (or
  captured-metric) subset and maintaining count/mean/min/max/std via
  Welford accumulators plus an exact median on the scheduler's dual-heap
  stream.  Group state is O(groups) — a 10^5-instance windowed run with
  ``keep_results=False`` aggregates without ever materializing results.
  (The exact median additionally keeps each group's samples on its two
  heaps; pass ``track_median=False`` for strictly O(1) per-group
  state.)
* **Derived performance-study metrics** — ``speedup()`` computes
  speedup and parallel efficiency relative to a declared baseline
  combination (the WDL ``baseline:`` keyword, e.g. 1 thread), the
  paper's Fig. 6/7 curves, from the same O(groups) state.

Captured metrics persist through ``StudyDB.record(metrics=…)`` on the
group-commit path, so they ride the same durability guarantees as the
journal and survive a journal-v2 resume: completed instances are never
re-extracted, and ``repro.launch.report`` reproduces any live table
offline from ``records.jsonl``.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import json
import math
import re
from typing import Any, Callable, Iterable, Iterator, Mapping, Sequence

from .interpolate import interpolate
from .scheduler import _StreamingMedian

#: metrics the engine measures itself — always present, never "missing".
BUILTIN_CAPTURES = ("rc", "duration", "host", "slot")

#: sources a text extractor may read from.
_SOURCES = ("stdout", "stderr")


class CaptureError(ValueError):
    """Raised on a malformed ``capture:`` declaration.

    ``keyword`` carries the keyword path of the offending entry relative
    to the task (e.g. ``capture.gflops.regex``) so parse diagnostics can
    point at the exact WDL line (see ``WDLError.with_context``).
    """

    def __init__(self, message: str, keyword: str | None = None) -> None:
        super().__init__(message)
        self.keyword = keyword


def infer_scalar(text: str) -> Any:
    """Type-infer one captured scalar, mirroring WDL value inference for
    scalars (int, then float, then bool, else the raw string).  Range
    syntax is deliberately *not* expanded — ``16:32`` in task output is
    data, not a sweep declaration."""
    txt = text.strip()
    for caster in (int, float):
        try:
            return caster(txt)
        except ValueError:
            continue
    if txt.lower() in ("true", "false"):
        return txt.lower() == "true"
    return text


_CASTERS: dict[str, Callable[[str], Any]] = {
    "int": lambda s: int(float(s)),
    "float": float,
    "str": str,
    "bool": lambda s: s.strip().lower() in ("1", "true", "yes", "on"),
}


@dataclasses.dataclass(frozen=True)
class CaptureSpec:
    """One declared metric: where it comes from and how to read it.

    ``kind`` is ``regex`` (``pattern`` + ``group``), ``json`` / ``csv``
    (``path`` — a dotted field path / a column name), or ``builtin``
    (``path`` names one of ``rc``/``duration``/``host``/``slot``).
    ``source`` is ``stdout`` (default), ``stderr``, ``outfile:<name>``
    (the task's declared output file, path template rendered per
    instance), or ``file:<template>`` (any path template).  ``cast``
    forces the type; otherwise scalar WDL inference applies.
    """

    name: str
    kind: str                       # regex | json | csv | builtin
    pattern: re.Pattern | None = None
    path: str | None = None         # json/csv field path or builtin name
    group: int | str | None = None  # regex group override
    source: str = "stdout"
    required: bool = False
    cast: str | None = None

    def convert(self, raw: Any) -> Any:
        if raw is None:
            return None
        if self.cast is not None:
            return _CASTERS[self.cast](raw if isinstance(raw, str)
                                       else str(raw))
        return infer_scalar(raw) if isinstance(raw, str) else raw


def parse_capture(task: str, name: str, raw: Any) -> CaptureSpec:
    """Parse one ``capture:`` entry.

    Shorthand (string value): a builtin name (``rc``, ``duration``,
    ``host``, ``slot``) or a regex applied to stdout (optional metric —
    mark required via the mapping form).  Mapping form: exactly one of
    ``regex:`` / ``json:`` / ``csv:`` / ``builtin:``, plus optional
    ``source:``, ``required:``, ``type:``, ``group:``.
    """
    where = f"task {task!r}: capture {name!r}"
    kwpath = f"capture.{name}"
    if isinstance(raw, str):
        if raw in BUILTIN_CAPTURES:
            return CaptureSpec(name=name, kind="builtin", path=raw)
        return CaptureSpec(name=name, kind="regex",
                           pattern=_compile(where, raw, kwpath))
    if not isinstance(raw, Mapping):
        raise CaptureError(
            f"{where}: entry must be a string (regex or builtin name) "
            f"or a mapping, got {type(raw).__name__}", kwpath)
    body = {str(k): v for k, v in raw.items()}
    kinds = [k for k in ("regex", "json", "csv", "builtin") if k in body]
    if len(kinds) != 1:
        raise CaptureError(
            f"{where}: declare exactly one of regex/json/csv/builtin "
            f"(got {kinds or 'none'})", kwpath)
    kind = kinds[0]
    extra = set(body) - {kind, "source", "required", "type", "group"}
    if extra:
        raise CaptureError(
            f"{where}: unknown key(s) {sorted(extra)} (valid: "
            f"regex/json/csv/builtin, source, required, type, group)", kwpath)
    source = str(body.get("source", "stdout"))
    if kind == "builtin":
        if "source" in body:
            raise CaptureError(f"{where}: builtin captures take no source",
                               f"{kwpath}.source")
        if body["builtin"] not in BUILTIN_CAPTURES:
            raise CaptureError(
                f"{where}: unknown builtin {body['builtin']!r} "
                f"(valid: {', '.join(BUILTIN_CAPTURES)})",
                f"{kwpath}.builtin")
    elif source not in _SOURCES and not source.startswith(("outfile:",
                                                           "file:")):
        raise CaptureError(
            f"{where}: unknown source {source!r} (valid: stdout, stderr, "
            f"outfile:<name>, file:<path template>)", f"{kwpath}.source")
    cast = body.get("type")
    if cast is not None and str(cast) not in _CASTERS:
        raise CaptureError(
            f"{where}: unknown type {cast!r} "
            f"(valid: {', '.join(sorted(_CASTERS))})", f"{kwpath}.type")
    required = body.get("required", False)
    if not isinstance(required, bool):
        required = str(required).strip().lower() in ("1", "true", "yes", "on")
    group = body.get("group")
    if group is not None and not isinstance(group, int):
        group = str(group)
    if kind == "regex":
        pattern = _compile(where, str(body["regex"]), f"{kwpath}.regex")
    else:
        pattern = None
    path = None
    if kind in ("json", "csv", "builtin"):
        path = str(body[kind])
        if not path:
            raise CaptureError(f"{where}: empty {kind} field path",
                               f"{kwpath}.{kind}")
    return CaptureSpec(name=name, kind=kind, pattern=pattern, path=path,
                       group=group, source=source, required=required,
                       cast=str(cast) if cast is not None else None)


def _compile(where: str, pattern: str,
             keyword: str | None = None) -> re.Pattern:
    try:
        return re.compile(pattern)
    except re.error as e:
        raise CaptureError(f"{where}: bad regex {pattern!r}: {e}",
                           keyword) from e


def parse_captures(task: str, raw: Any) -> dict[str, CaptureSpec]:
    """Parse a whole ``capture:`` block (metric name → spec)."""
    if not isinstance(raw, Mapping):
        raise CaptureError(
            f"task {task!r}: capture must be a mapping of metric names",
            "capture")
    return {str(name): parse_capture(task, str(name), val)
            for name, val in raw.items()}


class CaptureSet:
    """All of one task's compiled extractors, applied to a task value.

    ``extract`` pulls the text-sourced metrics (regex/json/csv) out of a
    completed attempt's value — a ``ShellResult`` contributes stdout and
    stderr; any other value stringifies as its stdout — and reports
    which *required* metrics are missing (the scheduler classifies that
    as an attempt failure).  ``finalize`` fills the built-ins from the
    resolved ``TaskResult`` (rc, duration, host, slot), which only exist
    once the scheduler has resolved the node.
    """

    def __init__(self, task: str,
                 specs: Mapping[str, CaptureSpec],
                 outfiles: Mapping[str, str] | None = None) -> None:
        self.task = task
        self.specs = dict(specs)
        self.outfiles = dict(outfiles or {})
        self.text_specs = [s for s in self.specs.values()
                           if s.kind != "builtin"]
        self.builtin_specs = [s for s in self.specs.values()
                              if s.kind == "builtin"]

    @property
    def uses_stderr(self) -> bool:
        """True when any extractor reads stderr — backends that spool
        stderr lazily (worker lanes) must route it back eagerly."""
        return any(s.source == "stderr" for s in self.text_specs)

    # -- source resolution ---------------------------------------------
    def _source_text(self, spec: CaptureSpec, value: Any,
                     combo: Mapping[str, Any] | None) -> str | None:
        if spec.source == "stdout":
            if hasattr(value, "stdout"):
                return value.stdout or ""
            return "" if value is None else str(value)
        if spec.source == "stderr":
            return (value.stderr or "") if hasattr(value, "stderr") else ""
        if spec.source.startswith("outfile:"):
            name = spec.source[len("outfile:"):]
            template = self.outfiles.get(name)
            if template is None:
                return None
            return self._read_file(template, combo)
        return self._read_file(spec.source[len("file:"):], combo)

    def _read_file(self, template: str,
                   combo: Mapping[str, Any] | None) -> str | None:
        try:
            path = interpolate(template, combo or {}, self.task)
        except KeyError:
            return None
        try:
            with open(path) as f:
                return f.read()
        except OSError:
            return None

    # -- extraction -----------------------------------------------------
    def extract(self, value: Any,
                combo: Mapping[str, Any] | None = None
                ) -> tuple[dict[str, Any], list[str]]:
        """Text-sourced metrics from one attempt's value: ``(metrics,
        missing required names)``.  Builtins are deferred to
        ``finalize`` (they come from the resolved ``TaskResult``)."""
        metrics: dict[str, Any] = {}
        missing: list[str] = []
        json_cache: dict[str, Any] = {}
        for spec in self.text_specs:
            text = self._source_text(spec, value, combo)
            raw = None if text is None else self._pull(spec, text, value,
                                                       json_cache)
            if raw is None:
                metrics[spec.name] = None
                if spec.required:
                    missing.append(spec.name)
            else:
                try:
                    metrics[spec.name] = spec.convert(raw)
                except (TypeError, ValueError):
                    metrics[spec.name] = None
                    if spec.required:
                        missing.append(spec.name)
        return metrics, missing

    def _pull(self, spec: CaptureSpec, text: str, value: Any,
              json_cache: dict[int, Any]) -> Any:
        if spec.kind == "regex":
            return _last_match(spec, text)
        if spec.kind == "json":
            # one parse per distinct source per attempt, shared across
            # every json capture reading it
            if spec.source not in json_cache:
                json_cache[spec.source] = _json_doc(text, value, spec.source)
            return _json_path(json_cache[spec.source], spec.path or "")
        if spec.kind == "csv":
            return _csv_field(text, spec.path or "")
        return None     # pragma: no cover - builtins never reach here

    def finalize(self, metrics: Mapping[str, Any] | None,
                 result: Any) -> dict[str, Any]:
        """Merge text metrics with built-ins measured by the engine,
        preserving declaration order.  ``result`` is the resolved
        ``TaskResult`` (duck-typed: runtime/host/slot/value)."""
        text = dict(metrics or {})
        out: dict[str, Any] = {}
        for name, spec in self.specs.items():
            if spec.kind != "builtin":
                out[name] = text.get(name)
                continue
            builtin = spec.path
            if builtin == "rc":
                out[name] = getattr(getattr(result, "value", None),
                                    "returncode", None)
            elif builtin == "duration":
                out[name] = getattr(result, "runtime", None)
            elif builtin == "host":
                out[name] = getattr(result, "host", None)
            else:       # slot
                out[name] = getattr(result, "slot", None)
            if spec.cast is not None and out[name] is not None:
                try:
                    out[name] = _CASTERS[spec.cast](str(out[name]))
                except (TypeError, ValueError):
                    pass
        return out


def _last_match(spec: CaptureSpec, text: str) -> str | None:
    """The last match wins: performance runs often log progressively and
    the final line is the settled measurement."""
    last: re.Match | None = None
    for m in spec.pattern.finditer(text):    # type: ignore[union-attr]
        last = m
    if last is None:
        return None
    if spec.group is not None:
        try:
            return last.group(spec.group)
        except IndexError:      # unknown group name/number
            return None
    if "value" in (last.groupdict() or {}):
        return last.group("value")
    return last.group(1) if last.re.groups else last.group(0)


def _json_doc(text: str, value: Any, source: str) -> Any:
    """The parsed JSON document for a source: a Mapping/list value is
    used directly (registry tasks return structured results), text is
    parsed."""
    if source == "stdout" and isinstance(value, (Mapping, list)):
        return value
    try:
        return json.loads(text)
    except (json.JSONDecodeError, TypeError):
        return None


def _json_path(doc: Any, path: str) -> Any:
    """Navigate a dotted field path (``perf.gflops`` / ``runs.0.time``)."""
    cur = doc
    for part in path.split("."):
        if isinstance(cur, Mapping):
            if part not in cur:
                return None
            cur = cur[part]
        elif isinstance(cur, Sequence) and not isinstance(cur, str):
            try:
                cur = cur[int(part)]
            except (ValueError, IndexError):
                return None
        else:
            return None
    return None if isinstance(cur, (Mapping, list)) else cur


def _csv_field(text: str, column: str) -> str | None:
    """A column from the *last* data row of CSV text.  The first row is
    the header; a purely numeric ``column`` falls back to a positional
    index when no header matches."""
    rows = [r for r in csv.reader(io.StringIO(text)) if r]
    if not rows:
        return None
    header, data = rows[0], rows[1:]
    if column in header:
        if not data:
            return None
        idx = header.index(column)
        row = data[-1]
        return row[idx] if idx < len(row) else None
    if column.lstrip("-").isdigit():
        if not data:    # header-only text must read as missing, not as
            return None  # a header cell
        try:
            return data[-1][int(column)]
        except IndexError:
            return None
    return None


def build_capture_sets(spec: Any) -> dict[str, CaptureSet]:
    """Per-task compiled capture sets for a ``StudySpec`` (tasks without
    a ``capture:`` block contribute nothing)."""
    out: dict[str, CaptureSet] = {}
    for tname, task in spec.tasks.items():
        if getattr(task, "capture", None):
            out[tname] = CaptureSet(tname, task.capture, task.outfiles)
    return out


# ---------------------------------------------------------------------------
# Streaming aggregation
# ---------------------------------------------------------------------------


class MetricStats:
    """Streaming accumulator for one (group, metric) cell: count, mean,
    min, max via Welford's algorithm (numerically stable, O(1) state),
    plus an exact median on the scheduler's dual-heap stream (O(n)
    samples retained — disable with ``track_median=False`` for strictly
    O(1) cells)."""

    __slots__ = ("n", "mean", "_m2", "min", "max", "_median")

    def __init__(self, track_median: bool = True) -> None:
        self.n = 0
        self.mean = 0.0
        self._m2 = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._median = _StreamingMedian() if track_median else None

    def add(self, x: float) -> None:
        x = float(x)
        self.n += 1
        delta = x - self.mean
        self.mean += delta / self.n
        self._m2 += delta * (x - self.mean)
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if self._median is not None:
            self._median.add(x)

    @property
    def std(self) -> float:
        """Sample standard deviation (0.0 below two samples)."""
        return math.sqrt(self._m2 / (self.n - 1)) if self.n > 1 else 0.0

    @property
    def median(self) -> float | None:
        """The upper median — matches ``sorted(xs)[len(xs) // 2]``."""
        if self._median is None or self.n == 0:
            return None
        return self._median.median()

    def stat(self, name: str) -> float | int | None:
        if self.n == 0:
            return None
        if name == "count":
            return self.n
        if name == "mean":
            return self.mean
        if name == "min":
            return self.min
        if name == "max":
            return self.max
        if name == "std":
            return self.std
        if name == "median":
            return self.median
        raise ValueError(
            f"unknown stat {name!r} (valid: {', '.join(STATS)})")

    def as_dict(self) -> dict[str, Any]:
        return {s: self.stat(s) for s in STATS
                if not (s == "median" and self._median is None)}


STATS = ("count", "mean", "std", "min", "max", "median")


def _canon(v: Any) -> Any:
    """Canonical group-key element: integral floats fold to int so a
    CLI-typed baseline (``threads=1``) matches a WDL-typed combo value."""
    if isinstance(v, bool):
        return v
    if isinstance(v, float) and v.is_integer():
        return int(v)
    return v


class KeyResolutionError(KeyError):
    """A group-by / baseline key matched no (or several) parameters."""


def resolve_key(key: str, available: Iterable[str]) -> str | None:
    """Resolve a short key against available names, mirroring WDL
    interpolation lookup: exact match first, then a unique tail match
    after ``:`` or ``/`` (``size`` → ``args:size``, ``t/args:size``)."""
    names = list(available)
    if key in names:
        return key
    tails = [n for n in names
             if n.endswith(":" + key) or n.endswith("/" + key)]
    if len(tails) == 1:
        return tails[0]
    if len(tails) > 1:
        raise KeyResolutionError(
            f"ambiguous key {key!r}: matches {sorted(tails)}")
    return None


class ResultsAggregator:
    """Group-by aggregation over a stream of (combo, metrics) pairs.

    State is O(groups × metrics) accumulator cells — never O(results) —
    so a windowed ``keep_results=False`` run aggregates 10^5 instances
    in constant memory per group.  Wire it into a run via
    ``ParameterStudy.run(aggregator=…)`` (the engine feeds every ``ok``
    resolution), or replay a finished study with ``add_records``.

    ``group_by`` keys name parameters (short forms resolve like WDL
    interpolation: ``size`` matches ``args:size``) or captured metrics
    (``threads`` matches a ``capture: threads:`` extraction) — so a
    study can pivot on a value the task *reported* as easily as one it
    was *given*.  ``metrics`` restricts which captured metrics
    aggregate; default: every numeric metric seen.
    """

    def __init__(self, group_by: Sequence[str],
                 metrics: Sequence[str] | None = None,
                 track_median: bool = True) -> None:
        if not group_by:
            raise ValueError("group_by must name at least one key")
        self.group_by = [str(k) for k in group_by]
        self.metrics = [str(m) for m in metrics] if metrics else None
        self.track_median = track_median
        #: group key tuple → metric name → MetricStats
        self.groups: dict[tuple, dict[str, MetricStats]] = {}
        self.n_results = 0          # results offered
        self.n_grouped = 0          # results that resolved every group key
        #: group key → resolution failure (ambiguous/unmatched) — a live
        #: run must not crash mid-study on a bad --group-by; callers
        #: surface these after the run instead
        self.key_errors: dict[str, str] = {}
        #: combo-keyset → per-group-key (resolved name, from_metrics)
        self._plans: dict[tuple[str, ...], list[tuple[str, bool]] | None] = {}

    # -- key resolution ---------------------------------------------------
    def _plan(self, combo: Mapping[str, Any],
              metrics: Mapping[str, Any]) -> list[tuple[str, bool]] | None:
        sig = tuple(combo) + ("|",) + tuple(sorted(metrics))
        if sig in self._plans:
            return self._plans[sig]
        plan: list[tuple[str, bool]] | None = []
        for key in self.group_by:
            try:
                name = resolve_key(key, combo)
                if name is None:
                    name = resolve_key(key, metrics)
            except KeyResolutionError as e:
                self.key_errors[key] = str(e)
                name = None
            if name is None:
                plan = None
                break
            plan.append((name, name in metrics and name not in combo))
        self._plans[sig] = plan
        return plan

    # -- ingestion --------------------------------------------------------
    def add(self, combo: Mapping[str, Any],
            metrics: Mapping[str, Any] | None = None) -> bool:
        """Fold one completed instance in.  Returns False when a group
        key resolves against neither the combo nor the metrics (the
        result is counted but not grouped — multi-task studies capture
        on a subset of tasks)."""
        self.n_results += 1
        metrics = metrics or {}
        plan = self._plan(combo, metrics)
        if plan is None:
            return False
        key = tuple(_canon(metrics[name] if from_m else combo[name])
                    for name, from_m in plan)
        cells = self.groups.get(key)
        if cells is None:
            cells = self.groups[key] = {}
        for mname, mval in metrics.items():
            if self.metrics is not None and mname not in self.metrics:
                continue
            if isinstance(mval, bool) or not isinstance(mval, (int, float)):
                continue
            stats = cells.get(mname)
            if stats is None:
                stats = cells[mname] = MetricStats(self.track_median)
            stats.add(mval)
        self.n_grouped += 1
        return True

    def add_records(self, records: Iterable[Mapping[str, Any]]) -> int:
        """Replay provenance records (``StudyDB.records()`` /
        ``records.jsonl`` lines): the latest ``ok`` record per task id
        wins, so a resumed or retried study aggregates each instance
        exactly once.  Returns the number of instances folded in."""
        latest: dict[str, Mapping[str, Any]] = {}
        for rec in records:
            if rec.get("status") == "ok" and rec.get("combo") is not None:
                latest[rec["task_id"]] = rec
        n = 0
        for rec in latest.values():
            if self.add(rec["combo"], rec.get("metrics") or {}):
                n += 1
        return n

    # -- queries ----------------------------------------------------------
    def metric_names(self) -> list[str]:
        names: list[str] = []
        for cells in self.groups.values():
            for m in cells:
                if m not in names:
                    names.append(m)
        return names

    def table(self, metric: str, stat: str = "mean"
              ) -> dict[tuple, float | int | None]:
        """Group key tuple → one statistic of one metric."""
        out: dict[tuple, float | int | None] = {}
        for key, cells in self.groups.items():
            stats = cells.get(metric)
            out[key] = stats.stat(stat) if stats is not None else None
        return out

    def summary(self, metric: str) -> dict[tuple, dict[str, Any]]:
        """Group key tuple → every statistic of one metric."""
        return {key: cells[metric].as_dict()
                for key, cells in sorted(self.groups.items(),
                                         key=lambda kv: _sort_key(kv[0]))
                if metric in cells}

    # -- derived performance-study metrics --------------------------------
    def _baseline_axis(self, baseline: Mapping[str, Any]) -> tuple[int, Any]:
        if len(baseline) != 1:
            raise ValueError(
                "baseline must pin exactly one group-by axis to a value "
                f"(got {dict(baseline)!r})")
        (bkey, bval), = baseline.items()
        axis = None
        for i, g in enumerate(self.group_by):
            if g == bkey or resolve_key(bkey, [g]) is not None \
                    or resolve_key(g, [bkey]) is not None:
                axis = i
                break
        if axis is None:
            raise KeyResolutionError(
                f"baseline key {bkey!r} is not a group-by axis "
                f"(axes: {self.group_by})")
        return axis, _canon(bval)

    def speedup(self, metric: str, baseline: Mapping[str, Any],
                stat: str = "mean"
                ) -> dict[tuple, dict[str, float | None]]:
        """Speedup and parallel efficiency per group, relative to the
        baseline combination (paper Fig. 6/7).

        ``baseline`` pins one group-by axis to its reference value
        (``{"threads": 1}``).  For every group, speedup is
        ``stat(metric)`` at the baseline point (same values on every
        *other* axis) divided by the group's own; efficiency divides
        speedup by the axis ratio (``threads / baseline_threads``) when
        both are numeric.  Groups with no recorded baseline point get
        ``None``."""
        axis, bval = self._baseline_axis(baseline)
        cells = self.table(metric, stat)
        out: dict[tuple, dict[str, float | None]] = {}
        for key, val in cells.items():
            base_key = key[:axis] + (bval,) + key[axis + 1:]
            base = cells.get(base_key)
            speedup = eff = None
            # explicit None checks: a legitimate 0 aggregate is data,
            # not a missing baseline (only division by 0 stays None)
            if val is not None and base is not None and val != 0:
                speedup = base / val
                axis_val = key[axis]
                if isinstance(axis_val, (int, float)) \
                        and isinstance(bval, (int, float)) and bval != 0 \
                        and axis_val != 0:
                    eff = speedup / (axis_val / bval)
            out[key] = {"value": val, "speedup": speedup,
                        "efficiency": eff}
        return out


def _sort_key(key: tuple) -> tuple:
    """Sort group tuples with mixed types: numerics first, by value."""
    return tuple((0, v) if isinstance(v, (int, float))
                 and not isinstance(v, bool) else (1, str(v))
                 for v in key)
