"""Zero-cost-when-disarmed observability: spans, metrics, live status.

The engine is fast enough (10^4 tasks/s) that *observing* it becomes
the interesting problem: where does slot time go, how does the adaptive
batch ramp, when does a retry storm start?  This module answers with
three pillars, all riding the seam pattern the chaos harness
established — components capture :func:`current` once at construction,
and that seam is ``None`` unless the run was armed, so the disarmed
engine pays one identity check per seam and nothing else.

* **Task-lifecycle spans** (:class:`TraceCollector`) — the scheduler
  emits a slice per dispatch on a per-slot track (retry and speculative
  attempts are further slices on the same track, flagged in ``args``),
  the lane pool a slice per frame on a per-lane track, the SSH pool a
  slice per remote batch on a per-``host/lane`` track, and the
  group-commit writers a slice per flush.  Retry backoff waits are
  async slices; chaos ``FaultLedger`` firings are instant events.
  ``trace.json`` serializes the run in Chrome trace-event format —
  open it at https://ui.perfetto.dev or ``chrome://tracing``.  Track
  ids are assigned per track *name*, so a respawned lane keeps its tid.

* **Metrics** (:class:`MetricsRegistry`) — O(1) streaming counters,
  gauges, and histograms (quantiles via
  :class:`~repro.core.stats.StreamingQuantile`): dispatches, slot
  occupancy, ready-queue depth, adaptive batch size, retry classes
  from ``classify_failure``, quarantine strikes/probes, group-commit
  appends/flushes per shard, lane respawns.  The end-of-run snapshot
  lands in ``study.json`` under ``telemetry``;
  :meth:`MetricsRegistry.prometheus` renders text exposition format.

* **Live status** (:meth:`Telemetry.status` / :meth:`Telemetry.serve`)
  — an in-place TTY progress line (``sweep.py --status``) with tasks/s
  and an ETA from the streaming median runtime, and a stdlib
  ``http.server`` thread (``sweep.py --metrics-port N``) serving
  ``/metrics`` (Prometheus) and ``/status`` (JSON) — the seam a
  future study service grows into.

Arm a run with ``ParameterStudy.run(trace=...)``, ``sweep.py
--trace``, or ``PAPAS_TRACE=1`` (or ``PAPAS_TRACE=/path/trace.json``)
in the environment.  Emission uses explicit caller-supplied timestamps
(the scheduler passes its own ``clock()`` readings), so traces from
``VirtualClock`` runs carry exact virtual timings.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from contextlib import contextmanager
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Any, Iterator, TextIO

from .stats import StreamingQuantile

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Telemetry",
    "TraceCollector",
    "activated",
    "current",
    "install",
]


# ---------------------------------------------------------------------------
# metrics registry


def _full_name(name: str, labels: dict[str, Any]) -> str:
    """Prometheus-style series name: ``name{k="v",...}`` (sorted keys)."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter.  ``inc`` is O(1) under the registry lock."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock) -> None:
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-value gauge with relative updates for incremental tracking."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock) -> None:
        self.name = name
        self.value = 0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v

    def add(self, delta: float) -> None:
        with self._lock:
            self.value += delta


class Histogram:
    """Streaming histogram: count/sum/min/max plus p50/p90 via
    :class:`StreamingQuantile` — O(1) memory regardless of sample count."""

    __slots__ = ("name", "count", "total", "min", "max", "_p50", "_p90",
                 "_lock")

    def __init__(self, name: str, lock: threading.Lock) -> None:
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._p50 = StreamingQuantile(0.5)
        self._p90 = StreamingQuantile(0.9)
        self._lock = lock

    def observe(self, x: float) -> None:
        with self._lock:
            self.count += 1
            self.total += x
            if x < self.min:
                self.min = x
            if x > self.max:
                self.max = x
            self._p50.add(x)
            self._p90.add(x)

    def snapshot(self) -> dict[str, float]:
        with self._lock:
            if not self.count:
                return {"count": 0, "sum": 0.0}
            return {"count": self.count, "sum": round(self.total, 6),
                    "min": round(self.min, 6), "max": round(self.max, 6),
                    "p50": round(self._p50.quantile(), 6),
                    "p90": round(self._p90.quantile(), 6)}


class MetricsRegistry:
    """Name → metric map with get-or-create accessors.

    One lock serializes creation and every update; hot paths resolve
    their metric objects once (outside the loop) so steady-state cost
    is a single lock + add per event.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, Any] = {}

    def _get(self, cls: type, name: str, labels: dict[str, Any]) -> Any:
        key = _full_name(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(key, self._lock)
            elif type(m) is not cls:
                raise TypeError(
                    f"metric {key!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get(Histogram, name, labels)

    def value(self, name: str, **labels: Any) -> Any:
        """Current value of a series (0 when never touched)."""
        with self._lock:
            m = self._metrics.get(_full_name(name, labels))
        if m is None:
            return 0
        if isinstance(m, Histogram):
            return m.snapshot()
        return m.value

    def sum_values(self, prefix: str) -> float:
        """Sum every counter/gauge whose series name starts with
        ``prefix`` — aggregates a labeled family, e.g. all retry kinds."""
        with self._lock:
            series = list(self._metrics.values())
        return sum(m.value for m in series
                   if not isinstance(m, Histogram)
                   and m.name.startswith(prefix))

    def snapshot(self) -> dict[str, Any]:
        """JSON-serializable dump of every series (study.json payload)."""
        with self._lock:
            series = list(self._metrics.items())
        out: dict[str, Any] = {}
        for key, m in series:
            out[key] = m.snapshot() if isinstance(m, Histogram) else m.value
        return out

    def prometheus(self) -> str:
        """Text exposition format; histograms render as summaries."""
        with self._lock:
            series = list(self._metrics.items())
        lines: list[str] = []
        typed: set[str] = set()
        for key, m in series:
            base = key.split("{", 1)[0]
            if isinstance(m, Histogram):
                if base not in typed:
                    typed.add(base)
                    lines.append(f"# TYPE {base} summary")
                snap = m.snapshot()
                for q, field in (("0.5", "p50"), ("0.9", "p90")):
                    if field in snap:
                        lines.append(
                            f"{_label_merge(key, 'quantile', q)} "
                            f"{snap[field]}")
                lines.append(f"{_suffix(key, '_count')} {snap['count']}")
                lines.append(f"{_suffix(key, '_sum')} {snap['sum']}")
                continue
            kind = "counter" if isinstance(m, Counter) else "gauge"
            if base not in typed:
                typed.add(base)
                lines.append(f"# TYPE {base} {kind}")
            lines.append(f"{key} {m.value}")
        return "\n".join(lines) + "\n"


def _label_merge(key: str, label: str, value: str) -> str:
    """Insert one more label into a possibly-labeled series name."""
    if key.endswith("}"):
        return f'{key[:-1]},{label}="{value}"}}'
    return f'{key}{{{label}="{value}"}}'


def _suffix(key: str, suffix: str) -> str:
    """Append ``_count``/``_sum`` to the metric name, keeping labels."""
    if "{" in key:
        base, rest = key.split("{", 1)
        return f"{base}{suffix}{{{rest}"
    return key + suffix


# ---------------------------------------------------------------------------
# trace collector (Chrome trace-event format)


class TraceCollector:
    """Accumulates Chrome trace events with explicit timestamps.

    Timestamps are caller-supplied seconds (the emitting component's
    own clock — ``time.monotonic`` or a ``VirtualClock``); only their
    differences are meaningful, which is all a trace viewer needs.
    Track ids (``tid``) are assigned per track *name* string, so the
    same logical track ("lane3", "host:h0/1") keeps a stable tid even
    when the OS thread behind it is respawned.
    """

    PID = 1

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: list[dict[str, Any]] = []
        self._tids: dict[str, int] = {}

    def _tid(self, track: str) -> int:
        # caller holds self._lock
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
            self._events.append(
                {"ph": "M", "name": "thread_name", "pid": self.PID,
                 "tid": tid, "args": {"name": track}})
        return tid

    def _emit(self, ph: str, track: str, name: str | None, ts: float,
              cat: str, args: dict[str, Any] | None,
              **extra: Any) -> None:
        ev: dict[str, Any] = {"ph": ph, "pid": self.PID, "ts": ts * 1e6,
                              "cat": cat}
        if name is not None:
            ev["name"] = name
        if args:
            ev["args"] = dict(args)
        ev.update(extra)
        with self._lock:
            ev["tid"] = self._tid(track)
            self._events.append(ev)

    def begin(self, track: str, name: str, ts: float, cat: str = "task",
              args: dict[str, Any] | None = None) -> None:
        """Open a duration slice (``B``) on ``track`` at ``ts`` seconds."""
        self._emit("B", track, name, ts, cat, args)

    def end(self, track: str, ts: float, cat: str = "task",
            args: dict[str, Any] | None = None) -> None:
        """Close the innermost open slice (``E``) on ``track``."""
        self._emit("E", track, None, ts, cat, args)

    def complete(self, track: str, name: str, t0: float, t1: float,
                 cat: str = "task",
                 args: dict[str, Any] | None = None) -> None:
        """Emit a retroactive ``B``/``E`` pair (both ends known)."""
        self._emit("B", track, name, t0, cat, args)
        self._emit("E", track, None, t1, cat, None)

    def instant(self, track: str, name: str, ts: float,
                cat: str = "mark",
                args: dict[str, Any] | None = None) -> None:
        """Thread-scoped instant event (``i``) — e.g. a chaos firing."""
        self._emit("i", track, name, ts, cat, args, s="t")

    def async_begin(self, track: str, name: str, id_: str, ts: float,
                    cat: str = "wait",
                    args: dict[str, Any] | None = None) -> None:
        """Open an async slice — for waits that overlap on one track
        (retry backoffs), where ``B``/``E`` stack discipline won't hold."""
        self._emit("b", track, name, ts, cat, args, id=id_)

    def async_end(self, track: str, name: str, id_: str, ts: float,
                  cat: str = "wait") -> None:
        self._emit("e", track, name, ts, cat, None, id=id_)

    def events(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def write(self, path: str | Path) -> Path:
        """Serialize as ``{"traceEvents": [...]}`` (Perfetto-loadable)."""
        path = Path(path)
        doc = {"traceEvents": self.events(), "displayTimeUnit": "ms"}
        path.write_text(json.dumps(doc) + "\n")
        return path


# ---------------------------------------------------------------------------
# controller: metrics + trace + status + HTTP surface


class _TelemetryHandler(BaseHTTPRequestHandler):
    """``/metrics`` (Prometheus text) + ``/status`` (JSON) endpoints."""

    telemetry: "Telemetry"

    def do_GET(self) -> None:      # noqa: N802 (stdlib handler API)
        if self.path == "/metrics":
            body = self.telemetry.metrics.prometheus().encode()
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif self.path in ("/", "/status"):
            body = (json.dumps(self.telemetry.status(), default=str)
                    + "\n").encode()
            ctype = "application/json"
        else:
            self.send_error(404)
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt: str, *args: Any) -> None:
        pass    # keep the TTY clean: no per-request access log


class Telemetry:
    """One armed run's worth of observability state.

    Bundles a :class:`TraceCollector` and a :class:`MetricsRegistry`,
    tracks run shape (total/slots) for the status line, and can serve
    both over HTTP.  Install one with :func:`install`/:func:`activated`
    or pass it to ``ParameterStudy.run(trace=...)``.
    """

    def __init__(self, path: str | Path | None = None) -> None:
        self.trace = TraceCollector()
        self.metrics = MetricsRegistry()
        #: trace.json destination; ``None`` → ``<study dir>/trace.json``
        self.path: str | None = str(path) if path else None
        self.total = 0
        self.slots = 1
        self.server: ThreadingHTTPServer | None = None
        self.port: int | None = None
        self._status_stream: TextIO | None = None
        self._next_tick = 0.0
        self._last_len = 0
        self._t0 = time.monotonic()
        self._rate_t = self._t0
        self._rate_n = 0
        self._rate = 0.0

    # -- run shape ---------------------------------------------------------

    def begin_run(self, total: int, slots: int) -> None:
        """Called by the study at dispatch start: run size for ETA math."""
        self.total = int(total)
        self.slots = max(1, int(slots))
        self._t0 = time.monotonic()
        self._rate_t = self._t0
        self._rate_n = 0
        self._rate = 0.0

    # -- live status -------------------------------------------------------

    def status(self) -> dict[str, Any]:
        """Point-in-time progress snapshot (the ``/status`` payload)."""
        m = self.metrics
        done = m.value("papas_tasks_completed_total")
        failed = m.value("papas_tasks_failed_total")
        skipped = m.value("papas_tasks_skipped_total")
        running = m.value("papas_tasks_running")
        retrying = m.value("papas_tasks_retrying")
        finished = done + failed + skipped
        now = time.monotonic()
        dt = now - self._rate_t
        if dt >= 0.5:
            self._rate = (finished - self._rate_n) / dt
            self._rate_t = now
            self._rate_n = finished
        elif not self._rate and now > self._t0:
            self._rate = finished / (now - self._t0)
        eta = None
        remaining = max(0, self.total - finished) if self.total else 0
        runtime = m.value("papas_task_runtime_seconds")
        if remaining and isinstance(runtime, dict) and runtime.get("count"):
            eta = remaining * runtime["p50"] / self.slots
        return {"total": self.total, "done": done, "failed": failed,
                "skipped": skipped, "running": running,
                "retrying": retrying, "tasks_per_sec": round(self._rate, 1),
                "eta_s": None if eta is None else round(eta, 1),
                "elapsed_s": round(now - self._t0, 1)}

    def status_line(self) -> str:
        s = self.status()
        eta = "?" if s["eta_s"] is None else f"{s['eta_s']:.0f}s"
        total = s["total"] or "?"
        return (f"[papas] {s['done']}/{total} done · "
                f"{s['running']:.0f} running · {s['failed']} failed · "
                f"{s['retrying']:.0f} retrying · "
                f"{s['tasks_per_sec']:.0f} tasks/s · eta {eta}")

    def attach_status(self, stream: TextIO | None = None) -> None:
        """Arm the in-place TTY progress line (``sweep.py --status``)."""
        self._status_stream = stream if stream is not None else sys.stderr
        self._next_tick = 0.0

    def tick(self, force: bool = False) -> None:
        """Redraw the status line, throttled to ~4 Hz; call from any
        per-completion hook — cheap no-op when not due."""
        out = self._status_stream
        if out is None:
            return
        now = time.monotonic()
        if not force and now < self._next_tick:
            return
        self._next_tick = now + 0.25
        line = self.status_line()
        pad = " " * max(0, self._last_len - len(line))
        self._last_len = len(line)
        out.write("\r" + line + pad)
        out.flush()

    def finish_status(self) -> None:
        """Final redraw + newline so the shell prompt lands clean."""
        if self._status_stream is None:
            return
        self.tick(force=True)
        self._status_stream.write("\n")
        self._status_stream.flush()
        self._status_stream = None

    # -- HTTP surface ------------------------------------------------------

    def serve(self, port: int = 0) -> int:
        """Start the daemon metrics server; returns the bound port
        (pass 0 for an ephemeral one)."""
        handler = type("_BoundHandler", (_TelemetryHandler,),
                       {"telemetry": self})
        self.server = ThreadingHTTPServer(("127.0.0.1", port), handler)
        self.port = int(self.server.server_address[1])
        threading.Thread(target=self.server.serve_forever,
                         name="papas-metrics", daemon=True).start()
        return self.port

    def close(self) -> None:
        if self.server is not None:
            self.server.shutdown()
            self.server.server_close()
            self.server = None


# ---------------------------------------------------------------------------
# arming — the same seam pattern as repro.core.chaos

_controller: Telemetry | None = None
_env_checked = False


def current() -> Telemetry | None:
    """The armed telemetry controller, or ``None`` (the common case).

    Components capture this once at construction; the disarmed cost is
    a single identity check at each seam.  First call lazily honors
    ``PAPAS_TRACE`` (``1`` to arm, or a path for ``trace.json``).
    """
    global _controller, _env_checked
    if _controller is None and not _env_checked:
        _env_checked = True
        val = os.environ.get("PAPAS_TRACE", "")
        if val and val.lower() not in ("0", "false", "no"):
            path = None if val.lower() in ("1", "true", "yes") else val
            _controller = Telemetry(path=path)
    return _controller


def install(tel: Telemetry | None) -> None:
    """Install (or clear, with ``None``) the process-wide controller."""
    global _controller, _env_checked
    _controller = tel
    _env_checked = True


@contextmanager
def activated(tel: Telemetry) -> Iterator[Telemetry]:
    """Scoped arming: install ``tel``, restore the previous controller
    on exit — how ``run(trace=...)`` and the tests arm a single run."""
    prev = current()
    install(tel)
    try:
        yield tel
    finally:
        install(prev)
