"""Input/output file staging (paper §5 ``infiles``/``outfiles``/
``substitute`` and the §6 NetLogo study pattern).

Per workflow instance:
* every ``infiles`` entry is staged into the instance's working
  directory; files whose content matches a ``substitute`` rule are
  rewritten with the instance's values (the paper varies XML elements of
  the NetLogo input this way); identical files are hard-linked instead
  of copied ("input files that were exactly the same ... were placed in
  a NFS directory, so only a single copy of each was made");
* ``${...}`` interpolation applies to the file *names* as well, so
  per-instance output paths like ``result_${args:size}.txt`` resolve;
* ``outfiles`` declares which artifacts to collect after the run.
"""
from __future__ import annotations

import os
import shutil
from pathlib import Path
from typing import Any, Mapping

from .interpolate import interpolate, substitute_content


def stage_instance(
    workdir: str | Path,
    instance_id: str,
    infiles: Mapping[str, str],
    combo: Mapping[str, Any],
    substitute: Mapping[str, Any] | None = None,
    source_root: str | Path = ".",
) -> Path:
    """Materialize one instance's working directory; returns its path."""
    inst_dir = Path(workdir) / instance_id
    inst_dir.mkdir(parents=True, exist_ok=True)
    source_root = Path(source_root)

    # per-instance substitute values: pick this combo's value per rule
    rules: dict[str, Any] = {}
    for pattern in (substitute or {}):
        key = f"substitute:{pattern}"
        if key in combo:
            rules[pattern] = combo[key]

    for _, raw_name in sorted(infiles.items()):
        name = interpolate(raw_name, combo)
        src = source_root / name
        dst = inst_dir / Path(name).name
        if not src.exists():
            raise FileNotFoundError(f"infile {src} missing")
        content = src.read_text()
        rewritten = substitute_content(content, rules) if rules else content
        if rewritten == content:
            # unchanged input: hard-link the shared copy (NFS pattern)
            if dst.exists():
                dst.unlink()
            try:
                os.link(src, dst)
            except OSError:
                shutil.copy2(src, dst)
        else:
            dst.write_text(rewritten)
    return inst_dir


def collect_outputs(
    inst_dir: str | Path,
    outfiles: Mapping[str, str],
    combo: Mapping[str, Any],
    dest_root: str | Path,
) -> dict[str, Path]:
    """Copy declared outputs into the provenance area; returns name→path."""
    inst_dir = Path(inst_dir)
    dest_root = Path(dest_root)
    dest_root.mkdir(parents=True, exist_ok=True)
    collected: dict[str, Path] = {}
    for key, raw_name in outfiles.items():
        name = interpolate(raw_name, combo)
        src = inst_dir / Path(name).name
        if src.exists():
            dst = dest_root / Path(name).name
            shutil.copy2(src, dst)
            collected[key] = dst
    return collected
