"""Task DAG construction (paper §4.2: the task generator builds a DAG
whose nodes are indivisible tasks; ``after`` declares prerequisites)."""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Iterable, Iterator, Mapping


class DAGError(ValueError):
    pass


@dataclasses.dataclass
class TaskNode:
    """One schedulable node: a task instance for one parameter combo."""

    id: str
    task: str                      # task (section) name in the study
    combo: dict[str, Any]          # parameter combination
    deps: list[str] = dataclasses.field(default_factory=list)
    payload: Any = None            # executor-specific callable / command


class TaskDAG:
    """Directed acyclic graph of task instances."""

    def __init__(self) -> None:
        self.nodes: dict[str, TaskNode] = {}

    def add(self, node: TaskNode) -> None:
        if node.id in self.nodes:
            raise DAGError(f"duplicate node id {node.id!r}")
        self.nodes[node.id] = node

    def validate(self) -> None:
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise DAGError(f"node {n.id!r}: missing dependency {d!r}")
        list(self.topological())  # raises on cycles

    def successors(self) -> dict[str, list[str]]:
        succ: dict[str, list[str]] = {nid: [] for nid in self.nodes}
        for n in self.nodes.values():
            for d in n.deps:
                succ[d].append(n.id)
        return succ

    def topological(self) -> Iterator[TaskNode]:
        """Kahn's algorithm over a min-heap ready queue (smallest id
        first, so the order matches a sorted list at O(V log V) instead
        of re-sorting per pop); raises DAGError on a cycle."""
        indeg = {nid: len(n.deps) for nid, n in self.nodes.items()}
        succ = self.successors()
        ready = [nid for nid, d in indeg.items() if d == 0]
        heapq.heapify(ready)
        emitted = 0
        while ready:
            nid = heapq.heappop(ready)
            emitted += 1
            yield self.nodes[nid]
            for s in succ[nid]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if emitted != len(self.nodes):
            cyclic = [nid for nid, d in indeg.items() if d > 0]
            raise DAGError(f"cycle detected among {sorted(cyclic)[:8]}")

    def levels(self) -> list[list[str]]:
        """Nodes grouped by DAG depth (for gang-packing within a level)."""
        depth: dict[str, int] = {}
        for node in self.topological():
            depth[node.id] = 1 + max((depth[d] for d in node.deps), default=-1)
        out: list[list[str]] = []
        for nid, lvl in depth.items():
            while len(out) <= lvl:
                out.append([])
            out[lvl].append(nid)
        return out
