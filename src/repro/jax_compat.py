"""Version-tolerant shims over jax APIs that moved between releases.

The model code targets the current mesh API (``jax.set_mesh`` /
``jax.sharding.get_abstract_mesh`` / ``jax.shard_map``); older jax
releases (<= 0.4.x) spell these ``with mesh:`` (the legacy ambient
physical mesh), ``jax._src.mesh.get_abstract_mesh``, and
``jax.experimental.shard_map.shard_map``.  Everything in the repo goes
through this module so a jax upgrade (or downgrade) is a no-op for
model and launch code.

Exports:

* ``get_abstract_mesh()`` — the active mesh-like object (abstract mesh
  if one is set, else the ambient physical mesh).  Always returns an
  object with an ``axis_names`` attribute; ``axis_names`` is ``()``
  when no mesh is active.
* ``mesh_axis_names()`` — convenience: the active mesh's axis names.
* ``set_mesh(mesh)`` — context manager activating ``mesh`` as the
  ambient mesh for sharding constraints and ``shard_map``.
* ``shard_map(f, mesh=..., in_specs=..., out_specs=...)`` — the SPMD
  map, whichever module it lives in.
"""
from __future__ import annotations

import contextlib
from typing import Any, Iterator

import jax


class _NoMesh:
    """Sentinel mesh-like: no axes, not usable for shard_map."""

    axis_names: tuple[str, ...] = ()
    shape: dict[str, int] = {}

    def __bool__(self) -> bool:
        return False


_NO_MESH = _NoMesh()


def _ambient_physical_mesh() -> Any | None:
    """The legacy ``with mesh:`` ambient mesh, if one is active."""
    try:
        from jax._src import mesh as _mesh_lib

        phys = _mesh_lib.thread_resources.env.physical_mesh
        if getattr(phys, "axis_names", ()):
            return phys
    except Exception:  # pragma: no cover - internal layout changed
        pass
    return None


def get_abstract_mesh() -> Any:
    """The active mesh (abstract if set, else ambient physical).

    Mirrors ``jax.sharding.get_abstract_mesh`` where available, but
    never raises on older jax: with no active mesh it returns an empty
    mesh-like object whose ``axis_names`` is ``()``.
    """
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is None:
        try:
            from jax._src import mesh as _mesh_lib

            getter = getattr(_mesh_lib, "get_abstract_mesh", None)
        except Exception:  # pragma: no cover
            getter = None
    if getter is not None:
        try:
            am = getter()
            if getattr(am, "axis_names", ()):
                return am
        except Exception:  # pragma: no cover - defensive
            pass
    return _ambient_physical_mesh() or _NO_MESH


def mesh_axis_names() -> tuple[str, ...]:
    return tuple(getattr(get_abstract_mesh(), "axis_names", ()) or ())


@contextlib.contextmanager
def set_mesh(mesh: Any) -> Iterator[Any]:
    """Activate ``mesh`` as the ambient mesh (``jax.set_mesh`` on new
    jax; the ``with mesh:`` physical-mesh context on old jax)."""
    setter = getattr(jax, "set_mesh", None)
    cm = setter(mesh) if setter is not None else mesh
    with cm:
        yield mesh


def shard_map(f: Any = None, /, **kwargs: Any) -> Any:
    """``jax.shard_map`` where it exists, else the experimental one."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    return fn(f, **kwargs) if f is not None else fn(**kwargs)


def make_mesh(shape: tuple[int, ...], axis_names: tuple[str, ...]) -> Any:
    """``jax.make_mesh`` where it exists, else a Mesh over a device
    array reshaped to ``shape``."""
    maker = getattr(jax, "make_mesh", None)
    if maker is not None:
        return maker(shape, axis_names)
    import numpy as np

    n = 1
    for s in shape:
        n *= s
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devices, axis_names)
