"""repro.serve"""
