"""Serving: batched prefill + decode steps with KV/SSM caches.

``make_serve_step`` returns the one-token decode function the dry-run
lowers for the ``decode_*``/``long_*`` shape cells; ``ServeEngine`` is
the runnable batching loop used by the serving example (continuous
token-level batching over a fixed slot pool — the inference analogue of
the paper's "group many small jobs into one allocation").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.models.transformer import decode_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    """(params, cache, token (B,1)) → (logits (B,V), new cache)."""

    def serve_step(params, cache, token):
        return decode_step(cfg, params, cache, token)

    return serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Token-level continuous batching over ``slots`` sequences."""

    def __init__(self, cfg: ArchConfig, params: Any, slots: int = 8,
                 max_len: int = 256) -> None:
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.model = Model(cfg)
        self.cache = self.model.init_cache(slots, max_len)
        self._step = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self.tokens = np.zeros((slots, 1), np.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # teacher-forced prefill: feed prompt tokens one at a time
                # through the decode path (shared cache; simple + correct)
                self.tokens[i, 0] = req.prompt[0] if req.prompt else 0
                req._fed = 1  # type: ignore[attr-defined]

    def step(self) -> list[Request]:
        """One engine tick: admit, decode one token for every live slot."""
        self._admit()
        if not any(self.active):
            return []
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(self.tokens))
        logits = np.asarray(logits)
        finished: list[Request] = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            fed = getattr(req, "_fed", len(req.prompt))
            if fed < len(req.prompt):
                self.tokens[i, 0] = req.prompt[fed]
                req._fed = fed + 1  # type: ignore[attr-defined]
                continue
            nxt = int(np.argmax(logits[i]))
            req.generated.append(nxt)
            self.tokens[i, 0] = nxt
            if len(req.generated) >= req.max_new:
                req.done = True
                finished.append(req)
                self.active[i] = None
        return finished

    def run(self) -> list[Request]:
        done: list[Request] = []
        while self.queue or any(self.active):
            done.extend(self.step())
        return done
