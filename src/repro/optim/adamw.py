"""AdamW + schedules + gradient utilities (pure JAX, no optax dependency).

Includes the distributed-training extras the framework exposes:
* global-norm clipping,
* gradient accumulation (microbatching) helper,
* int8 gradient compression/decompression for bandwidth-bound
  data-parallel reduction (used as a §Perf option on the pod axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        progress = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                            0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def linear_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        lin = base_lr * jnp.clip(1.0 - (step - warmup)
                                 / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        return jnp.where(step < warmup, warm, lin)
    return fn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamW:
    """AdamW with fp32 master weights.

    The training params may live in bf16 (halving weight HBM traffic and
    gradient-reduction bytes); the optimizer keeps the fp32 master copy
    in its state, where ZeRO-1 shards it over the data axis.
    """

    schedule: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: Pytree) -> dict[str, Pytree]:
        zeros = lambda p: jax.tree.map(  # noqa: E731
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return {"m": zeros(params), "v": zeros(params),
                # copy=True: fp32 params would otherwise ALIAS the master
                # (astype is a no-op) and break buffer donation
                "master": jax.tree.map(
                    lambda x: jnp.array(x, dtype=jnp.float32, copy=True),
                    params),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, grads: Pytree, state: dict[str, Pytree],
               params: Pytree) -> tuple[Pytree, dict[str, Pytree],
                                        dict[str, jax.Array]]:
        count = state["count"] + 1
        gnorm = global_norm(grads)
        if self.clip_norm:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        c = count.astype(jnp.float32)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        lr = self.schedule(count)

        def upd(w, mm, vv):
            mhat = mm / (1 - b1 ** c)
            vhat = vv / (1 - b2 ** c)
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and w.ndim >= 2:   # decay matrices only
                step = step + self.weight_decay * w
            return w - lr * step

        new_master = jax.tree.map(upd, state["master"], m, v)
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), new_master, params)
        metrics = {"lr": lr, "grad_norm": gnorm}
        return new_params, {"m": m, "v": v, "master": new_master,
                            "count": count}, metrics


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ---------------------------------------------------------------------------
# Gradient accumulation
# ---------------------------------------------------------------------------

def accumulate_grads(loss_fn: Callable, params: Pytree, batches: Pytree,
                     n_micro: int) -> tuple[Pytree, jax.Array, Pytree]:
    """Scan over ``n_micro`` microbatches (leading axis of ``batches``),
    averaging grads — the memory/throughput lever for large global
    batches."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, micro):
        acc, loss_acc = carry
        (loss, aux), g = grad_fn(params, micro)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, loss_acc + loss), aux

    zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    (gsum, loss_sum), auxs = jax.lax.scan(body, (zero, 0.0), batches)
    grads = jax.tree.map(lambda g: g / n_micro, gsum)
    aux_last = jax.tree.map(lambda x: x[-1], auxs)
    return grads, loss_sum / n_micro, aux_last


# ---------------------------------------------------------------------------
# int8 gradient compression (pod-axis all-reduce bandwidth saver)
# ---------------------------------------------------------------------------

def compress_int8(tree: Pytree) -> Pytree:
    """Per-leaf symmetric int8 quantization: (q, scale)."""
    def q(x):
        amax = jnp.max(jnp.abs(x)) + 1e-12
        scale = amax / 127.0
        return {"q": jnp.clip(jnp.round(x / scale), -127, 127
                              ).astype(jnp.int8),
                "scale": scale.astype(jnp.float32)}
    return jax.tree.map(q, tree)


def decompress_int8(tree: Pytree) -> Pytree:
    is_leaf = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}  # noqa: E731
    return jax.tree.map(
        lambda x: x["q"].astype(jnp.float32) * x["scale"],
        tree, is_leaf=is_leaf)
