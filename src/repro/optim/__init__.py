"""repro.optim"""
