"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \\
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Runs on whatever devices exist (CPU here, a pod in production): builds
the mesh, sharded train state, data stream, jit'd train step; checkpoints
every ``--ckpt-every`` steps and resumes from the latest checkpoint when
restarted — kill it mid-run and rerun the same command to see the
fault-tolerance path.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.jax_compat import set_mesh
from repro.configs import get, get_smoke
from repro.data.pipeline import make_stream
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import (
    TrainStepConfig, init_train_state, make_train_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    mesh = make_local_mesh()
    opt = AdamW(schedule=cosine_schedule(args.lr, args.warmup, args.steps))
    step_fn = make_train_step(cfg, opt,
                              TrainStepConfig(n_micro=args.n_micro))

    state = init_train_state(cfg, opt, jax.random.PRNGKey(args.seed))
    state_sh = shd.state_shardings(
        jax.eval_shape(lambda s: s, state), mesh)
    state = jax.device_put(state, state_sh)

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state = ckpt.restore(state, args.ckpt_dir, shardings=state_sh)
        start_step = int(state["step"])
        print(f"[restore] resumed from step {start_step}")

    stream = make_stream(cfg, args.batch, args.seq, seed=args.seed,
                         start_step=start_step)
    batch_sh = None
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    t0 = time.time()
    tokens = 0
    with set_mesh(mesh):
        for i, host_batch in enumerate(stream):
            step = start_step + i
            if step >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
            state, metrics = jit_step(state, batch)
            tokens += args.batch * args.seq
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"ce={float(metrics['ce']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"tok/s={tokens / max(dt, 1e-9):,.0f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(state, args.ckpt_dir, step + 1)
                print(f"[ckpt] saved {path}")
    if args.ckpt_dir:
        ckpt.save(state, args.ckpt_dir, int(state["step"]))
    print(f"done: final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
