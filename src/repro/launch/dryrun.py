"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating any real tensors:
  * proof the sharded program compiles (SPMD partitioning is coherent),
  * ``memory_analysis()``  — bytes/device (fits-in-HBM check),
  * ``cost_analysis()``    — per-device HLO FLOPs + bytes accessed,
  * the collective schedule parsed from the post-SPMD HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute operand bytes),
  * three-term roofline (compute / memory / collective seconds).

Results are written one JSON per cell under experiments/dryrun/.
"""
# The placeholder-device flag MUST be set before jax initializes devices —
# keep these as the very first executable statements of the module.
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import all_archs, get  # noqa: E402
from repro.jax_compat import set_mesh  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh, mesh_chips,
)
from repro.models.config import SHAPES, cell_applicable  # noqa: E402
from repro.models.model import cache_specs, input_specs  # noqa: E402
from repro.optim.adamw import AdamW, cosine_schedule  # noqa: E402
from repro.serve.engine import make_serve_step  # noqa: E402
from repro.train.step import (  # noqa: E402
    TrainStepConfig, abstract_train_state, make_train_step,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

#: effective bytes crossing a link per payload byte (ring algorithms)
_ALGO_FACTOR = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Sum operand bytes of every collective in post-SPMD HLO."""
    out: dict[str, dict[str, float]] = {
        k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r".*= (\([^)]*\)|\S+) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        kind = m.group(2)
        # bytes: use the RESULT shape (what lands on the wire, roughly)
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(m.group(1))
    return out


def roofline(flops: float, hbm_bytes: float,
             coll: dict[str, dict[str, float]]) -> dict[str, float]:
    """Three-term per-device roofline (seconds)."""
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm_bytes / HBM_BW
    coll_bytes = sum(v["bytes"] * _ALGO_FACTOR[k] for k, v in coll.items())
    collective_s = coll_bytes / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "collective_bytes": coll_bytes,
        "dominant": dominant,
        "step_s_lower_bound": max(compute_s, memory_s, collective_s),
    }


def model_flops(cfg, shape) -> float:
    """6·N_active·D reference FLOPs for the whole step (train) or
    2·N_active·B for one decode token."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq


def _moe_groups(shape) -> int:
    return max(32, shape.tokens // 2048)


def lower_train_cell(cfg, shape, mesh, n_micro: int = 1
                     ) -> tuple[jax.stages.Lowered, object]:
    opt = AdamW(schedule=cosine_schedule(3e-4, 2000, 100_000))
    dp = shd._dp_entry(mesh)
    step_cfg = TrainStepConfig(
        n_micro=n_micro,
        moe_groups=_moe_groups(shape),
        seq_spec=(NamedSharding(mesh, P(dp, "model", None))
                  if cfg.seq_shard else None))
    train_step = make_train_step(cfg, opt, step_cfg)

    state = abstract_train_state(cfg, opt)
    batch = input_specs(cfg, shape)
    state_sh = shd.state_shardings(state, mesh)
    batch_sh = shd.batch_shardings(batch, mesh)

    metrics = jax.eval_shape(train_step, state, batch)[1]
    metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics)

    jitted = jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,))
    with set_mesh(mesh):
        lowered = jitted.lower(state, batch)
        compiled = lowered.compile()
    return lowered, compiled


def lower_prefill_cell(cfg, shape, mesh):
    """Prefill = forward pass only (logits for the full prompt)."""
    from repro.models.transformer import forward, init_params

    infer_cfg = dataclasses.replace(cfg, remat="none")
    dp = shd._dp_entry(mesh)
    seq_spec = (NamedSharding(mesh, P(dp, "model", None))
                if cfg.seq_shard else None)
    moe_groups = _moe_groups(shape)

    def prefill(params, batch):
        logits, _ = forward(infer_cfg, params, batch, moe_groups, seq_spec)
        return logits

    params = jax.eval_shape(lambda k: init_params(infer_cfg, k),
                            jax.random.PRNGKey(0))
    batch = {k: v for k, v in input_specs(cfg, shape).items()
             if k != "labels"}
    params_sh = shd.params_shardings(params, mesh)
    batch_sh = shd.batch_shardings(batch, mesh)
    out_abs = jax.eval_shape(prefill, params, batch)
    out_sh = NamedSharding(
        mesh, shd.fit_spec(P(dp, None, "model"), out_abs.shape, mesh))
    jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh),
                     out_shardings=out_sh)
    with set_mesh(mesh):
        lowered = jitted.lower(params, batch)
        compiled = lowered.compile()
    return lowered, compiled


def lower_decode_cell(cfg, shape, mesh):
    serve_step = make_serve_step(cfg)
    from repro.models.transformer import init_params

    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))
    cache = cache_specs(cfg, shape)
    token = input_specs(cfg, shape)["token"]
    dp = shd._dp_entry(mesh)

    params_sh = shd.params_shardings(params, mesh)
    cache_sh = shd.cache_shardings(cache, mesh)
    token_sh = NamedSharding(
        mesh, shd.fit_spec(P(dp, None), token.shape, mesh))
    logits_sh = NamedSharding(
        mesh, shd.fit_spec(P(dp, "model"),
                           (shape.global_batch, cfg.vocab_size), mesh))

    jitted = jax.jit(
        serve_step,
        in_shardings=(params_sh, cache_sh, token_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,))
    with set_mesh(mesh):
        lowered = jitted.lower(params, cache, token)
        compiled = lowered.compile()
    return lowered, compiled


def _lower_fn(kind: str):
    return {"train": lower_train_cell, "prefill": lower_prefill_cell,
            "decode": lower_decode_cell}[kind]


def _compiled_costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def _variant(cfg, layer_types: tuple):
    return dataclasses.replace(
        cfg, n_layers=len(layer_types), layer_types=tuple(layer_types))


def corrected_costs(cfg, shape, mesh) -> dict:
    """Layer-exact costs.

    XLA's cost analysis counts while-loop (scan) bodies ONCE, so the
    scan-over-layers program underreports flops/bytes/collectives by the
    trip count.  We recover exact totals linearly: lower a 0-layer
    variant (embeddings + loss/head) and a 1-layer variant per layer
    kind, then total = base + Σ_kind n_kind · (kind − base).  Memory
    analysis still comes from the full scan-based program (that is what
    deploys)."""
    lower = _lower_fn(shape.kind)

    def costs_of(variant_cfg):
        # minis use unchunked CE and unchunked attention: those lax.map/
        # scan bodies would be trip-count-undercounted; the dense forms
        # count identically and exactly
        _, compiled = lower(
            dataclasses.replace(variant_cfg, loss_chunk=0, attn_q_chunk=0),
            shape, mesh)
        return _compiled_costs(compiled)

    base = costs_of(_variant(cfg, ()))
    kinds: dict[str, int] = {}
    for k in cfg.layer_types:
        kinds[k] = kinds.get(k, 0) + 1

    total = {"flops": base["flops"], "bytes": base["bytes"],
             "coll": json.loads(json.dumps(base["coll"]))}
    per_kind = {}
    for kind, n in sorted(kinds.items()):
        one = costs_of(_variant(cfg, (kind,)))
        d_flops = one["flops"] - base["flops"]
        d_bytes = one["bytes"] - base["bytes"]
        per_kind[kind] = {"n_layers": n, "flops": d_flops, "bytes": d_bytes}
        total["flops"] += n * d_flops
        total["bytes"] += n * d_bytes
        for cname in _COLLECTIVES:
            dc = one["coll"][cname]["count"] - base["coll"][cname]["count"]
            db = one["coll"][cname]["bytes"] - base["coll"][cname]["bytes"]
            total["coll"][cname]["count"] += n * dc
            total["coll"][cname]["bytes"] += n * db
    total["per_kind"] = per_kind
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             outdir: Path | None = None, loss_chunk: int = 1024,
             overrides: dict | None = None,
             mesh_shape: tuple | None = None) -> dict:
    """``mesh_shape`` re-maps the SAME chips to a different logical
    (data, model) or (pod, data, model) split — the §Perf sharding lever
    (e.g. (64, 4): TP=4 instead of 16 on one 256-chip pod)."""
    opts = dict(loss_chunk=loss_chunk, vocab_pad=256,
                param_dtype="bfloat16", attn_q_chunk=1024, seq_shard=True)
    opts.update(overrides or {})
    cfg = dataclasses.replace(get(arch), **opts)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if mesh_shape is not None:
        mesh_name = "x".join(map(str, mesh_shape))
    else:
        mesh_name = "2x16x16" if multi_pod else "16x16"
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "applicable": ok,
    }
    if not ok:
        record["skip_reason"] = reason
        return record

    if mesh_shape is not None:
        axes = (("pod", "data", "model") if len(mesh_shape) == 3
                else ("data", "model"))
        mesh = jax.make_mesh(mesh_shape, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    # NOTE: gradient accumulation (n_micro>1) currently triggers GSPMD
    # "involuntary full rematerialization" on the microbatch reshape
    # (XLA b/433785288); >HBM cells are documented in EXPERIMENTS.md with
    # the production mitigation (Pallas flash kernels on real TPU).
    t0 = time.time()
    lowered, compiled = _lower_fn(shape.kind)(cfg, shape, mesh)
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    raw = _compiled_costs(compiled)
    corr = corrected_costs(cfg, shape, mesh)

    flops = corr["flops"]
    hbm_bytes = corr["bytes"]
    rl = roofline(flops, hbm_bytes, corr["coll"])
    mflops = model_flops(cfg, shape)
    record.update({
        "chips": chips,
        "compile_seconds": compile_s,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "raw_scan_counted": raw,
        "per_kind": corr["per_kind"],
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": hbm_bytes,
        "collectives": corr["coll"],
        "roofline": rl,
        "model_flops_total": mflops,
        "model_flops_per_device": mflops / chips,
        "useful_flops_ratio": (mflops / chips) / flops if flops else None,
    })
    if outdir:
        outdir.mkdir(parents=True, exist_ok=True)
        fname = f"{arch.replace('.', '_')}__{shape_name}__{mesh_name}.json"
        (outdir / fname).write_text(json.dumps(record, indent=1, default=str))
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["16x16", "2x16x16",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = all_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"16x16": [False], "2x16x16": [True],
              "both": [False, True]}[args.mesh]
    outdir = Path(args.out)

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                try:
                    rec = run_cell(arch, shape_name, multi_pod, outdir)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"FAIL {arch} {shape_name} "
                          f"{'2x16x16' if multi_pod else '16x16'}: "
                          f"{type(e).__name__}: {e}")
                    continue
                if not rec.get("applicable", True):
                    print(f"SKIP {arch} {shape_name}: {rec['skip_reason']}")
                    continue
                rl = rec["roofline"]
                print(f"OK   {arch:18s} {shape_name:12s} {rec['mesh']:8s} "
                      f"compile={rec['compile_seconds']:6.1f}s "
                      f"flops/dev={rec['hlo_flops_per_device']:.3e} "
                      f"dom={rl['dominant']:10s} "
                      f"peakMB={rec['memory']['peak_bytes']/1e6:9.1f}")
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
