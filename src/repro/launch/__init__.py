"""repro.launch"""
