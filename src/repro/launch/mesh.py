"""Production mesh construction.

Importing this module never touches jax device state; meshes are built
lazily inside the function (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to get placeholder devices).

Topology (TPU v5e numbers used by the roofline):
* single pod: (16, 16) = 256 chips, axes ("data", "model")
* multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model")
"""
from __future__ import annotations

import jax

# v5e hardware constants (per chip) — §Roofline inputs
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Whatever this process has (tests / smoke runs)."""
    n = jax.device_count()
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
