"""The PaPaS driver: run a WDL parameter file where tasks are TRAINING
RUNS of this framework — the paper's technique applied to itself.

    PYTHONPATH=src python -m repro.launch.sweep examples/lr_sweep.yaml

Tasks whose command starts with ``train`` are resolved to in-process
training calls (registry execution); anything else runs as a shell
command.  ``parallel: vmap-stack`` gang-packs stackable instances (same
arch/shape, different scalars) into ONE compiled program via
``repro.train.ensemble`` — the TPU realization of the paper's
job-batching (§4.3).  ``--slots N --pool thread|process`` runs instances
concurrently through the engine's worker pools (the paper's
``nnodes × ppnode`` resource knob); ``--pool lane`` feeds rendered shell
commands to persistent worker lanes — the short-task throughput path
(sub-100ms tasks dispatch at thousands/sec instead of being
scheduler-bound on process spawn).

Remote backends (paper §4.3 distributed parallelization):
``--pool ssh --hosts a,b --ppnode 2`` dispatches rendered shell
commands over ``hosts × ppnode`` slots; ``--pool slurm|pbs --nnodes N
--ppnode P`` submits grouped allocations.  ``--transport``/
``--submitter`` default to the no-network fakes (commands run locally,
per-"host" accounting preserved) — pass ``--transport ssh`` /
``--submitter scheduler`` to reach real hosts / a real queue.

``--window N`` streams the study instead of materializing it: instances
are addressed by space index, at most ``slots + N`` task nodes stay
live, and checkpoints use the compact v2 journal — constant startup time
and bounded memory for arbitrarily large parameter spaces.

``--report {summary,table,speedup} --group-by size,threads`` turns the
run into a performance study (paper §6): tasks' ``capture:`` metrics
stream through a ``ResultsAggregator`` as completions arrive (the run
switches to ``keep_results=False`` — O(groups) memory however large the
sweep) and the chosen pivot table prints at the end.  ``--baseline
threads=1`` (default: the WDL ``baseline:`` keyword) anchors the
speedup/efficiency derivation; ``--metric``/``--stat``/``--format``
pick what fills the cells.  The same table is reproducible offline from
``records.jsonl`` via ``python -m repro.launch.report``.
"""
from __future__ import annotations

import argparse
import shlex
import sys
from pathlib import Path
from typing import Any

import jax

from repro.configs import get_smoke
from repro.core import (
    GangExecutor, LocalSubmitter, LocalTransport, ResultsAggregator,
    SchedulerSubmitter, SSHTransport, Telemetry, WDLError, load_study,
    stackable_key,
)
from repro.launch import report as report_mod
from repro.train.ensemble import train_ensemble


def _train_combo(combo: dict[str, Any], defaults: dict[str, Any]) -> float:
    """One member training run (used for one-per-task dispatch)."""
    from repro.train.ensemble import train_members
    args = {**defaults, **combo}
    return train_members([args])[0]


def _window_arg(text: str) -> Any:
    """``--window`` accepts a positive int or the literal ``auto``."""
    if text.strip().lower() == "auto":
        return "auto"
    try:
        return int(text)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"window must be a positive int or 'auto', got {text!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("paramfile", nargs="+")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--gang", action="store_true",
                    help="vmap-stack stackable instances (one dispatch)")
    ap.add_argument("--slots", type=int, default=1,
                    help="concurrent execution slots (local pools)")
    ap.add_argument("--pool", default="inline",
                    help="execution backend for non-gang runs: inline, "
                         "thread, process, lane (persistent shell worker "
                         "lanes — short-task throughput), ssh, slurm, "
                         "or pbs")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated host list for --pool ssh "
                         "(default: the WDL hosts: keyword)")
    ap.add_argument("--ppnode", type=int, default=None,
                    help="processes per node for ssh/batch pools")
    ap.add_argument("--nnodes", type=int, default=None,
                    help="allocation node count for batch pools")
    ap.add_argument("--transport", choices=("local", "ssh"), default="local",
                    help="ssh-pool transport: 'local' = no-network fake "
                         "(runs commands on this machine, one slot per "
                         "host×ppnode), 'ssh' = real ssh subprocesses")
    ap.add_argument("--submitter", choices=("local", "scheduler"),
                    default="local",
                    help="batch-pool submitter: 'local' = run the rendered "
                         "script with sh (no scheduler binary), "
                         "'scheduler' = real sbatch/qsub")
    ap.add_argument("--speculate", action="store_true",
                    help="duplicate straggler tasks (idempotent tasks only)")
    ap.add_argument("--window", type=_window_arg, default=None,
                    help="streaming admission: keep at most slots+WINDOW "
                         "task nodes live, address instances by index "
                         "instead of materializing the space, and journal "
                         "in compact v2 form; 'auto' sizes the window "
                         "from the observed completion rate (default: "
                         "eager whole-DAG)")
    ap.add_argument("--straggler-quantile", type=float, default=None,
                    metavar="Q",
                    help="straggler cutoff as a runtime quantile in "
                         "(0, 1), e.g. 0.9 for p90 — replaces the "
                         "default straggler_factor x median rule "
                         "(default: the WDL straggler_quantile: keyword)")
    ap.add_argument("--report", choices=report_mod.REPORTS, default=None,
                    help="aggregate captured metrics while the study "
                         "streams and print this pivot table at the end "
                         "(requires --group-by; implies keep_results=False "
                         "— O(groups) memory).  'runtime' instead prints "
                         "the per-task (or per-host, --group-by host) "
                         "runtime table from provenance — no captures "
                         "needed")
    ap.add_argument("--group-by", default=None,
                    help="comma-separated group keys for --report: "
                         "parameters or captured metrics (short names "
                         "resolve like WDL interpolation)")
    ap.add_argument("--baseline", default=None,
                    help="speedup baseline as key=value (default: the "
                         "WDL 'baseline:' keyword)")
    ap.add_argument("--metric", default="time",
                    help="captured metric the report aggregates "
                         "(default: time)")
    ap.add_argument("--stat", default="mean",
                    choices=[s for s in report_mod.STATS if s != "count"],
                    help="statistic for table/speedup cells")
    ap.add_argument("--format", choices=report_mod.FORMATS, default="md",
                    dest="report_format", help="report output format")
    ap.add_argument("--chaos", default=None, metavar="PLAN",
                    help="arm deterministic fault injection from a "
                         "fault-plan YAML (repro.core.chaos): faults "
                         "fire by plan, the run degrades gracefully "
                         "instead of dying, and study.json carries the "
                         "fault ledger")
    ap.add_argument("--trace", nargs="?", const=True, default=None,
                    metavar="PATH",
                    help="arm the telemetry layer (repro.core.telemetry) "
                         "and write a Chrome-trace-event JSON of the run "
                         "— task-lifecycle spans per slot/lane/host, "
                         "retry waits, chaos firings — loadable in "
                         "https://ui.perfetto.dev (default path: "
                         "<study dir>/trace.json)")
    ap.add_argument("--status", action="store_true",
                    help="live in-place progress line on stderr: "
                         "done/running/failed/retrying, tasks/s, and an "
                         "ETA from the streaming median runtime "
                         "(implies telemetry arming)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="N",
                    help="serve Prometheus text /metrics and JSON "
                         "/status from a daemon thread on 127.0.0.1:N "
                         "while the study runs (0 picks a free port; "
                         "implies telemetry arming)")
    ap.add_argument("--check", action="store_true",
                    help="pre-flight static analysis (repro.core.lint) "
                         "before admitting the run: print findings and "
                         "exit 1 on any error-severity rule — the same "
                         "checks 'python -m repro.launch.lint' runs")
    ap.add_argument("--root", default=".papas")
    args = ap.parse_args()

    try:
        study = load_study(*[Path(p) for p in args.paramfile],
                           root=args.root)
    except WDLError as e:
        if not args.check:
            raise
        print(f"ERROR E001 {e}", file=sys.stderr)
        sys.exit(1)

    if args.check:
        report = study.lint(slots=args.slots)
        if report.findings:
            print(report.render(), file=sys.stderr)
        if not report.ok:
            print("lint: study rejected (fix the errors above or "
                  "suppress rule ids via the study's lint: block)",
                  file=sys.stderr)
            sys.exit(1)

    aggregator = None
    if args.report == "runtime":
        # runtime tables come straight from provenance — no capture
        # aggregation; --group-by (optional) picks the task/host axis
        if args.group_by not in (None, "task", "host"):
            ap.error("--report runtime groups by 'task' or 'host'")
    elif args.report is not None:
        if not args.group_by:
            ap.error("--report requires --group-by")
        aggregator = ResultsAggregator(
            [k.strip() for k in args.group_by.split(",") if k.strip()])
    elif args.group_by:
        ap.error("--group-by only makes sense with --report")

    # registry: any task whose command begins with "train" runs in-process
    registry = {}
    for tname, task in study.spec.tasks.items():
        if task.command and task.command.split()[0] == "train":
            defaults = dict(
                tok for tok in
                (t.split("=", 1) for t in shlex.split(task.command)[1:]
                 if "=" in t))
            registry[tname] = (
                lambda combo, _d=defaults: _train_combo(combo, _d))
    study.registry.update(registry)

    counts = {"ok": 0, "total": 0}
    extra_kwargs: dict = {}
    if aggregator is not None:
        if args.resume:
            # metrics recorded before the resume never re-stream —
            # seed the aggregator from the surviving records
            aggregator.add_records(study.db.records())

        def _count(res) -> None:
            counts["total"] += 1
            if res.status == "ok":
                counts["ok"] += 1
        extra_kwargs = dict(aggregator=aggregator, on_result=_count,
                            keep_results=False)

    if args.straggler_quantile is not None:
        extra_kwargs["straggler_quantile"] = args.straggler_quantile
    if args.chaos is not None:
        extra_kwargs["chaos"] = args.chaos

    # telemetry: one instance owns the trace, metrics, status line, and
    # (optionally) the HTTP endpoint; the study arms it for the run and
    # snapshots metrics into study.json, sweep owns its lifetime
    tel = None
    if (args.trace is not None or args.status
            or args.metrics_port is not None):
        tel = Telemetry(path=None if args.trace in (None, True)
                        else args.trace)
        extra_kwargs["trace"] = tel
        if args.metrics_port is not None:
            port = tel.serve(args.metrics_port)
            print(f"[telemetry] http://127.0.0.1:{port}/metrics "
                  f"(Prometheus text) and /status (JSON)")
        if args.status:
            tel.attach_status()
            _prev_cb = extra_kwargs.get("on_result")

            def _tick(res, _prev=_prev_cb, _tel=tel):
                if _prev is not None:
                    _prev(res)
                _tel.tick()
            extra_kwargs["on_result"] = _tick

    if args.gang:
        def gang_runner(nodes):
            members = [dict(n.combo) for n in nodes]
            return train_ensemble(members)
        gang = GangExecutor(stackable_key, gang_runner)
        results = study.run(gang=gang, resume=args.resume,
                            window=args.window, **extra_kwargs)
        print(f"[gang] {gang.stats.tasks} tasks in "
              f"{gang.stats.dispatches} dispatches "
              f"(batching ×{gang.stats.batching_factor:.0f})")
    else:
        transport = None
        if args.pool == "ssh":
            transport = (SSHTransport() if args.transport == "ssh"
                         else LocalTransport())
        submitter = None
        if args.pool in ("slurm", "pbs"):
            submitter = (SchedulerSubmitter(args.pool)
                         if args.submitter == "scheduler"
                         else LocalSubmitter())
        hosts = ([h.strip() for h in args.hosts.split(",") if h.strip()]
                 if args.hosts else None)
        try:
            results = study.run(resume=args.resume, slots=args.slots,
                                pool=args.pool, speculate=args.speculate,
                                hosts=hosts, ppnode=args.ppnode,
                                nnodes=args.nnodes, transport=transport,
                                submitter=submitter, window=args.window,
                                **extra_kwargs)
        except ValueError as e:
            ap.error(str(e))    # e.g. unknown --pool kind, missing hosts

    if tel is not None:
        if args.status:
            tel.finish_status()
        trace_path = (Path(tel.path) if tel.path
                      else study.db.dir / "trace.json")
        print(f"[telemetry] trace written to {trace_path} — load it in "
              f"https://ui.perfetto.dev")
        tel.close()

    if aggregator is not None:
        ok, total = counts["ok"], counts["total"]
    else:
        ok = sum(1 for r in results.values() if r.status == "ok")
        total = len(results)
    print(f"{ok}/{total} instances complete; "
          f"provenance in {study.db.dir}")
    banner = report_mod.degraded_banner(study.db.dir)
    if banner:
        print(banner, file=sys.stderr)
    stats = getattr(study, "last_run_stats", None)
    if args.window is not None and stats:
        print(f"[window] admitted {stats['admitted_instances']}"
              f"/{stats['n_instances']} instances "
              f"({stats['skipped_complete']} already complete), "
              f"peak live nodes {stats['peak_live_nodes']} "
              f"(bound {stats['slots']} slots + {stats['window']} window)")
    if args.report == "runtime":
        # live path: surfaces StudyDB.runtime_summary() directly (the
        # offline twin reads records.jsonl via repro.launch.report)
        print(report_mod.runtime_report(study.db, args.group_by or "task",
                                        args.report_format))
        return
    if aggregator is not None:
        for key, err in aggregator.key_errors.items():
            print(f"warning: group-by key {key!r}: {err}",
                  file=sys.stderr)
        try:
            if aggregator.n_grouped == 0:
                raise ValueError(
                    f"no results matched the group-by keys "
                    f"{aggregator.group_by}")
            baseline = (report_mod.parse_baseline(args.baseline)
                        if args.baseline else _wdl_baseline(study.spec))
            print(report_mod.run_report(
                aggregator, args.report, args.metric, args.stat,
                baseline, args.report_format))
        except (KeyError, ValueError) as e:
            ap.error(str(e))    # e.g. missing baseline, bad group key
        return

    for rid, res in sorted(results.items()):
        val = res.value if res.value is not None else ""
        where = f" @{res.host}" if res.host else ""
        print(f"  {rid}: {res.status} ({res.runtime:.2f}s){where} {val}")


def _wdl_baseline(spec) -> dict | None:
    """The study-declared baseline point, merged across tasks (two tasks
    declaring different values for the same key is a spec error)."""
    out: dict = {}
    for t in spec.tasks.values():
        for k, v in t.baseline.items():
            if k in out and out[k] != v:
                raise ValueError(
                    f"conflicting baseline for {k!r}: {out[k]!r} vs {v!r}")
            out[k] = v
    return out or None


if __name__ == "__main__":
    main()
