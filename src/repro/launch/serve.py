"""Serving driver: batched continuous decoding over a slot pool.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \\
        --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get, get_smoke
from repro.models import Model
from repro.serve.engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    if not cfg.has_decode():
        raise SystemExit(f"{cfg.name} is encoder-only; nothing to decode")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(2, 6)).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s on {args.slots} slots)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.prompt} -> {r.generated[:8]}...")


if __name__ == "__main__":
    main()
