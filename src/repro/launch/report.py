"""Performance-study reports over captured metrics (paper §6).

Renders pivot tables from a parameter study's captured metrics — either
a live ``ResultsAggregator`` fed by a running study, or offline from a
finished study's ``records.jsonl`` (the group-commit provenance stream),
so the table a streaming run printed is reproducible later without
re-running anything:

    PYTHONPATH=src python -m repro.launch.report .papas/mystudy \\
        --group-by size,threads --metric time \\
        --report speedup --baseline threads=1

Three report shapes, each printable as Markdown (default), CSV, or JSON:

* ``summary`` — one row per group: count/mean/std/min/max/median of a
  metric (Welford + dual-heap median, the aggregator's O(groups) state).
* ``table``  — a pivot of one statistic: the last ``--group-by`` axis
  spreads across columns, earlier axes label the rows.
* ``speedup`` — the paper's Fig. 6/7 derivation: speedup and parallel
  efficiency of a timing metric relative to the declared baseline point
  (``--baseline threads=1``; ``repro.launch.sweep --report`` defaults it
  from the WDL ``baseline:`` keyword), pivoted the same way.
* ``runtime`` — where the wall-clock went: one row per task (or per
  host with ``--group-by host``) with count/total/min/median/max over
  the ok records, plus a ``chaos_events`` column counting fault-ledger
  entries that targeted the group — a DEGRADED run shows where faults
  landed next to where time went.  Needs no ``--group-by`` and no
  ``capture:`` metrics; it surfaces ``StudyDB.runtime_summary()``
  (live) or rebuilds the same summary offline from ``records.jsonl``.

Group-by keys name parameters (short forms resolve like WDL
interpolation: ``size`` matches ``args:size``) or captured metrics
(``threads`` matches a ``capture: threads:`` extraction).  Offline
aggregation streams the records file and keeps the *latest* ``ok``
record per task id, so resumed or retried studies count each instance
exactly once.  This module deliberately avoids jax and the training
stack — reports run anywhere the provenance files do.
"""
from __future__ import annotations

import argparse
import csv
import heapq
import io
import json
import sys
from pathlib import Path
from typing import Any, Iterable, Iterator, Mapping, Sequence

from repro.core.groupcommit import iter_jsonl
from repro.core.results import (
    STATS, KeyResolutionError, ResultsAggregator, infer_scalar,
)

REPORTS = ("summary", "table", "speedup", "runtime")
FORMATS = ("md", "csv", "json")


# ---------------------------------------------------------------------------
# Offline loading
# ---------------------------------------------------------------------------


def records_path(path: "str | Path") -> Path:
    """Resolve a records file: accepts the ``records.jsonl`` itself or a
    study directory containing one."""
    p = Path(path)
    if p.is_dir():
        p = p / "records.jsonl"
    if not p.exists():
        raise FileNotFoundError(
            f"no provenance records at {p} (pass a study directory or a "
            f"records.jsonl path)")
    return p


def iter_records(path: "str | Path") -> Iterator[dict[str, Any]]:
    """Stream provenance records from disk, skipping blank lines.

    A sharded run leaves per-shard segments (``records.jsonl.s<k>``)
    next to the base file; they are k-way-merged back into one
    timestamp-ordered stream, so offline reports see exactly what a
    single-handle run would have written."""
    base = records_path(path)
    segs = sorted((p for p in base.parent.glob(base.name + ".s*")
                   if p.name[len(base.name) + 2:].isdigit()),
                  key=lambda p: int(p.name.rsplit(".s", 1)[1]))

    def _stream(p: Path) -> Iterator[dict[str, Any]]:
        # corruption-tolerant (shared with the live loaders): a torn
        # tail warns and drops that record, not the whole report
        yield from iter_jsonl(p, "records")
    if not segs:
        yield from _stream(base)
        return
    yield from heapq.merge(*(_stream(p) for p in [base] + segs),
                           key=lambda r: r.get("timestamp") or 0.0)


def aggregate_records(
    path: "str | Path",
    group_by: Sequence[str],
    metrics: Sequence[str] | None = None,
) -> ResultsAggregator:
    """Offline aggregation: fold a finished study's records into a fresh
    aggregator (latest ``ok`` record per task wins)."""
    agg = ResultsAggregator(group_by, metrics=metrics)
    agg.add_records(iter_records(path))
    return agg


def degraded_banner(path: "str | Path") -> str | None:
    """A warning banner when the study's ``study.json`` marks the run
    degraded (it finished on surviving hosts after losing some): names
    the lost hosts with their failure causes and summarizes the
    attached fault ledger, so a report over partial infrastructure
    never masquerades as a clean one."""
    p = Path(path)
    meta_path = (p if p.is_dir() else p.parent) / "study.json"
    if not meta_path.exists():
        return None
    try:
        meta = json.loads(meta_path.read_text())
    except ValueError:
        return None
    if not meta.get("degraded"):
        return None
    lines = ["DEGRADED RUN: the study lost hosts mid-run and finished "
             "on the survivors"]
    causes = meta.get("host_causes") or {}
    for host in meta.get("lost_hosts") or sorted(causes):
        cause = causes.get(host, "")
        lines.append(f"  lost host {host}" + (f": {cause}" if cause
                                              else ""))
    faults = meta.get("fault_ledger") or []
    if faults:
        lines.append(f"  fault ledger: {len(faults)} injected fault(s) "
                     + ", ".join(f"{f.get('fault')}@{f.get('target')}"
                                 for f in faults[:8])
                     + ("…" if len(faults) > 8 else ""))
    return "\n".join(lines)


def parse_baseline(text: str) -> dict[str, Any]:
    """Parse a ``key=value`` baseline declaration (value type-inferred,
    matching WDL scalars)."""
    key, sep, val = text.partition("=")
    if not sep or not key.strip():
        raise ValueError(
            f"baseline must be key=value (e.g. threads=1), got {text!r}")
    return {key.strip(): infer_scalar(val.strip())}


# ---------------------------------------------------------------------------
# Pivoting + rendering
# ---------------------------------------------------------------------------


def _fmt_cell(v: Any) -> str:
    if v is None:
        return ""
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def render_rows(headers: Sequence[str], rows: Iterable[Sequence[Any]],
                fmt: str = "md") -> str:
    """Render one table in the requested format.  JSON emits a list of
    header-keyed objects (raw values, not formatted strings)."""
    rows = [list(r) for r in rows]
    if fmt == "json":
        return json.dumps([dict(zip(headers, r)) for r in rows], indent=2,
                          default=str)
    if fmt == "csv":
        buf = io.StringIO()
        w = csv.writer(buf, lineterminator="\n")
        w.writerow(headers)
        for r in rows:
            w.writerow([_fmt_cell(v) for v in r])
        return buf.getvalue().rstrip("\n")
    if fmt != "md":
        raise ValueError(f"unknown format {fmt!r} (valid: {FORMATS})")
    cells = [[_fmt_cell(v) for v in r] for r in rows]
    widths = [max(len(str(h)), *(len(r[i]) for r in cells), 1)
              if cells else len(str(h))
              for i, h in enumerate(headers)]
    def line(vals: Sequence[str]) -> str:
        return "| " + " | ".join(str(v).ljust(w)
                                 for v, w in zip(vals, widths)) + " |"
    out = [line([str(h) for h in headers]),
           "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
    out += [line(r) for r in cells]
    return "\n".join(out)


def _sorted_keys(keys: Iterable[tuple]) -> list[tuple]:
    def k(t: tuple) -> tuple:
        return tuple((0, v) if isinstance(v, (int, float))
                     and not isinstance(v, bool) else (1, str(v))
                     for v in t)
    return sorted(keys, key=k)


def pivot_rows(entries: Mapping[tuple, Any], group_by: Sequence[str]
               ) -> tuple[list[str], list[list[Any]]]:
    """Pivot group-keyed cells: the last group-by axis spreads across
    columns, earlier axes label the rows.  A single axis degenerates to
    one row per value."""
    group_by = list(group_by)
    if len(group_by) == 1:
        headers = [group_by[0], "value"]
        rows = [[key[0], entries[key]] for key in _sorted_keys(entries)]
        return headers, rows
    col_axis = group_by[-1]
    col_vals = _sorted_keys({(key[-1],) for key in entries})
    cols = [c[0] for c in col_vals]
    by_row: dict[tuple, dict[Any, Any]] = {}
    for key, val in entries.items():
        by_row.setdefault(key[:-1], {})[key[-1]] = val
    headers = group_by[:-1] + [f"{col_axis}={c}" for c in cols]
    rows = [list(rkey) + [by_row[rkey].get(c) for c in cols]
            for rkey in _sorted_keys(by_row)]
    return headers, rows


def summary_report(agg: ResultsAggregator, metric: str,
                   fmt: str = "md") -> str:
    headers = list(agg.group_by) + list(STATS)
    rows = [list(key) + [stats.get(s) for s in STATS]
            for key, stats in agg.summary(metric).items()]
    return render_rows(headers, rows, fmt)


def table_report(agg: ResultsAggregator, metric: str, stat: str = "mean",
                 fmt: str = "md") -> str:
    headers, rows = pivot_rows(agg.table(metric, stat), agg.group_by)
    return render_rows(headers, rows, fmt)


def speedup_report(agg: ResultsAggregator, metric: str,
                   baseline: Mapping[str, Any], stat: str = "mean",
                   fmt: str = "md") -> str:
    """Speedup + parallel efficiency pivots relative to ``baseline``
    (the paper's Fig. 6/7 tables)."""
    derived = agg.speedup(metric, baseline, stat)
    if fmt == "json":
        return json.dumps(
            [dict(zip(agg.group_by, key), **vals)
             for key, vals in sorted(derived.items(),
                                     key=lambda kv: str(kv[0]))],
            indent=2, default=str)
    (bkey, bval), = baseline.items()
    sections = []
    for field in ("speedup", "efficiency"):
        entries = {key: vals[field] for key, vals in derived.items()}
        headers, rows = pivot_rows(entries, agg.group_by)
        title = (f"{field} of {stat}({metric}), "
                 f"baseline {bkey}={bval}")
        body = render_rows(headers, rows, fmt)
        sections.append(f"# {title}\n{body}")
    return "\n\n".join(sections)


def _offline_runtime_summary(path: "str | Path",
                             by: str) -> dict[str, dict[str, Any]]:
    """Rebuild ``StudyDB.runtime_summary(by=...)`` from the on-disk
    record stream: latest ``ok`` record per task id wins, so resumed
    or retried studies count each instance exactly once."""
    latest: dict[str, dict[str, Any]] = {}
    for r in iter_records(path):
        if r.get("status") == "ok":
            latest[r["task_id"]] = r
    groups: dict[str, list[float]] = {}
    for r in latest.values():
        key = (r["task_id"].partition("@")[0] if by == "task"
               else str(r.get("host") or "local"))
        groups.setdefault(key, []).append(float(r.get("runtime") or 0.0))
    out: dict[str, dict[str, Any]] = {}
    for key, times in sorted(groups.items()):
        times.sort()
        out[key] = {"count": len(times), "total": sum(times),
                    "min": times[0], "median": times[len(times) // 2],
                    "max": times[-1]}
    return out


def _fault_counts(path: "str | Path", by: str) -> dict[str, int]:
    """Fault-ledger entries per group key, from ``study.json`` — the
    runtime table's ``chaos_events`` column (0 everywhere on a run
    without an armed chaos controller)."""
    p = Path(path)
    meta_path = (p if p.is_dir() else p.parent) / "study.json"
    if not meta_path.exists():
        return {}
    try:
        meta = json.loads(meta_path.read_text())
    except ValueError:
        return {}
    counts: dict[str, int] = {}
    for f in meta.get("fault_ledger") or []:
        target = str(f.get("target") or "")
        key = target.partition("@")[0] if by == "task" else target
        counts[key] = counts.get(key, 0) + 1
    return counts


def runtime_report(source: Any, by: str = "task", fmt: str = "md") -> str:
    """Per-task / per-host runtime table.  ``source`` is a ``StudyDB``
    (live — uses its ``runtime_summary``) or a study directory /
    ``records.jsonl`` path (offline rebuild of the same summary)."""
    if by not in ("task", "host"):
        raise ValueError(
            f"runtime report groups by 'task' or 'host', got {by!r}")
    if hasattr(source, "runtime_summary"):
        summary = source.runtime_summary(by=by)
        where: Any = source.dir
    else:
        summary = _offline_runtime_summary(source, by)
        where = source
    faults = _fault_counts(where, by)
    headers = [by, "count", "total", "min", "median", "max",
               "chaos_events"]
    rows = [[key, s.get("count"), s.get("total"), s.get("min"),
             s.get("median"), s.get("max"), faults.get(key, 0)]
            for key, s in summary.items()]
    return render_rows(headers, rows, fmt)


def run_report(agg: ResultsAggregator, report: str, metric: str,
               stat: str = "mean",
               baseline: Mapping[str, Any] | None = None,
               fmt: str = "md") -> str:
    """Dispatch one report by name (shared by this CLI and
    ``repro.launch.sweep --report``)."""
    if report == "summary":
        return summary_report(agg, metric, fmt)
    if report == "table":
        return table_report(agg, metric, stat, fmt)
    if report == "speedup":
        if not baseline:
            raise ValueError(
                "speedup report needs a baseline (--baseline key=value, "
                "or a WDL 'baseline:' declaration when run via sweep)")
        return speedup_report(agg, metric, baseline, stat, fmt)
    if report == "runtime":
        raise ValueError("runtime report reads provenance directly — "
                         "call runtime_report(study_dir_or_db, by, fmt)")
    raise ValueError(f"unknown report {report!r} (valid: {REPORTS})")


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render pivot tables from a study's captured metrics "
                    "(records.jsonl)")
    ap.add_argument("path",
                    help="study directory or records.jsonl path")
    ap.add_argument("--group-by", default=None,
                    help="comma-separated group keys (parameters or "
                         "captured metrics; short names resolve like WDL "
                         "interpolation).  Required for every report "
                         "except runtime, where it picks the table axis "
                         "('task', the default, or 'host')")
    ap.add_argument("--report", choices=REPORTS, default="summary")
    ap.add_argument("--metric", default="time",
                    help="captured metric to aggregate (default: time)")
    ap.add_argument("--stat", choices=[s for s in STATS if s != "count"],
                    default="mean",
                    help="statistic for table/speedup cells")
    ap.add_argument("--baseline", default=None,
                    help="baseline point for --report speedup, as "
                         "key=value (e.g. threads=1)")
    ap.add_argument("--format", choices=FORMATS, default="md")
    args = ap.parse_args(argv)

    if args.report == "runtime":
        try:
            out = runtime_report(args.path, args.group_by or "task",
                                 args.format)
        except (FileNotFoundError, ValueError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        banner = degraded_banner(args.path)
        if banner:
            print(banner, file=sys.stderr)
        print(out)
        return 0
    if not args.group_by:
        ap.error(f"--group-by is required for --report {args.report}")

    group_by = [k.strip() for k in args.group_by.split(",") if k.strip()]
    try:
        agg = aggregate_records(args.path, group_by)
        baseline = parse_baseline(args.baseline) if args.baseline else None
        out = run_report(agg, args.report, args.metric, args.stat,
                         baseline, args.format)
    except (FileNotFoundError, KeyResolutionError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if agg.n_grouped == 0:
        detail = "; ".join(agg.key_errors.values())
        print("error: no records matched the group-by keys "
              f"{group_by} (saw {agg.n_results} ok records"
              + (f"; {detail}" if detail else "") + ")",
              file=sys.stderr)
        return 2
    banner = degraded_banner(args.path)
    if banner:
        print(banner, file=sys.stderr)
    print(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
