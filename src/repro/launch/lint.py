"""``papas lint`` CLI — static analysis for WDL parameter files.

    PYTHONPATH=src python -m repro.launch.lint examples/*.yaml
    PYTHONPATH=src python -m repro.launch.lint study.yaml --format json
    PYTHONPATH=src python -m repro.launch.lint study.yaml --strict

Each file is linted as its own study (lint a merged composition by
running ``sweep.py --check`` instead, which lints exactly what it is
about to run).  Exit status: 1 when any file has error-severity
findings (or warnings under ``--strict``), else 0 — so the command
gates CI and pre-run hooks.  A file that does not parse at all is
reported as rule ``E001`` with the parser's file/line context rather
than a traceback.

``--root`` points at a study root (``.papas``) to price the cost
estimator from observed runtimes; without it the declared ``timeout:``
keywords are the only duration priors.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any

from repro.core.lint import Finding, LintReport, lint
from repro.core.wdl import WDLError, parse_file


def lint_file(path: str | Path, slots: int | None = None,
              priors: dict[str, float] | None = None,
              max_runtime_days: float | None = None) -> LintReport:
    """Lint one parameter file, mapping parse failures to E001."""
    try:
        spec = parse_file(path, validate=False)
    except WDLError as e:
        return LintReport(findings=[Finding(
            rule="E001", severity="error", message=e.message,
            task=e.task, keyword=e.keyword,
            file=e.file or str(path), line=e.line)])
    except OSError as e:
        return LintReport(findings=[Finding(
            rule="E001", severity="error",
            message=f"cannot read file: {e}", file=str(path))])
    return lint(spec, slots=slots, priors=priors,
                max_runtime_days=max_runtime_days)


def render_text(reports: "dict[str, LintReport]") -> str:
    """The findings table: one block per file, aligned columns."""
    lines: list[str] = []
    for fname, rep in reports.items():
        status = "clean" if rep.ok and not rep.findings else \
            ("ok" if rep.ok else "FAIL")
        lines.append(f"== {fname} [{status}]")
        lines.extend("  " + f.render() for f in rep.findings)
        if rep.suppressed:
            lines.append(f"  suppressed: {', '.join(rep.suppressed)}")
    total_e = sum(len(r.errors) for r in reports.values())
    total_w = sum(len(r.warnings) for r in reports.values())
    lines.append(f"{len(reports)} file(s): {total_e} error(s), "
                 f"{total_w} warning(s)")
    return "\n".join(lines)


def render_json(reports: "dict[str, LintReport]") -> str:
    doc: dict[str, Any] = {
        "ok": all(r.ok for r in reports.values()),
        "files": {fname: rep.as_dict()
                  for fname, rep in reports.items()},
    }
    return json.dumps(doc, indent=2, sort_keys=True)


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        description="static analysis for WDL parameter studies")
    ap.add_argument("paramfile", nargs="+",
                    help="parameter files (each linted as its own study)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    dest="fmt", help="findings output format")
    ap.add_argument("--slots", type=int, default=None,
                    help="assumed concurrency for the cost estimate "
                         "(default: the study's lint: block, else 8)")
    ap.add_argument("--max-runtime-days", type=float, default=None,
                    help="cost-estimate budget before W601 fires "
                         "(default: the study's lint: block, else 30)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on warnings too, not just errors")
    ap.add_argument("--root", default=None,
                    help="study root (.papas) for observed-duration "
                         "priors (default: declared timeouts only)")
    args = ap.parse_args(argv)

    reports: dict[str, LintReport] = {}
    for fname in args.paramfile:
        priors = None
        if args.root:
            priors = _observed_priors(args.root, fname)
        reports[fname] = lint_file(
            fname, slots=args.slots, priors=priors,
            max_runtime_days=args.max_runtime_days)

    out = (render_json(reports) if args.fmt == "json"
           else render_text(reports))
    print(out)
    failed = any(not r.ok for r in reports.values()) or (
        args.strict and any(r.warnings for r in reports.values()))
    return 1 if failed else 0


def _observed_priors(root: str, paramfile: str) -> "dict[str, float] | None":
    """Median observed runtime per task from an existing study root —
    best effort: a missing/foreign root simply prices from timeouts."""
    try:
        from repro.core.study import load_study

        study = load_study(paramfile, root=root)
        samples: dict[str, list[float]] = {}
        for rec in study.db.records():
            if rec.get("status") != "ok":
                continue
            tname = str(rec.get("task_id", "")).split("@", 1)[0]
            rt = rec.get("runtime")
            if tname and isinstance(rt, (int, float)):
                samples.setdefault(tname, []).append(float(rt))
        return {t: sorted(v)[len(v) // 2] for t, v in samples.items()} \
            or None
    except Exception:
        return None


if __name__ == "__main__":
    sys.exit(main())
