"""File staging: infiles, substitute rewriting, NFS-style hard links."""
from pathlib import Path

from repro.core import (
    ParameterStudy, collect_outputs, parse_yaml, stage_instance,
)


def test_substitute_rewrites_per_instance(tmp_path):
    src = tmp_path / "model.xml"
    src.write_text("<steps>100</steps><agents>50</agents>")
    combo = {"substitute:<steps>\\d+</steps>": "<steps>500</steps>"}
    inst = stage_instance(tmp_path / "work", "i0", {"m": "model.xml"},
                          combo, {"<steps>\\d+</steps>": [0]},
                          source_root=tmp_path)
    out = (inst / "model.xml").read_text()
    assert "<steps>500</steps>" in out and "<agents>50</agents>" in out


def test_unchanged_inputs_hardlinked(tmp_path):
    src = tmp_path / "shared.dat"
    src.write_text("constant input")
    inst = stage_instance(tmp_path / "work", "i1", {"d": "shared.dat"},
                          {}, None, source_root=tmp_path)
    staged = inst / "shared.dat"
    assert staged.read_text() == "constant input"
    assert staged.stat().st_ino == src.stat().st_ino   # same inode


def test_interpolated_names_and_collection(tmp_path):
    (tmp_path / "in_4.txt").write_text("x")
    combo = {"args:size": 4}
    inst = stage_instance(tmp_path / "work", "i2",
                          {"f": "in_${args:size}.txt"}, combo,
                          source_root=tmp_path)
    (inst / "out_4.txt").write_text("result")
    got = collect_outputs(inst, {"o": "out_${args:size}.txt"}, combo,
                          tmp_path / "prov")
    assert got["o"].read_text() == "result"


def test_depth_vs_breadth_order(tmp_path):
    spec = parse_yaml("""
prep:
  args:
    x: [1, 2]
  command: unused
train:
  after: [prep]
  command: unused
""")
    runs = []
    reg = {"prep": lambda c: runs.append(("prep", c.get("args:x"))),
           "train": lambda c: runs.append(("train", None))}

    from repro.core import Scheduler
    study = ParameterStudy(spec, registry=reg, root=tmp_path, name="bf")
    dag = study.build_dag()
    Scheduler(order="breadth").execute(dag, study._default_runner)
    breadth = [t for t, _ in runs]
    assert breadth[:2] == ["prep", "prep"]      # level-major

    runs.clear()
    Scheduler(order="depth").execute(dag, study._default_runner)
    depth = [t for t, _ in runs]
    assert depth[:2] == ["prep", "train"]       # instance-major
