"""Property harness for the chaos layer (skips without hypothesis).

The central claim of the chaos subsystem: for ANY seeded fault plan the
engine either converges on the exact record set of a fault-free run, or
fails loudly — never a silently different result.
"""
import tempfile
import warnings
from pathlib import Path

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    ParameterStudy, StudyJournal, parse_yaml, record_fingerprint,
    truncate_tail,
)
from repro.core.chaos import FaultPlan

WDL = """
t:
  args:
    x: ["1:5"]
  command: echo ${args:x}
"""


class TestChaosEquivalence:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16))
    def test_generated_lane_faults_converge_to_clean_run(self, seed):
        root = Path(tempfile.mkdtemp(prefix="papas_chaos_prop_"))
        clean = ParameterStudy(parse_yaml(WDL), root=root, name="clean")
        clean.run(pool="lane", slots=2)
        fp_clean = record_fingerprint(clean.db.records())

        plan = FaultPlan.generate(seed, lanes=2)
        faulty = ParameterStudy(parse_yaml(WDL), root=root, name="faulty")
        results = faulty.run(pool="lane", slots=2, chaos=plan,
                             max_retries=4, retry={"base": 0.01})
        assert all(r.status == "ok" for r in results.values())
        assert record_fingerprint(faulty.db.records()) == fp_clean

        # resume over a finished study is a no-op: same records, no dupes
        again = ParameterStudy(parse_yaml(WDL), root=root, name="faulty")
        again.run(pool="lane", slots=2)
        assert record_fingerprint(again.db.records()) == fp_clean


class TestTornTailResume:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(min_value=1, max_value=8),
           seed=st.integers(min_value=0, max_value=2**16))
    def test_torn_journal_tail_loses_at_most_one_entry(self, n, seed):
        root = Path(tempfile.mkdtemp(prefix="papas_torn_prop_"))
        j = StudyJournal(root / "journal.json")
        j.save([{"x": i} for i in range(n)], set(), {"name": "s"})
        ids = [f"t@{i}" for i in range(n)]
        for nid in ids:
            j.mark_complete(nid)
        assert truncate_tail(j.log_path)

        j2 = StudyJournal(root / "journal.json")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            state = j2.load_state()
        assert state.completed <= set(ids)
        assert len(state.completed) >= n - 1
