"""Property tests for the combinatorial engine (paper §5.1)."""
import itertools

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ParameterSpace, combo_id


def small_values():
    return st.lists(st.integers(0, 9), min_size=1, max_size=4, unique=True)


def spaces():
    return st.dictionaries(
        st.sampled_from(list("abcdef")), small_values(),
        min_size=1, max_size=4,
    ).map(lambda params: ParameterSpace(params=params))


class TestCartesian:
    @given(spaces())
    @settings(max_examples=100, deadline=None)
    def test_cardinality_is_product(self, space):
        # N_W = ∏ N_i  (paper, §5.1)
        expected = 1
        for vals in space.params.values():
            expected *= len(vals)
        combos = list(space.combinations())
        assert space.size() == expected == len(combos)

    @given(spaces())
    @settings(max_examples=50, deadline=None)
    def test_combinations_unique(self, space):
        ids = [combo_id(c) for c in space.combinations()]
        assert len(ids) == len(set(ids))

    @given(spaces())
    @settings(max_examples=50, deadline=None)
    def test_every_value_appears(self, space):
        combos = list(space.combinations())
        for name, vals in space.params.items():
            seen = {c[name] for c in combos}
            assert seen == set(vals)

    def test_commutativity(self):
        # P_i × P_j = P_j × P_i (paper): same combination SET either order
        s1 = ParameterSpace(params={"a": [1, 2], "b": [3, 4]})
        s2 = ParameterSpace(params={"b": [3, 4], "a": [1, 2]})
        as_set = lambda s: {tuple(sorted(c.items()))  # noqa: E731
                            for c in s.combinations()}
        assert as_set(s1) == as_set(s2)


class TestFixed:
    def test_fixed_zips(self):
        space = ParameterSpace(
            params={"a": [1, 2, 3], "b": [10, 20, 30], "c": [0, 1]},
            fixed=[["a", "b"]])
        combos = list(space.combinations())
        assert space.size() == 6 == len(combos)
        for c in combos:
            assert c["b"] == c["a"] * 10   # bijection preserved

    def test_multiple_fixed_groups(self):
        space = ParameterSpace(
            params={"a": [1, 2], "b": [3, 4], "c": [5, 6], "d": [7, 8]},
            fixed=[["a", "b"], ["c", "d"]])
        assert space.size() == 4

    def test_constant_single_valued_fixed(self):
        # paper: fixed also expresses constant single-valued parameters
        space = ParameterSpace(params={"a": [1], "b": [2, 3]},
                               fixed=[["a"]])
        assert space.size() == 2

    def test_mismatched_lengths_rejected(self):
        with pytest.raises(ValueError):
            ParameterSpace(params={"a": [1, 2], "b": [1, 2, 3]},
                           fixed=[["a", "b"]])

    def test_param_in_two_groups_rejected(self):
        with pytest.raises(ValueError):
            ParameterSpace(params={"a": [1], "b": [1], "c": [1]},
                           fixed=[["a", "b"], ["a", "c"]])

    @given(st.integers(1, 5), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_fixed_cardinality(self, n_fixed, n_free):
        space = ParameterSpace(
            params={"f1": list(range(n_fixed)), "f2": list(range(n_fixed)),
                    "g": list(range(n_free))},
            fixed=[["f1", "f2"]])
        assert space.size() == n_fixed * n_free


class TestSampling:
    def test_uniform_subset(self):
        space = ParameterSpace(params={"a": list(range(10))},
                               sampling={"method": "uniform", "count": 4})
        sample = space.sample()
        assert len(sample) == 4
        full = list(space.combinations())
        assert all(s in full for s in sample)

    def test_random_subset_deterministic(self):
        space = ParameterSpace(
            params={"a": list(range(20))},
            sampling={"method": "random", "count": 5, "seed": 42})
        assert space.sample() == space.sample()

    def test_fraction(self):
        space = ParameterSpace(params={"a": list(range(10))},
                               sampling={"method": "uniform",
                                         "fraction": 0.3})
        assert len(space.sample()) == 3

    @given(spaces(), st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_sample_always_subset(self, space, k):
        import dataclasses
        s2 = dataclasses.replace(
            space, sampling={"method": "random", "count": k, "seed": 0})
        full = list(space.combinations())
        sample = s2.sample()
        assert len(sample) == min(k, len(full))
        for c in sample:
            assert c in full
