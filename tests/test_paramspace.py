"""Example tests for the combinatorial engine (paper §5.1).

Property-based coverage (requires ``hypothesis``) lives in
``test_paramspace_props.py``.
"""
import pytest

from repro.core import ParameterSpace


class TestCartesian:
    def test_commutativity(self):
        # P_i × P_j = P_j × P_i (paper): same combination SET either order
        s1 = ParameterSpace(params={"a": [1, 2], "b": [3, 4]})
        s2 = ParameterSpace(params={"b": [3, 4], "a": [1, 2]})
        as_set = lambda s: {tuple(sorted(c.items()))  # noqa: E731
                            for c in s.combinations()}
        assert as_set(s1) == as_set(s2)

    def test_cardinality_small(self):
        space = ParameterSpace(params={"a": [1, 2, 3], "b": [0, 1]})
        assert space.size() == 6 == len(list(space.combinations()))


class TestFixed:
    def test_fixed_zips(self):
        space = ParameterSpace(
            params={"a": [1, 2, 3], "b": [10, 20, 30], "c": [0, 1]},
            fixed=[["a", "b"]])
        combos = list(space.combinations())
        assert space.size() == 6 == len(combos)
        for c in combos:
            assert c["b"] == c["a"] * 10   # bijection preserved

    def test_multiple_fixed_groups(self):
        space = ParameterSpace(
            params={"a": [1, 2], "b": [3, 4], "c": [5, 6], "d": [7, 8]},
            fixed=[["a", "b"], ["c", "d"]])
        assert space.size() == 4

    def test_constant_single_valued_fixed(self):
        # paper: fixed also expresses constant single-valued parameters
        space = ParameterSpace(params={"a": [1], "b": [2, 3]},
                               fixed=[["a"]])
        assert space.size() == 2

    def test_mismatched_lengths_rejected(self):
        with pytest.raises(ValueError):
            ParameterSpace(params={"a": [1, 2], "b": [1, 2, 3]},
                           fixed=[["a", "b"]])

    def test_param_in_two_groups_rejected(self):
        with pytest.raises(ValueError):
            ParameterSpace(params={"a": [1], "b": [1], "c": [1]},
                           fixed=[["a", "b"], ["a", "c"]])


class TestSampling:
    def test_uniform_subset(self):
        space = ParameterSpace(params={"a": list(range(10))},
                               sampling={"method": "uniform", "count": 4})
        sample = space.sample()
        assert len(sample) == 4
        full = list(space.combinations())
        assert all(s in full for s in sample)

    def test_random_subset_deterministic(self):
        space = ParameterSpace(
            params={"a": list(range(20))},
            sampling={"method": "random", "count": 5, "seed": 42})
        assert space.sample() == space.sample()

    def test_fraction(self):
        space = ParameterSpace(params={"a": list(range(10))},
                               sampling={"method": "uniform",
                                         "fraction": 0.3})
        assert len(space.sample()) == 3
