"""Unified-engine concurrency tests.

Deterministic coverage uses ``VirtualPool`` — a virtual-clock event
source driving the *same* event loop as live execution — so out-of-order
completion, retry-then-skip closure, timeouts, and speculative
duplication are exercised without wall-clock sleeps.  One test runs a
real ``ThreadWorkerPool`` and asserts actual makespan speedup.
"""
import time

import pytest

from repro.core import (
    Scheduler, ShellResult, TaskDAG, TaskNode, VirtualClock, VirtualPool,
    make_pool,
)


def build_dag(spec):
    """spec: {node_id: [deps]}"""
    dag = TaskDAG()
    for nid, deps in spec.items():
        dag.add(TaskNode(id=nid, task="t", combo={}, deps=list(deps)))
    return dag


def virtual(durations, **kw):
    clock = VirtualClock()
    return clock, VirtualPool(durations, clock, call_runner=True, **kw)


class TestOutOfOrderCompletion:
    def test_fast_tasks_finish_and_release_deps_first(self):
        dag = build_dag({"a": [], "b": [], "c": [], "d": ["a"], "e": ["c"]})
        clock, pool = virtual({"a": 5.0, "b": 3.0, "c": 1.0,
                               "d": 5.0, "e": 1.0})
        res = Scheduler(slots=3, clock=clock).execute(
            dag, lambda n: n.id, pool=pool)
        assert all(r.status == "ok" for r in res.values())
        # c (dur 1) finished before b (dur 3) even though b dispatched first,
        # and its successor e completed while a was still running
        assert res["c"].finished < res["b"].finished
        assert res["e"].finished < res["a"].finished
        # successors never start before their dependency finishes
        assert res["d"].started >= res["a"].finished
        assert res["e"].started >= res["c"].finished

    def test_real_slots_reported(self):
        dag = build_dag({"a": [], "b": [], "c": []})
        clock, pool = virtual({"a": 2.0, "b": 2.0, "c": 2.0})
        res = Scheduler(slots=3, clock=clock).execute(
            dag, lambda n: n.id, pool=pool)
        assert sorted(r.slot for r in res.values()) == [0, 1, 2]

    def test_execute_and_simulate_agree_on_slot_meaning(self):
        dag = build_dag({"a": [], "b": []})
        ev = Scheduler().simulate(dag, {"a": 1.0, "b": 1.0}, "serial")
        assert all(e.slot == 0 for e in ev)
        res = Scheduler(slots=1).execute(dag, lambda n: n.id)
        assert all(r.slot == 0 for r in res.values())


class TestRetryAndClosure:
    def test_retry_then_skip_closure_under_out_of_order(self):
        dag = build_dag({"bad": [], "ok1": [], "ok2": [],
                         "child": ["bad"], "grand": ["child"]})

        def runner(node):
            if node.id == "bad":
                raise RuntimeError("boom")
            return node.id

        # each bad attempt takes 2 virtual seconds; ok2 is still running
        # (dur 5) when bad exhausts its retries at t=4
        clock, pool = virtual({"bad": 2.0, "ok1": 1.0, "ok2": 5.0,
                               "child": 1.0, "grand": 1.0})
        res = Scheduler(slots=3, max_retries=1, clock=clock).execute(
            dag, runner, pool=pool)
        assert res["bad"].status == "failed" and res["bad"].attempts == 2
        assert res["child"].status == "skipped"
        assert res["grand"].status == "skipped"
        assert "dependency failed" in res["child"].error
        assert res["ok1"].status == "ok" and res["ok2"].status == "ok"
        # ok1 resolved before the failure was final (out-of-order)
        assert res["ok1"].finished < res["bad"].finished

    def test_retry_spans_are_recorded(self):
        dag = build_dag({"flaky": []})
        calls = {"n": 0}

        def runner(node):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient")
            return "fine"

        clock, pool = virtual({"flaky": 2.0})
        res = Scheduler(max_retries=2, clock=clock).execute(
            dag, runner, pool=pool)
        r = res["flaky"]
        assert r.status == "ok" and r.attempts == 2
        # runtime spans both attempts (2s each) plus the default
        # 50 ms retry backoff between them
        assert r.runtime == pytest.approx(4.05)


class TestSpeculation:
    def test_speculative_duplicate_wins(self):
        ids = [f"a{i}" for i in range(5)] + ["zz-slow"]
        dag = build_dag({nid: [] for nid in ids})

        def durations(nid, attempt):
            if nid == "zz-slow":
                return 100.0 if attempt == 0 else 1.0
            return 1.0

        clock, pool = virtual(durations)
        res = Scheduler(slots=2, straggler_factor=3.0, clock=clock,
                        speculate=True).execute(dag, lambda n: n.id, pool=pool)
        assert all(r.status == "ok" for r in res.values())
        slow = res["zz-slow"]
        # the duplicate (launched once elapsed > 3× median) finished first
        assert slow.speculative is True
        assert slow.finished < 100.0
        assert max(r.finished for r in res.values()) < 100.0

    def test_no_speculation_without_flag(self):
        ids = [f"a{i}" for i in range(5)] + ["zz-slow"]
        dag = build_dag({nid: [] for nid in ids})
        dispatches = {"zz-slow": 0}

        def durations(nid, attempt):
            if nid == "zz-slow":
                dispatches["zz-slow"] += 1
                return 100.0
            return 1.0

        clock, pool = virtual(durations)
        res = Scheduler(slots=2, clock=clock).execute(
            dag, lambda n: n.id, pool=pool)
        assert dispatches["zz-slow"] == 1
        assert res["zz-slow"].speculative is False
        assert res["zz-slow"].finished == pytest.approx(102.0)


class TestTimeouts:
    def test_payload_timeout_fails_attempt(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="t", task="t", combo={},
                         payload={"timeout": 1.5}))
        clock, pool = virtual({"t": 10.0})
        res = Scheduler(max_retries=0, clock=clock).execute(
            dag, lambda n: n.id, pool=pool)
        assert res["t"].status == "failed"
        assert "timeout" in res["t"].error

    def test_timeout_does_not_poison_queued_work(self):
        # A timed-out dispatch leaves its worker busy; the slot must stay
        # occupied until the zombie completes, so queued work and retries
        # actually run instead of spuriously timing out behind it.
        calls = {"a": 0, "b": 0}

        def runner(node):
            calls[node.id] += 1
            if node.id == "a" and calls["a"] == 1:
                time.sleep(0.3)
            return node.id

        dag = TaskDAG()
        dag.add(TaskNode(id="a", task="t", combo={},
                         payload={"timeout": 0.1}))
        dag.add(TaskNode(id="b", task="t", combo={}))
        res = Scheduler(slots=1, max_retries=1).execute(
            dag, runner, pool=make_pool("thread", 1))
        assert res["b"].status == "ok" and calls["b"] == 1
        assert res["a"].status == "ok"
        assert res["a"].attempts == 2 and calls["a"] == 2

    def test_thread_pool_deadline_abandons_straggler(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="t", task="t", combo={},
                         payload={"timeout": 0.05}))
        t0 = time.monotonic()
        res = Scheduler(max_retries=0).execute(
            dag, lambda n: time.sleep(0.5), pool=make_pool("thread", 1))
        wall = time.monotonic() - t0
        assert res["t"].status == "failed"
        assert "timeout" in res["t"].error
        assert wall < 0.4   # did not wait out the full 0.5s sleep


class TestGangTimeoutBudget:
    def test_gang_batch_gets_summed_timeout_budget(self, tmp_path):
        # 4 members × timeout 0.4 → 1.6s batch budget; a 0.3s batch
        # launch must NOT be failed against a single member's limit
        from repro.core import GangExecutor, ParameterStudy, parse_yaml, \
            stackable_key
        spec = parse_yaml("""
work:
  args:
    x: [1, 2, 3, 4]
  timeout: 0.4
  command: unused
""")
        study = ParameterStudy(spec, root=tmp_path, name="gangtmo")
        gang = GangExecutor(
            stackable_key,
            lambda nodes: time.sleep(0.3) or [n.combo["args:x"]
                                              for n in nodes])
        res = study.run(gang=gang, max_retries=0)
        assert len(res) == 4
        assert all(r.status == "ok" for r in res.values())
        assert gang.stats.dispatches == 1


class TestProcessPoolPickling:
    def test_default_runner_is_picklable(self, tmp_path):
        # pool="process" pickles the bound default runner — the study's
        # journal/provenance locks must not ride along
        import pickle
        from repro.core import ParameterStudy, parse_yaml
        spec = parse_yaml("sh:\n  command: echo hi\n")
        study = ParameterStudy(spec, root=tmp_path, name="pkl")
        clone = pickle.loads(pickle.dumps(study._default_runner))
        (node,) = study.build_dag().nodes.values()
        assert clone(node).stdout.strip() == "hi"


class TestShellClassification:
    def test_nonzero_exit_classified_as_failure(self):
        dag = build_dag({"sh": []})
        runner = lambda n: ShellResult(3, "", "boom", 0.01)  # noqa: E731
        res = Scheduler(max_retries=0).execute(dag, runner)
        assert res["sh"].status == "failed"
        assert "nonzero exit 3" in res["sh"].error
        assert res["sh"].value is None

    def test_allow_nonzero_payload_accepts_exit_code(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="sh", task="t", combo={},
                         payload={"allow_nonzero": True}))
        runner = lambda n: ShellResult(3, "out", "", 0.01)  # noqa: E731
        res = Scheduler(max_retries=0).execute(dag, runner)
        assert res["sh"].status == "ok"
        assert res["sh"].value.returncode == 3

    def test_run_subprocess_returns_result_on_nonzero(self):
        from repro.core import run_subprocess
        r = run_subprocess("false")
        assert r.returncode != 0 and not r.ok
        r2 = run_subprocess("echo hi")
        assert r2.returncode == 0 and r2.stdout.strip() == "hi"


class TestRealParallelism:
    def test_thread_pool_makespan_beats_serial_on_sleep_tasks(self):
        n, nap = 24, 0.04
        dag = build_dag({f"j{i:02d}": [] for i in range(n)})
        runner = lambda node: time.sleep(nap)  # noqa: E731

        t0 = time.monotonic()
        serial = Scheduler(slots=1).execute(dag, runner)
        serial_wall = time.monotonic() - t0

        t0 = time.monotonic()
        threaded = Scheduler(slots=4).execute(dag, runner,
                                              pool=make_pool("thread", 4))
        thread_wall = time.monotonic() - t0

        assert all(r.status == "ok" for r in serial.values())
        assert all(r.status == "ok" for r in threaded.values())
        assert thread_wall < 0.5 * serial_wall
        used = {r.slot for r in threaded.values()}
        assert used <= set(range(4)) and len(used) > 1

    def test_study_run_on_thread_pool(self, tmp_path):
        from repro.core import ParameterStudy, parse_yaml
        spec = parse_yaml("""
work:
  args:
    x: ["1:8"]
  command: unused
""")
        study = ParameterStudy(
            spec, registry={"work": lambda c: time.sleep(0.02) or c["args:x"]},
            root=tmp_path, name="tp")
        res = study.run(slots=4, pool="thread")
        assert len(res) == 8
        assert all(r.status == "ok" for r in res.values())
        assert sorted(r.value for r in res.values()) == list(range(1, 9))
        # provenance + journal kept up under the concurrent engine
        assert study.db.completed_ids() == set(res)
        _, completed, _ = study.journal.load()
        assert completed == set(res)
