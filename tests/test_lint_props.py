"""Property test: the lint reference checker is a static proof.

For randomly generated studies — tasks with random declared parameters
and commands referencing random (sometimes bogus, sometimes ambiguous)
``${...}`` paths — the rule pack must be *sound*: a study that lints
with zero errors renders every one of its instances without raising,
and conversely a study whose command cannot render must carry at least
one error-severity finding.  This pins ``classify_reference`` to the
exact resolution order ``interpolate.resolve`` uses; any drift between
the two shows up here as a falsifying example.
"""
import itertools

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    InterpolationError, compile_template, lint, parse_dict,
)

TASK_NAMES = ("prep", "crunch", "report")
PARAM_NAMES = ("alpha", "beta", "gamma")
GROUPS = ("args", "opts")


@st.composite
def study_docs(draw):
    """A small random study: 1-3 tasks, each declaring a few grouped
    parameters and a command whose ``${...}`` slots are drawn from
    declared paths, short tails, inter-task paths, and typos alike."""
    names = draw(st.lists(st.sampled_from(TASK_NAMES),
                          min_size=1, max_size=3, unique=True))
    doc = {}
    for tname in names:
        groups = {}
        for pname in draw(st.lists(st.sampled_from(PARAM_NAMES),
                                   min_size=0, max_size=3, unique=True)):
            group = draw(st.sampled_from(GROUPS))
            groups.setdefault(group, {})[pname] = [1, 2]
        ref_pool = (
            [f"{g}:{p}" for g in GROUPS for p in PARAM_NAMES]
            + list(PARAM_NAMES)
            + [f"{o}:{g}:{p}" for o in TASK_NAMES
               for g in GROUPS[:1] for p in PARAM_NAMES]
            + ["bogus", "args:bogus"])
        refs = draw(st.lists(st.sampled_from(ref_pool),
                             min_size=0, max_size=4))
        task = {"command": "run " + " ".join(f"${{{r}}}" for r in refs)}
        task.update(groups)
        doc[tname] = task
    return doc


def _combos(params):
    """Every combination of a task's declared parameter values."""
    keys = sorted(params)
    for values in itertools.product(*(params[k] for k in keys)):
        yield dict(zip(keys, values))


def _render_all(spec):
    """Render every task's command over every one of its combos, with
    the full inter-task scope — the runtime's exact resolution path."""
    params = {t: task.parameters() for t, task in spec.tasks.items()}
    anchor = {t: {k: v[0] for k, v in p.items()}
              for t, p in params.items()}
    for tname, task in spec.tasks.items():
        tmpl = compile_template(task.command)
        for combo in _combos(params[tname]):
            studies = dict(anchor)
            studies[tname] = combo
            tmpl.render(combo, tname, studies)


@settings(max_examples=80, deadline=None)
@given(study_docs())
def test_zero_error_lint_implies_every_instance_renders(doc):
    spec = parse_dict(doc, validate=False)
    report = lint(spec)
    if report.errors:
        return    # vacuous branch of the implication
    _render_all(spec)    # must not raise


@settings(max_examples=80, deadline=None)
@given(study_docs())
def test_render_failure_implies_an_error_finding(doc):
    spec = parse_dict(doc, validate=False)
    try:
        _render_all(spec)
    except InterpolationError:
        report = lint(spec)
        assert report.errors, \
            "a study that cannot render must not lint clean"
        assert {f.rule for f in report.errors} <= {"E101", "E102"}
