"""Results subsystem: capture grammar, extraction, classification,
streaming aggregation, and resume semantics for captured metrics."""
import json
import math
import statistics

import pytest

from repro.core import ParameterStudy, ResultsAggregator, parse_yaml
from repro.core.executors import ShellResult
from repro.core.results import (
    BUILTIN_CAPTURES, CaptureError, CaptureSet, KeyResolutionError,
    MetricStats, infer_scalar, parse_capture, parse_captures, resolve_key,
)
from repro.core.wdl import RESERVED_KEYWORDS, WDLError


def _study(wdl: str, tmp_path, name="s", **kwargs) -> ParameterStudy:
    return ParameterStudy(parse_yaml(wdl), root=tmp_path, name=name,
                          **kwargs)


# ---------------------------------------------------------------------------
# Capture grammar
# ---------------------------------------------------------------------------


class TestCaptureGrammar:
    def test_reserved_keywords(self):
        assert "capture" in RESERVED_KEYWORDS
        assert "baseline" in RESERVED_KEYWORDS

    def test_shorthand_regex_is_optional_stdout(self):
        spec = parse_capture("t", "m", r"v=(\d+)")
        assert spec.kind == "regex" and spec.source == "stdout"
        assert not spec.required

    def test_shorthand_builtin(self):
        for b in BUILTIN_CAPTURES:
            spec = parse_capture("t", "m", b)
            assert spec.kind == "builtin" and spec.path == b

    def test_mapping_form(self):
        spec = parse_capture("t", "m", {
            "regex": r"t=(?P<value>\d+)", "source": "stderr",
            "required": True, "type": "float"})
        assert spec.source == "stderr" and spec.required
        assert spec.cast == "float"

    def test_exactly_one_kind(self):
        with pytest.raises(CaptureError, match="exactly one"):
            parse_capture("t", "m", {"regex": "a", "json": "b"})
        with pytest.raises(CaptureError, match="exactly one"):
            parse_capture("t", "m", {"required": True})

    def test_bad_regex(self):
        with pytest.raises(CaptureError, match="bad regex"):
            parse_capture("t", "m", "([")

    def test_unknown_source_type_builtin_and_keys(self):
        with pytest.raises(CaptureError, match="unknown source"):
            parse_capture("t", "m", {"regex": "a", "source": "nope"})
        with pytest.raises(CaptureError, match="unknown type"):
            parse_capture("t", "m", {"regex": "a", "type": "complex"})
        with pytest.raises(CaptureError, match="unknown builtin"):
            parse_capture("t", "m", {"builtin": "ram"})
        with pytest.raises(CaptureError, match="unknown key"):
            parse_capture("t", "m", {"regex": "a", "pattern": "b"})

    def test_wdl_surfaces_capture_errors(self):
        with pytest.raises(WDLError, match="bad regex"):
            parse_yaml("t:\n  capture:\n    m: '(['\n")

    def test_wdl_outfile_capture_validated(self):
        with pytest.raises(WDLError, match="no such\\s+outfile"):
            parse_yaml(
                "t:\n  capture:\n    m:\n      regex: a\n"
                "      source: 'outfile:res'\n")
        spec = parse_yaml(
            "t:\n  outfiles:\n    res: out.txt\n"
            "  capture:\n    m:\n      regex: a\n"
            "      source: 'outfile:res'\n")
        assert spec.tasks["t"].capture["m"].source == "outfile:res"

    def test_wdl_baseline_scalars_only(self):
        spec = parse_yaml("t:\n  baseline:\n    threads: '1'\n")
        assert spec.tasks["t"].baseline == {"threads": 1}
        with pytest.raises(WDLError, match="scalar"):
            parse_yaml("t:\n  baseline:\n    threads: '1:8'\n")

    def test_infer_scalar_never_expands_ranges(self):
        assert infer_scalar("16:32") == "16:32"
        assert infer_scalar("42") == 42
        assert infer_scalar("4.5") == 4.5
        assert infer_scalar("true") is True


# ---------------------------------------------------------------------------
# Extraction
# ---------------------------------------------------------------------------


def _cs(caps: dict, outfiles=None) -> CaptureSet:
    return CaptureSet("t", parse_captures("t", caps), outfiles)


class TestExtraction:
    def test_last_match_wins(self):
        cs = _cs({"m": r"v=(\d+)"})
        v = ShellResult(0, "v=1\nv=2\nv=3", "", 0.0)
        assert cs.extract(v)[0] == {"m": 3}

    def test_named_group_and_explicit_group(self):
        cs = _cs({"a": r"(?P<value>\d+) of (\d+)",
                  "b": {"regex": r"(\d+) of (\d+)", "group": 2}})
        v = ShellResult(0, "7 of 9", "", 0.0)
        assert cs.extract(v)[0] == {"a": 7, "b": 9}

    def test_stderr_source(self):
        cs = _cs({"m": {"regex": r"err=(\d+)", "source": "stderr"}})
        v = ShellResult(0, "", "err=5", 0.0)
        assert cs.extract(v)[0] == {"m": 5}
        assert cs.uses_stderr

    def test_json_path_from_text_and_value(self):
        cs = _cs({"m": {"json": "perf.runs.1.t"}})
        doc = {"perf": {"runs": [{"t": 1}, {"t": 2.5}]}}
        v = ShellResult(0, json.dumps(doc), "", 0.0)
        assert cs.extract(v)[0] == {"m": 2.5}
        # registry tasks can return the structure directly
        assert cs.extract(doc)[0] == {"m": 2.5}

    def test_csv_column_last_row_and_positional(self):
        text = "n,t\n1,0.5\n2,0.25\n"
        cs = _cs({"t": {"csv": "t"}, "first": {"csv": "0"}})
        v = ShellResult(0, text, "", 0.0)
        assert cs.extract(v)[0] == {"t": 0.25, "first": 2}

    def test_csv_header_only_is_missing(self):
        cs = _cs({"t": {"csv": "t", "required": True},
                  "p": {"csv": "0", "required": True}})
        metrics, missing = cs.extract(ShellResult(0, "n,t\n", "", 0.0))
        assert metrics == {"t": None, "p": None}
        assert sorted(missing) == ["p", "t"]

    def test_file_template_source(self, tmp_path):
        out = tmp_path / "r_3.txt"
        out.write_text("gflops: 12.5\n")
        cs = _cs({"g": {"regex": r"gflops: ([\d.]+)",
                        "source": f"file:{tmp_path}/r_${{x}}.txt"}})
        metrics, missing = cs.extract(ShellResult(0, "", "", 0.0),
                                      combo={"x": 3})
        assert metrics == {"g": 12.5} and not missing

    def test_outfile_template_source(self, tmp_path):
        out = tmp_path / "res_2.txt"
        out.write_text("t=9")
        cs = _cs({"m": {"regex": r"t=(\d+)", "source": "outfile:res"}},
                 outfiles={"res": f"{tmp_path}/res_${{x}}.txt"})
        assert cs.extract(None, combo={"x": 2})[0] == {"m": 9}

    def test_required_vs_optional_missing(self):
        cs = _cs({"req": {"regex": r"a=(\d+)", "required": True},
                  "opt": r"b=(\d+)"})
        metrics, missing = cs.extract(ShellResult(0, "nothing", "", 0.0))
        assert missing == ["req"]
        assert metrics == {"req": None, "opt": None}

    def test_type_inference_and_cast(self):
        cs = _cs({"i": r"i=(\S+)", "f": r"f=(\S+)", "b": r"b=(\S+)",
                  "s": r"s=(\S+)",
                  "forced": {"regex": r"i=(\S+)", "type": "str"}})
        v = ShellResult(0, "i=3 f=2.5 b=true s=abc", "", 0.0)
        m = cs.extract(v)[0]
        assert m == {"i": 3, "f": 2.5, "b": True, "s": "abc",
                     "forced": "3"}
        assert isinstance(m["i"], int) and isinstance(m["f"], float)

    def test_non_shellresult_value_stringifies(self):
        cs = _cs({"m": r"([\d.]+)"})
        assert cs.extract(3.25)[0] == {"m": 3.25}

    def test_finalize_builtins(self):
        cs = _cs({"rc": "rc", "dur": "duration", "host": "host",
                  "slot": "slot", "m": r"v=(\d+)"})

        class R:
            runtime, host, slot = 1.5, "h0", 3
            value = ShellResult(2, "v=1", "", 1.5)
        out = cs.finalize({"m": 1}, R())
        assert out == {"rc": 2, "dur": 1.5, "host": "h0", "slot": 3,
                       "m": 1}
        assert list(out) == ["rc", "dur", "host", "slot", "m"]


# ---------------------------------------------------------------------------
# Engine integration: classification, records, builtins
# ---------------------------------------------------------------------------


WDL_CAP = """
t:
  x: ["1:3"]
  command: echo "v=${x}"
  capture:
    v:
      regex: "v=([0-9]+)"
      required: true
    rc: rc
    dur: duration
"""


class TestEngineIntegration:
    def test_ok_run_records_metrics(self, tmp_path):
        study = _study(WDL_CAP, tmp_path)
        results = study.run()
        assert all(r.status == "ok" for r in results.values())
        for r in results.values():
            assert r.metrics["rc"] == 0 and r.metrics["dur"] >= 0
        by_v = sorted(r.metrics["v"] for r in results.values())
        assert by_v == [1, 2, 3]
        recs = [r for r in study.db.records() if r["status"] == "ok"]
        assert sorted(r["metrics"]["v"] for r in recs) == [1, 2, 3]

    def test_missing_required_fails_and_closes(self, tmp_path):
        wdl = """
a:
  x: ["1:2"]
  command: echo "nothing"
  capture:
    v:
      regex: "v=([0-9]+)"
      required: true
b:
  after: [a]
  command: echo "done"
"""
        study = _study(wdl, tmp_path)
        results = study.run(max_retries=1)
        a = [r for rid, r in results.items() if rid.startswith("a@")]
        b = [r for rid, r in results.items() if rid.startswith("b@")]
        assert all(r.status == "failed" for r in a)
        assert all("missing required metric" in r.error for r in a)
        assert all(r.attempts == 2 for r in a), "retries must apply"
        assert all(r.status == "skipped" for r in b), "closure must apply"

    def test_missing_optional_is_null(self, tmp_path):
        wdl = WDL_CAP.replace("required: true", "required: false")
        study = _study(wdl.replace('echo "v=${x}"', 'echo "w=${x}"'),
                       tmp_path)
        results = study.run()
        assert all(r.status == "ok" for r in results.values())
        assert all(r.metrics["v"] is None for r in results.values())

    def test_lane_pool_stderr_capture_routed(self, tmp_path):
        wdl = """
t:
  x: ["1:4"]
  command: echo "e=${x}" >&2
  capture:
    e:
      regex: "e=([0-9]+)"
      source: stderr
      required: true
"""
        study = _study(wdl, tmp_path)
        results = study.run(pool="lane", slots=2)
        assert all(r.status == "ok" for r in results.values())
        assert sorted(r.metrics["e"] for r in results.values()) == \
            [1, 2, 3, 4]

    def test_slot_and_host_builtins_on_lane(self, tmp_path):
        wdl = """
t:
  x: ["1:4"]
  command: "true"
  capture:
    where: host
    lane_slot: slot
"""
        study = _study(wdl, tmp_path)
        results = study.run(pool="lane", slots=2)
        hosts = {r.metrics["where"] for r in results.values()}
        assert hosts and all(h.startswith("lane") for h in hosts)
        assert all(r.metrics["lane_slot"] >= 0 for r in results.values())

    def test_batch_pool_spool_stdout_capture(self, tmp_path):
        """Batch allocations spool per-task .out files; capture must see
        that stdout exactly like an inline run's."""
        from repro.core import LocalSubmitter

        study = _study(WDL_CAP, tmp_path, name="batch")
        results = study.run(pool="slurm", submitter=LocalSubmitter(),
                            nnodes=1, ppnode=2)
        assert all(r.status == "ok" for r in results.values())
        assert sorted(r.metrics["v"] for r in results.values()) == [1, 2, 3]

    def test_ssh_pool_stdout_capture(self, tmp_path):
        from repro.core import LocalTransport

        study = _study(WDL_CAP, tmp_path, name="ssh")
        results = study.run(pool="ssh", hosts=["h0", "h1"],
                            transport=LocalTransport())
        assert all(r.status == "ok" for r in results.values())
        assert sorted(r.metrics["v"] for r in results.values()) == [1, 2, 3]
        # the host builtin is absent here, but TaskResult.host is real
        assert {r.host for r in results.values()} <= {"h0", "h1"}

    def test_gang_path_captures(self, tmp_path):
        from repro.core import GangExecutor, stackable_key

        study = _study(WDL_CAP.replace('echo "v=${x}"', "noop"), tmp_path)

        def gang_runner(nodes):
            return [f"v={n.combo['x']}" for n in nodes]
        gang = GangExecutor(stackable_key, gang_runner)
        results = study.run(gang=gang)
        assert sorted(r.metrics["v"] for r in results.values()) == [1, 2, 3]


# ---------------------------------------------------------------------------
# Streaming aggregation
# ---------------------------------------------------------------------------


class TestAggregator:
    def test_stats_match_reference(self):
        xs = [3.5, 1.0, 2.25, 9.0, 4.0, 4.0, 0.5]
        ms = MetricStats()
        for x in xs:
            ms.add(x)
        assert ms.n == len(xs)
        assert ms.mean == pytest.approx(statistics.fmean(xs))
        assert ms.std == pytest.approx(statistics.stdev(xs))
        assert ms.min == min(xs) and ms.max == max(xs)
        assert ms.median == sorted(xs)[len(xs) // 2]

    def test_short_key_resolution(self):
        assert resolve_key("size", ["args:size", "other"]) == "args:size"
        assert resolve_key("size", ["t/args:size"]) == "t/args:size"
        assert resolve_key("size", ["width", "height"]) is None
        with pytest.raises(KeyResolutionError):
            resolve_key("size", ["a:size", "b:size"])

    def test_group_by_param_and_metric(self):
        agg = ResultsAggregator(["size", "mode"])
        agg.add({"args:size": 16}, {"mode": "fast", "t": 1.0})
        agg.add({"args:size": 16}, {"mode": "slow", "t": 4.0})
        assert set(agg.groups) == {(16, "fast"), (16, "slow")}

    def test_unresolvable_key_counts_but_skips(self):
        agg = ResultsAggregator(["nope"])
        assert agg.add({"x": 1}, {"t": 1.0}) is False
        assert agg.n_results == 1 and agg.n_grouped == 0
        assert not agg.groups

    def test_ambiguous_key_never_raises_mid_stream(self):
        """An ambiguous --group-by must not crash a live run from inside
        the engine's on_result path: the result is skipped and the
        resolution error is recorded for post-run surfacing."""
        agg = ResultsAggregator(["size"])
        combo = {"a:size": 1, "b:size": 2}
        assert agg.add(combo, {"t": 1.0}) is False
        assert "size" in agg.key_errors
        assert "ambiguous" in agg.key_errors["size"]
        assert agg.n_grouped == 0

    def test_canonical_keys_fold_integral_floats(self):
        agg = ResultsAggregator(["x"])
        agg.add({"x": 2}, {"t": 1.0})
        agg.add({"x": 2.0}, {"t": 3.0})
        assert list(agg.groups) == [(2,)]
        assert agg.groups[(2,)]["t"].n == 2

    def test_speedup_and_efficiency(self):
        agg = ResultsAggregator(["size", "threads"])
        for size in (16, 32):
            for p in (1, 2, 4):
                agg.add({"size": size},
                        {"threads": p, "time": 8.0 * size / p})
        out = agg.speedup("time", {"threads": 1})
        for (size, p), vals in out.items():
            assert vals["speedup"] == pytest.approx(p)
            assert vals["efficiency"] == pytest.approx(1.0)

    def test_speedup_missing_baseline_group_is_none(self):
        agg = ResultsAggregator(["threads"])
        agg.add({"threads": 2}, {"time": 1.0})
        out = agg.speedup("time", {"threads": 1})
        assert out[(2,)]["speedup"] is None

    def test_speedup_zero_baseline_is_data_not_missing(self):
        """A legitimate 0 aggregate (e.g. an error counter) is data: the
        ratio computes; only division by a 0 group value stays None."""
        agg = ResultsAggregator(["threads"])
        agg.add({"threads": 1}, {"errs": 0.0})
        agg.add({"threads": 2}, {"errs": 4.0})
        out = agg.speedup("errs", {"threads": 1})
        assert out[(2,)]["speedup"] == 0.0          # 0 / 4
        assert out[(1,)]["speedup"] is None         # x / 0 undefined

    def test_baseline_must_pin_one_axis(self):
        agg = ResultsAggregator(["a", "b"])
        with pytest.raises(ValueError, match="exactly one"):
            agg.speedup("t", {"a": 1, "b": 2})
        with pytest.raises(KeyResolutionError):
            agg.speedup("t", {"c": 1})

    def test_streaming_memory_is_o_groups_at_1e4(self, tmp_path):
        """≥10^4 instances through a windowed keep_results=False run:
        aggregator state stays O(groups), engine state O(slots+window)."""
        wdl = """
t:
  x: ["1:100"]
  y: ["1:100"]
  command: noop
  capture:
    m: "m=([0-9]+)"
"""
        study = _study(wdl, tmp_path)
        n = study.instance_count()
        assert n == 10_000
        study.registry.update(
            {"t": lambda combo: f"m={combo['x'] % 7}"})
        agg = ResultsAggregator(["m"], track_median=False)
        slots, window = 4, 64
        results = study.run(window=window, slots=slots,
                            keep_results=False, aggregator=agg)
        assert results == {}, "keep_results=False must not accumulate"
        assert agg.n_grouped == n
        assert len(agg.groups) == 7, "state must be O(groups), not O(N)"
        # with the exact median disabled, no per-result samples survive
        for cells in agg.groups.values():
            for stats in cells.values():
                assert stats._median is None
        assert sum(ms.n for c in agg.groups.values()
                   for ms in c.values()) == n
        assert study.last_run_stats["peak_live_nodes"] <= slots + window


# ---------------------------------------------------------------------------
# Resume semantics: metrics survive a crash, no re-extraction, no dupes
# ---------------------------------------------------------------------------


WDL_RESUME = """
t:
  x: ["1:40"]
  command: noop
  capture:
    v:
      regex: "v=([0-9]+)"
      required: true
"""


class _Crash(RuntimeError):
    pass


def _run_with_crash(study, crash_after, **kwargs):
    """Run until ``crash_after`` completions, then die mid-study (the
    group-commit guarantee flushes everything recorded so far)."""
    seen = [0]

    def boom(res):
        seen[0] += 1
        if seen[0] >= crash_after:
            raise _Crash

    with pytest.raises(_Crash):
        study.run(on_result=boom, **kwargs)
    return seen[0]


class TestResumeMetrics:
    @pytest.mark.parametrize("window", [None, 8],
                             ids=["eager", "windowed"])
    def test_metrics_survive_resume(self, tmp_path, window, monkeypatch):
        registry = {"t": lambda combo: f"v={combo['x']}"}
        study = _study(WDL_RESUME, tmp_path, name=f"r{window}")
        study.registry.update(registry)
        n = study.instance_count()
        crashed_at = _run_with_crash(study, crash_after=10, window=window)
        pre = {r["task_id"]: r for r in study.db.records()
               if r["status"] == "ok"}
        assert len(pre) >= 10, "group commit must flush pre-crash metrics"

        # fresh study object (new process semantics) + extraction counter
        study2 = _study(WDL_RESUME, tmp_path, name=f"r{window}")
        study2.registry.update(registry)
        calls = [0]
        orig = CaptureSet.extract

        def counting(self, value, combo=None):
            calls[0] += 1
            return orig(self, value, combo)
        monkeypatch.setattr(CaptureSet, "extract", counting)
        results = study2.run(resume=True, window=window)
        if window is None:
            assert sum(1 for r in results.values()
                       if r.status == "ok") == n
        # completed instances are never re-extracted...
        completed_before = len(pre)
        assert calls[0] == n - completed_before
        # ...and never re-recorded: exactly one ok record per task
        ok_recs = [r for r in study2.db.records() if r["status"] == "ok"]
        per_task: dict = {}
        for r in ok_recs:
            per_task.setdefault(r["task_id"], []).append(r)
        assert len(per_task) == n
        assert all(len(v) == 1 for v in per_task.values()), \
            "duplicate ok records after resume"
        # every pre-crash metric is still present, byte for byte
        for tid, rec in pre.items():
            assert per_task[tid][0]["metrics"] == rec["metrics"]
        # and the full metric set covers the whole space
        vs = sorted(r[0]["metrics"]["v"] for r in per_task.values())
        assert vs == list(range(1, n + 1))

    def test_windowed_resume_uses_v2_journal(self, tmp_path):
        registry = {"t": lambda combo: f"v={combo['x']}"}
        study = _study(WDL_RESUME, tmp_path, name="v2")
        study.registry.update(registry)
        _run_with_crash(study, crash_after=10, window=8)
        doc = json.loads(study.journal.path.read_text())
        assert doc["version"] == 2
