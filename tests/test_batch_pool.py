"""Batch-scheduler worker pool: golden SLURM/PBS submission scripts (no
scheduler binary required), grouped-allocation execution through the
fake LocalSubmitter, spool-protocol completion, and cancellation."""
from pathlib import Path

import pytest

from repro.core import (
    BatchWorkerPool, LocalSubmitter, ParameterStudy, Scheduler, TaskDAG,
    TaskNode, make_pool, parse_yaml, render_batch_script,
)
from repro.core.remote import SchedulerSubmitter

GOLDEN = Path(__file__).parent / "golden"

ENTRIES = [
    ("matmul 16 result_16N_1T.txt", {"OMP_NUM_THREADS": "1"}),
    ("matmul 32 result_32N_2T.txt", {"OMP_NUM_THREADS": "2"}),
]


def make_dag(names, command="echo hi"):
    dag = TaskDAG()
    for name in names:
        dag.add(TaskNode(id=name, task=name, combo={},
                         payload={"command": command}))
    return dag


def render(node):
    return node.payload["command"], {}


class TestScriptRendering:
    @pytest.mark.parametrize("kind", ["slurm", "pbs"])
    def test_golden_script(self, kind):
        script = render_batch_script(
            kind, job_name="papas-demo", nnodes=2, ppnode=4,
            entries=ENTRIES, spool="/spool")
        golden = (GOLDEN / f"{kind}_n2_p4.sh").read_text()
        assert script == golden

    def test_slurm_directives(self):
        script = render_batch_script(
            "slurm", job_name="j", nnodes=2, ppnode=4,
            entries=ENTRIES, spool="/s")
        assert "#SBATCH --nodes=2" in script
        assert "#SBATCH --ntasks-per-node=4" in script

    def test_pbs_directives(self):
        script = render_batch_script(
            "pbs", job_name="j", nnodes=2, ppnode=4,
            entries=ENTRIES, spool="/s")
        assert "#PBS -l nodes=2:ppn=4" in script

    def test_env_values_are_shell_quoted(self):
        script = render_batch_script(
            "slurm", job_name="j", nnodes=1, ppnode=1,
            entries=[("run", {"MSG": "two words; rm -rf /"})], spool="/s")
        assert "export MSG='two words; rm -rf /'" in script

    def test_unknown_batch_kind(self):
        with pytest.raises(ValueError, match="slurm"):
            render_batch_script("lsf", job_name="j", nnodes=1, ppnode=1,
                                entries=ENTRIES, spool="/s")


class TestBatchPoolExecution:
    def test_group_runs_inside_one_allocation(self, tmp_path):
        pool = BatchWorkerPool(batch="slurm", nnodes=1, ppnode=4,
                               render=render, submitter=LocalSubmitter(),
                               spool_root=tmp_path)
        assert pool.slots == 4
        # one dispatch = one whole allocation: the scheduler must drive
        # max_allocations lanes, not slots of them
        assert pool.dispatch_slots == 1
        dag = make_dag([f"t{i}" for i in range(4)])
        sched = Scheduler(slots=pool.dispatch_slots)
        try:
            results = sched.execute(dag, runner=None, pool=pool)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in results.values())
        # one grouped allocation hosted all four tasks
        hosts = {r.host for r in results.values()}
        assert len(hosts) == 1
        assert next(iter(hosts)).startswith("slurm:local")
        for r in results.values():
            assert r.value.returncode == 0
            assert r.value.stdout.strip() == "hi"

    def test_overflow_submits_sequential_allocations(self, tmp_path):
        """More ready tasks than one allocation holds: groups are
        submitted one after another (max_allocations=1), never
        nnodes×ppnode simultaneous jobs."""
        submitter = LocalSubmitter()
        pool = BatchWorkerPool(batch="slurm", nnodes=1, ppnode=4,
                               render=render, submitter=submitter,
                               spool_root=tmp_path)
        dag = make_dag([f"t{i}" for i in range(10)])
        sched = Scheduler(slots=pool.dispatch_slots)
        try:
            results = sched.execute(dag, runner=None, pool=pool)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in results.values())
        # ceil(10 / 4) = 3 allocations total
        assert len({r.host for r in results.values()}) == 3
        assert submitter._n == 3

    def test_take_claims_up_to_group_size(self, tmp_path):
        pool = BatchWorkerPool(batch="slurm", nnodes=2, ppnode=2,
                               render=render, submitter=LocalSubmitter(),
                               spool_root=tmp_path)
        try:
            ready = [f"t{i}" for i in range(7)]
            dag = make_dag(list(ready))
            assert pool.take(ready, dag) == ["t0", "t1", "t2", "t3"]
            assert ready == ["t4", "t5", "t6"]
        finally:
            pool.shutdown()

    def test_nonzero_exit_classified_as_failure(self, tmp_path):
        pool = BatchWorkerPool(batch="slurm", nnodes=1, ppnode=1,
                               render=render, submitter=LocalSubmitter(),
                               spool_root=tmp_path)
        dag = make_dag(["bad"], command="exit 3")
        sched = Scheduler(slots=1, max_retries=0)
        try:
            results = sched.execute(dag, runner=None, pool=pool)
        finally:
            pool.shutdown()
        assert results["bad"].status == "failed"
        assert "nonzero exit 3" in results["bad"].error

    def test_pbs_pool_end_to_end(self, tmp_path):
        pool = BatchWorkerPool(batch="pbs", nnodes=1, ppnode=2,
                               render=render, submitter=LocalSubmitter(),
                               spool_root=tmp_path)
        dag = make_dag(["a", "b"])
        sched = Scheduler(slots=pool.dispatch_slots)
        try:
            results = sched.execute(dag, runner=None, pool=pool)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in results.values())
        assert all(r.host.startswith("pbs:") for r in results.values())

    def test_cancel_synthesizes_completion(self, tmp_path):
        pool = BatchWorkerPool(batch="slurm", nnodes=1, ppnode=1,
                               render=render, submitter=LocalSubmitter(),
                               spool_root=tmp_path)
        try:
            node = TaskNode(id="slow", task="slow", combo={},
                            payload={"command": "sleep 30"})
            pool.submit(0, None, [node])
            pool.cancel(0)
            ev = pool.next_event(timeout=2)
            assert ev is not None and ev.token == 0
            assert "cancelled" in ev.errors[0]
        finally:
            pool.shutdown()

    def test_submission_failure_fails_the_attempt(self, tmp_path):
        class BrokenSubmitter(LocalSubmitter):
            def submit(self, script):
                raise RuntimeError("queue rejected the job")

        pool = BatchWorkerPool(batch="slurm", nnodes=1, ppnode=1,
                               render=render, submitter=BrokenSubmitter(),
                               spool_root=tmp_path)
        dag = make_dag(["x"])
        sched = Scheduler(slots=1, max_retries=0)
        try:
            results = sched.execute(dag, runner=None, pool=pool)
        finally:
            pool.shutdown()
        assert results["x"].status == "failed"
        assert "queue rejected" in results["x"].error


class TestStudyIntegration:
    WDL = """
    sweepit:
      batch: slurm
      nnodes: 2
      ppnode: 4
      environ:
        N: ["1:4"]
      command: echo n=${environ:N}
    """

    def test_wdl_batch_keywords_drive_the_pool(self, tmp_path):
        study = ParameterStudy(parse_yaml(self.WDL), root=tmp_path,
                               name="batchstudy")
        results = study.run(pool="batch", submitter=LocalSubmitter())
        assert len(results) == 4
        assert all(r.status == "ok" for r in results.values())
        assert all((r.host or "").startswith("slurm:") for r in results.values())
        # the rendered submission script reflects batch: slurm, nnodes: 2,
        # ppnode: 4 from the WDL
        scripts = list((study.db.dir / "batch").glob("job*/job.sh"))
        assert scripts
        text = scripts[0].read_text()
        assert "#SBATCH --nodes=2" in text
        assert "#SBATCH --ntasks-per-node=4" in text
        # journal carries the allocation identity per task
        hosts = study.journal.hosts()
        assert set(hosts) == set(results)


class TestMakePool:
    def test_slurm_kind(self, tmp_path):
        pool = make_pool("slurm", nnodes=2, ppnode=3, render=render,
                         submitter=LocalSubmitter(), spool_root=tmp_path)
        try:
            assert pool.slots == 6 and pool.batch == "slurm"
        finally:
            pool.shutdown()

    def test_pbs_kind(self, tmp_path):
        pool = make_pool("pbs", nnodes=1, ppnode=2, render=render,
                         submitter=LocalSubmitter(), spool_root=tmp_path)
        try:
            assert pool.slots == 2 and pool.batch == "pbs"
        finally:
            pool.shutdown()

    def test_scheduler_submitter_specs(self):
        s = SchedulerSubmitter("slurm")
        assert s.submit_cmd == ("sbatch",)
        m = s.id_re.search("Submitted batch job 42")
        assert m and m.group(1) == "42"
        p = SchedulerSubmitter("pbs")
        m = p.id_re.search("1234.head-node")
        assert m and m.group(1) == "1234.head-node"
