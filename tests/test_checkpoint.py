"""Checkpoint save/restore: roundtrip, atomicity, pruning, elastic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_smoke
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import init_train_state

KEY = jax.random.PRNGKey(3)


def small_state():
    cfg = get_smoke("deepseek-7b")
    opt = AdamW(schedule=cosine_schedule(1e-3, 5, 50))
    return cfg, opt, init_train_state(cfg, opt, KEY)


class TestRoundtrip:
    def test_save_restore_identical(self, tmp_path):
        _, _, state = small_state()
        ckpt.save(state, tmp_path, step=7)
        restored = ckpt.restore(state, tmp_path)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step_and_pruning(self, tmp_path):
        _, _, state = small_state()
        for s in (1, 2, 3, 4, 5):
            ckpt.save(state, tmp_path, step=s, keep=2)
        assert ckpt.all_steps(tmp_path) == [4, 5]
        assert ckpt.latest_step(tmp_path) == 5

    def test_restore_specific_step(self, tmp_path):
        _, _, state = small_state()
        s1 = jax.tree.map(lambda x: x, state)
        ckpt.save(s1, tmp_path, step=1)
        s2 = jax.tree.map(
            lambda x: x + 1 if jnp.issubdtype(x.dtype, jnp.floating) else x,
            state)
        ckpt.save(s2, tmp_path, step=2)
        r1 = ckpt.restore(state, tmp_path, step=1)
        np.testing.assert_array_equal(
            np.asarray(jax.tree.leaves(r1)[0]),
            np.asarray(jax.tree.leaves(s1)[0]))

    def test_shape_mismatch_rejected(self, tmp_path):
        _, _, state = small_state()
        ckpt.save(state, tmp_path, step=1)
        bad = jax.tree.map(lambda x: jnp.zeros((3,) + x.shape, x.dtype),
                           state)
        with pytest.raises(ValueError):
            ckpt.restore(bad, tmp_path)

    def test_missing_dir_raises(self, tmp_path):
        _, _, state = small_state()
        with pytest.raises(FileNotFoundError):
            ckpt.restore(state, tmp_path / "nope")

    def test_no_tmp_dir_left_behind(self, tmp_path):
        _, _, state = small_state()
        ckpt.save(state, tmp_path, step=1)
        assert not list(tmp_path.glob("*.tmp"))


class TestElastic:
    def test_restore_onto_explicit_shardings(self, tmp_path):
        """Elastic restart: restore with a target sharding tree built for
        the current (1-device) mesh."""
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_local_mesh

        _, _, state = small_state()
        ckpt.save(state, tmp_path, step=3)
        mesh = make_local_mesh()
        shardings = shd.state_shardings(
            jax.eval_shape(lambda s: s, state), mesh)
        restored = ckpt.restore(state, tmp_path, shardings=shardings)
        leaf = jax.tree.leaves(restored["params"])[0]
        assert leaf.sharding is not None
        np.testing.assert_array_equal(
            np.asarray(leaf),
            np.asarray(jax.tree.leaves(state["params"])[0]))

    def test_training_resumes_from_checkpoint(self, tmp_path):
        """Save at step 2, keep training to 4; restart from ckpt and
        re-train — trajectories match (determinism of resume)."""
        from repro.data.pipeline import SyntheticStream
        from repro.train.step import TrainStepConfig, make_train_step

        cfg, opt, state = small_state()
        step_fn = jax.jit(make_train_step(cfg, opt))
        stream = SyntheticStream(cfg, global_batch=2, seq_len=16, seed=1)

        losses_a = []
        for i in range(4):
            batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
            state, m = step_fn(state, batch)
            losses_a.append(float(m["loss"]))
            if i == 1:
                ckpt.save(state, tmp_path, step=2)

        restored = ckpt.restore(
            jax.eval_shape(lambda s: s, state), tmp_path, step=2)
        losses_b = []
        for i in range(2, 4):
            batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
            restored, m = step_fn(restored, batch)
            losses_b.append(float(m["loss"]))
        np.testing.assert_allclose(losses_a[2:], losses_b, rtol=1e-5)
