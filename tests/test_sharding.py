"""Sharding-rule unit tests (no multi-device mesh needed: rules are pure
functions over paths/shapes + a mesh object built from 1 device)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke
from repro.distributed import sharding as shd
from repro.models import Model


@pytest.fixture(scope="module")
def mesh():
    # single real device, axis sizes 1: rule structure is what we test
    return jax.make_mesh((1, 1), ("data", "model"))


def specs_by_suffix(tree, mesh):
    out = {}
    shardings = shd.params_shardings(tree, mesh)
    flat_s = jax.tree_util.tree_flatten_with_path(shardings)[0]
    for path, sh in flat_s:
        name = shd._path_names(path)[-1]
        out.setdefault(name, set()).add(tuple(sh.spec))
    return out


class TestParamRules:
    def test_dense_rules(self, mesh):
        cfg = get_smoke("deepseek-7b")
        params = Model(cfg).init_abstract()
        by = specs_by_suffix(params, mesh)
        assert by["embed"] == {("model", None)}
        assert by["wq"] == {(None, None, "model")}      # segment-stacked
        assert by["wo"] == {(None, "model", None)}
        assert by["lm_head"] == {(None, "model")}

    def test_moe_rules(self, mesh):
        cfg = get_smoke("olmoe-1b-7b")
        params = Model(cfg).init_abstract()
        by = specs_by_suffix(params, mesh)
        assert by["wi_gate"] == {(None, None, None, "model")}   # (R,E,d,f)
        # both attention wo (R,ad,d) and moe wo (R,E,f,d) exist
        assert by["wo"] == {(None, "model", None),
                            (None, None, "model", None)}
        assert by["router"] == {(None, None, None)}

    def test_ssm_rules(self, mesh):
        cfg = get_smoke("mamba2-780m")
        params = Model(cfg).init_abstract()
        by = specs_by_suffix(params, mesh)
        assert by["in_proj"] == {(None, None, "model")}
        assert by["out_proj"] == {(None, "model", None)}
        assert by["A_log"] == {(None, None)}            # replicated

    def test_norms_replicated(self, mesh):
        cfg = get_smoke("gemma-7b")
        params = Model(cfg).init_abstract()
        by = specs_by_suffix(params, mesh)
        assert by["norm1"] == {(None, None)}


class TestFitSpec:
    def big_mesh(self):
        # mesh object with fake sizes via Mesh of a reshaped device array
        # is impossible with 1 device; test fit_spec math directly with a
        # stub exposing .shape
        class StubMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")
        return StubMesh()

    def test_non_divisible_dropped(self):
        m = self.big_mesh()
        spec = shd.fit_spec(P("model", None), (92553, 6144), m)
        assert tuple(spec) == (None, "model")   # vocab fallback to d

    def test_divisible_kept(self):
        m = self.big_mesh()
        spec = shd.fit_spec(P("model", None), (92672, 6144), m)
        assert tuple(spec) == ("model", None)

    def test_tuple_axes(self):
        m = self.big_mesh()
        spec = shd.fit_spec(P(("data", "model")), (512,), m)
        assert tuple(spec) == ((("data", "model")),)
        spec2 = shd.fit_spec(P(("data", "model")), (100,), m)
        assert tuple(spec2) == (None,)

    def test_batch_one_replicated(self):
        m = self.big_mesh()
        spec = shd.fit_spec(P("data", None), (1, 1), m)
        assert tuple(spec) == (None, None)


class TestZero1:
    def test_moments_pick_largest_free_axis(self):
        class StubMesh:
            shape = {"data": 4, "model": 4}
            axis_names = ("data", "model")
        leaf = jax.ShapeDtypeStruct((1024, 4096), jnp.float32)
        spec = shd.zero1_spec(P(None, "model"), leaf, StubMesh())
        assert tuple(spec) == ("data", "model")

    def test_small_leaves_untouched(self):
        class StubMesh:
            shape = {"data": 4, "model": 4}
            axis_names = ("data", "model")
        leaf = jax.ShapeDtypeStruct((8,), jnp.float32)
        assert tuple(shd.zero1_spec(P(None), leaf, StubMesh())) == (None,)


class TestCacheRules:
    def test_kv_cache_heads_or_headdim(self, mesh):
        cfg = get_smoke("gemma3-1b")      # kv=1 → head_dim sharding path
        cache = jax.eval_shape(
            lambda: Model(cfg).init_cache(batch=2, max_len=16))
        shardings = shd.cache_shardings(cache, mesh)
        flat = jax.tree_util.tree_flatten_with_path(shardings)[0]
        kv_specs = {tuple(sh.spec) for path, sh in flat
                    if shd._path_names(path)[-1] in ("k", "v")}
        assert kv_specs    # non-empty; structure validated
