"""MoE dispatch-strategy equivalence: einsum vs ragged vs sorted, plus
the shard_map path under an ambient mesh."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.jax_compat import set_mesh
from repro.models import Model, synthetic_batch
from repro.models.moe import moe_ragged, moe_sorted_local

KEY = jax.random.PRNGKey(5)


def toy_moe(T=64, D=32, E=8, F=16):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (T, D), jnp.float32)
    p = {"router": jax.random.normal(ks[1], (D, E)) * 0.1,
         "wi_gate": jax.random.normal(ks[2], (E, D, F)) * 0.1,
         "wi_up": jax.random.normal(ks[3], (E, D, F)) * 0.1,
         "wo": jax.random.normal(ks[4], (E, F, D)) * 0.1}
    return x, p, E


class TestSortedDispatch:
    def test_sorted_matches_ragged_when_no_drops(self):
        x, p, e = toy_moe()
        o1, a1 = moe_sorted_local(x, p, n_experts=e, top_k=2, act="silu",
                                  router_renorm=False,
                                  compute_dtype=jnp.float32,
                                  capacity_factor=16.0)
        o2, _ = moe_ragged(x, p, n_experts=e, top_k=2, act="silu",
                           router_renorm=False, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=1e-5)
        assert float(a1["dropped"]) == 0.0

    def test_sorted_reports_drops_at_tight_capacity(self):
        # route everything to one expert → capacity must overflow
        x, p, e = toy_moe(T=512)
        p = dict(p)
        p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
        _, aux = moe_sorted_local(x, p, n_experts=e, top_k=1, act="silu",
                                  router_renorm=False,
                                  compute_dtype=jnp.float32,
                                  capacity_factor=1.0)
        assert float(aux["dropped"]) > 0.0

    def test_gradients_flow(self):
        x, p, e = toy_moe()

        def loss(p):
            o, _ = moe_sorted_local(x, p, n_experts=e, top_k=2, act="silu",
                                    router_renorm=False,
                                    compute_dtype=jnp.float32)
            return jnp.sum(o ** 2)

        g = jax.grad(loss)(p)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.isfinite(leaf).all())
        assert float(jnp.abs(g["wi_gate"]).max()) > 0


class TestShardMapPath:
    def test_ragged_dispatch_under_ambient_mesh(self):
        """dispatch='ragged' + active mesh with a model axis routes
        through moe_ragged_sharded (shard_map)."""
        cfg = dataclasses.replace(get_smoke("olmoe-1b-7b"),
                                  moe_dispatch="ragged")
        m = Model(cfg)
        params = m.init(KEY)
        batch = synthetic_batch(cfg, 2, 32, KEY)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with set_mesh(mesh):
            loss, aux = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
        assert bool(jnp.isfinite(loss))
        # agrees with the local (no-mesh) ragged path
        loss2, _ = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
        assert abs(float(loss) - float(loss2)) < 5e-3

    def test_einsum_vs_sorted_end_to_end(self):
        cfg_e = dataclasses.replace(get_smoke("qwen2-moe-a2.7b"),
                                    capacity_factor=8.0)
        cfg_s = dataclasses.replace(cfg_e, moe_dispatch="ragged")
        me, ms = Model(cfg_e), Model(cfg_s)
        params = me.init(KEY)
        batch = synthetic_batch(cfg_e, 2, 32, KEY)
        le, _ = me.loss(params, batch)
        ls, _ = ms.loss(params, batch)
        assert abs(float(le) - float(ls)) < 5e-3
