"""LaneWorkerPool — persistent worker lanes (short-task throughput path).

Covers: pipe-protocol execution with stdout capture, per-task env
scoping (no leakage between tasks on the same lane), gang-style take
batching, nonzero-exit classification through the scheduler, timeout →
lane kill → respawn recovery, cancel semantics, study-level integration
(``pool="lane"``), and the ``run_gang`` GangRunner adapter.
"""
import time

import pytest

from repro.core import (
    GangExecutor, LaneWorkerPool, ParameterStudy, Scheduler, TaskDAG,
    TaskNode, make_pool, parse_yaml, stackable_key,
)


def _payload_render(node):
    return node.payload.get("command"), node.payload.get("env") or {}


def _dag(commands, task="t", envs=None):
    dag = TaskDAG()
    for i, cmd in enumerate(commands):
        payload = {"command": cmd}
        if envs and envs[i]:
            payload["env"] = envs[i]
        dag.add(TaskNode(id=f"{task}{i:03d}", task=task, combo={},
                         payload=payload))
    return dag


class TestLaneExecution:
    def test_commands_run_with_stdout_captured(self):
        dag = _dag([f"echo out{i}" for i in range(10)])
        pool = LaneWorkerPool(2, render=_payload_render)
        try:
            res = Scheduler(slots=2).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in res.values())
        for i in range(10):
            assert res[f"t{i:03d}"].value.stdout == f"out{i}\n"
        # host provenance names the executing lane
        assert all((r.host or "").startswith("lane") for r in res.values())

    def test_stdout_without_trailing_newline(self):
        dag = _dag(["printf noline"])
        pool = LaneWorkerPool(1, render=_payload_render)
        try:
            res = Scheduler(slots=1).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert res["t000"].value.stdout == "noline"

    def test_env_scoped_per_task_no_lane_leakage(self):
        # both tasks run on the SAME lane; the first's env must not leak
        dag = _dag(["echo v=${PAPAS_X:-unset}", "echo v=${PAPAS_X:-unset}"],
                   envs=[{"PAPAS_X": "42"}, None])
        pool = LaneWorkerPool(1, render=_payload_render, batch=2)
        try:
            res = Scheduler(slots=1).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert res["t000"].value.stdout == "v=42\n"
        assert res["t001"].value.stdout == "v=unset\n"

    def test_builtin_noop_runs_without_fork(self):
        # `true` is a shell builtin: the whole batch is zero-fork
        dag = _dag(["true"] * 16)
        pool = LaneWorkerPool(2, render=_payload_render, batch=8)
        try:
            res = Scheduler(slots=2).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in res.values())
        assert pool.stats.tasks == 16
        # batched, not per-task (chunks shrink adaptively near the tail:
        # 8+4+2+1+1 across 2 slots)
        assert pool.stats.dispatches <= 6
        assert pool.stats.batching_factor >= 2.5

    def test_nonzero_exit_classified_with_stderr(self):
        dag = _dag(["sh -c 'echo broke >&2; exit 3'", "echo fine"])
        pool = LaneWorkerPool(1, render=_payload_render, batch=2)
        try:
            res = Scheduler(slots=1, max_retries=0).execute(dag, None,
                                                            pool=pool)
        finally:
            pool.shutdown()
        assert res["t000"].status == "failed"
        assert "nonzero exit 3" in res["t000"].error
        assert "broke" in res["t000"].error      # stderr spool read back
        assert res["t001"].status == "ok"

    def test_registry_only_node_fails_with_clear_error(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="x", task="t", combo={}, payload={}))
        pool = LaneWorkerPool(1, render=_payload_render)
        try:
            res = Scheduler(slots=1, max_retries=0).execute(dag, None,
                                                            pool=pool)
        finally:
            pool.shutdown()
        assert res["x"].status == "failed"
        assert "no shell command" in res["x"].error


class TestTimeoutAndRecovery:
    def test_timeout_kills_lane_and_later_tasks_recover(self):
        dag = TaskDAG()
        dag.add(TaskNode(id="a", task="t", combo={},
                         payload={"command": "echo one", "timeout": 10}))
        dag.add(TaskNode(id="b", task="t", combo={},
                         payload={"command": "sleep 30", "timeout": 0.3}))
        dag.add(TaskNode(id="c", task="t", combo={},
                         payload={"command": "echo three", "timeout": 10}))
        pool = LaneWorkerPool(1, render=_payload_render, batch=3)
        t0 = time.monotonic()
        try:
            res = Scheduler(slots=1, max_retries=0).execute(dag, None,
                                                            pool=pool)
        finally:
            pool.shutdown()
        assert time.monotonic() - t0 < 10       # never waited out the sleep
        assert res["a"].status == "ok" and res["a"].value.stdout == "one\n"
        assert res["b"].status == "failed" and "timeout" in res["b"].error
        # c was resent after the lane respawned
        assert res["c"].status == "ok" and res["c"].value.stdout == "three\n"
        assert pool.stats.respawns >= 1

    def test_scheduler_cancel_frees_slot(self):
        # scheduler-side deadline expiry abandons the dispatch and
        # cancel() kills the lane; the slot must return to service
        dag = TaskDAG()
        dag.add(TaskNode(id="slow", task="t", combo={},
                         payload={"command": "sleep 30", "timeout": 0.2}))
        dag.add(TaskNode(id="next", task="t", combo={},
                         payload={"command": "echo ok"}))
        pool = LaneWorkerPool(1, render=_payload_render, batch=1)
        try:
            res = Scheduler(slots=1, max_retries=0).execute(dag, None,
                                                            pool=pool)
        finally:
            pool.shutdown()
        assert res["slow"].status == "failed"
        assert res["next"].status == "ok"


class TestFrameReassembly:
    """Split-sentinel / slow-writer regressions.  The mux reads lane
    stdout in 64 KB chunks, so frames routinely arrive fragmented (large
    outputs), coalesced (many tiny outputs in one read), or with the rc
    sentinel itself straddling two reads.  None of that may mis-frame a
    result."""

    def test_large_output_fragments_across_reads(self):
        # ~260 KB of stdout: several pipe reads per frame, the sentinel
        # lands in the final fragment
        n = 40_000
        dag = _dag([f"seq 1 {n}"])
        pool = LaneWorkerPool(1, render=_payload_render)
        try:
            res = Scheduler(slots=1).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert res["t000"].status == "ok"
        assert res["t000"].value.stdout == \
            "".join(f"{i}\n" for i in range(1, n + 1))

    def test_large_and_tiny_frames_interleave_in_one_batch(self):
        # one batch mixes multi-read frames with sub-read frames on the
        # same lane buffer
        cmds = ["seq 1 20000", "echo tiny0", "seq 20001 40000", "echo tiny1"]
        dag = _dag(cmds, task="t")
        pool = LaneWorkerPool(1, render=_payload_render, batch=4)
        try:
            res = Scheduler(slots=1).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in res.values())
        assert res["t000"].value.stdout == \
            "".join(f"{i}\n" for i in range(1, 20001))
        assert res["t001"].value.stdout == "tiny0\n"
        assert res["t002"].value.stdout == \
            "".join(f"{i}\n" for i in range(20001, 40001))
        assert res["t003"].value.stdout == "tiny1\n"
        assert pool.stats.dispatches == 1       # one pipe-fed batch

    def test_slow_writer_dribbles_partial_frames(self):
        # a scripted slow writer: output (and eventually the sentinel)
        # arrives across multiple reads separated by real time — the
        # partial frame must buffer, never parse early
        dag = _dag(["sh -c 'printf alpha; sleep 0.4; printf beta'",
                    "echo after"])
        pool = LaneWorkerPool(1, render=_payload_render, batch=2)
        try:
            res = Scheduler(slots=1).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert res["t000"].status == "ok"
        assert res["t000"].value.stdout == "alphabeta"
        assert res["t001"].value.stdout == "after\n"

    def test_lane_crash_mid_batch_charges_head_and_recovers(self):
        # scripted lane death: the middle command kills its own worker
        # shell (stdout EOF, no sentinel).  Exactly the command at the
        # read head is charged; completed frames keep their results and
        # the remainder reruns on the respawned lane.
        dag = _dag(["echo pre", "kill -9 $$", "echo post"])
        pool = LaneWorkerPool(1, render=_payload_render, batch=3)
        try:
            res = Scheduler(slots=1, max_retries=0).execute(dag, None,
                                                            pool=pool)
        finally:
            pool.shutdown()
        assert res["t000"].status == "ok"
        assert res["t000"].value.stdout == "pre\n"
        assert res["t001"].status == "failed"
        assert "lane worker exited" in res["t001"].error
        assert res["t002"].status == "ok"
        assert res["t002"].value.stdout == "post\n"
        assert pool.stats.respawns >= 2         # initial spawn + recovery

    def test_repeated_lane_death_fails_batch_not_pool(self):
        # a command that always kills its lane: the stall counter stops
        # the respawn loop and fails the survivors instead of spinning
        dag = _dag(["kill -9 $$"])
        pool = LaneWorkerPool(1, render=_payload_render, batch=1)
        try:
            res = Scheduler(slots=1, max_retries=0).execute(dag, None,
                                                            pool=pool)
            assert res["t000"].status == "failed"
            # the pool is still serviceable after the death loop
            dag2 = _dag(["echo alive"], task="u")
            res2 = Scheduler(slots=1).execute(dag2, None, pool=pool)
            assert res2["u000"].value.stdout == "alive\n"
        finally:
            pool.shutdown()


class TestStudyIntegration:
    WDL = """
sweep:
  environ:
    PAPAS_N: ["1:3"]
  args:
    word: [alpha, beta]
  command: echo ${args:word}_${environ:PAPAS_N}
"""

    def test_pool_lane_end_to_end(self, tmp_path):
        study = ParameterStudy(parse_yaml(self.WDL), root=tmp_path,
                               name="lane_e2e")
        res = study.run(pool="lane", slots=2)
        assert len(res) == 6
        assert all(r.status == "ok" for r in res.values())
        outs = {r.value.stdout.strip() for r in res.values()}
        assert outs == {f"{w}_{n}" for w in ("alpha", "beta")
                        for n in (1, 2, 3)}
        # lane identity is per-attempt provenance (records.jsonl), NOT
        # durable journal host state — a 10^5-task windowed run must not
        # grow an O(N_W) journal host map out of lane labels
        recs = {r["task_id"]: r for r in study.db.records()}
        assert len(recs) == 6
        assert all(r["host"].startswith("lane") for r in recs.values())
        assert study.journal.hosts() == {}

    def test_windowed_lane_composes(self, tmp_path):
        study = ParameterStudy(parse_yaml(self.WDL), root=tmp_path,
                               name="lane_win")
        seen = []
        res = study.run(pool="lane", slots=2, window=2,
                        on_result=lambda r: seen.append(r.id),
                        keep_results=False)
        assert res == {}                        # streamed, not accumulated
        assert len(seen) == 6
        state = study.journal.load_state()
        assert state.version == 2
        assert len(state.completed_indices["sweep"]) == 6

    def test_windowed_lane_resumes_from_v2_journal(self, tmp_path):
        """Interrupt a windowed lane run mid-study; the resume re-admits
        only the remainder and the final journal is compact v2."""
        class Stop(Exception):
            pass

        seen = []

        def tripwire(res):
            seen.append(res.id)
            if len(seen) == 3:
                raise Stop

        study = ParameterStudy(parse_yaml(self.WDL), root=tmp_path,
                               name="lane_resume")
        with pytest.raises(Stop):
            study.run(pool="lane", slots=1, window=1, on_result=tripwire)
        done_before = len(
            study.journal.load_state().completed_indices["sweep"])
        assert done_before == 3

        resumed = ParameterStudy(parse_yaml(self.WDL), root=tmp_path,
                                 name="lane_resume")
        res = resumed.run(pool="lane", slots=2, window=2, resume=True)
        assert all(r.status == "ok" for r in res.values())
        state = resumed.journal.load_state()
        assert state.version == 2
        assert len(state.completed_indices["sweep"]) == 6
        assert resumed.last_run_stats["skipped_complete"] == 3

    def test_lane_renders_byte_identical_to_eager(self, tmp_path):
        """window + lane + group-commit compose: rendered commands match
        the eager regex path byte for byte."""
        study = ParameterStudy(parse_yaml(self.WDL), root=tmp_path,
                               name="lane_render")
        from repro.core import render_command
        for node in study.build_dag().nodes.values():
            task = study.spec.tasks[node.task]
            cmd, _ = study.render_node(node)
            assert cmd == render_command(task.command, node.combo, node.task,
                                         {node.task: dict(node.combo)})

    def test_make_pool_kind(self):
        pool = make_pool("lane", 2, render=_payload_render, batch=4)
        try:
            assert pool.kind == "lane" and pool.slots == 2
        finally:
            pool.shutdown()

    def test_unknown_kind_error_names_lane(self):
        with pytest.raises(ValueError, match="lane"):
            make_pool("warp", 1)


class TestRunGang:
    def test_gang_runner_adapter(self):
        nodes = [TaskNode(id=f"g{i}", task="t", combo={"args:i": i},
                          payload={"command": f"echo g{i}"})
                 for i in range(10)]
        pool = LaneWorkerPool(3, render=_payload_render)
        try:
            values = pool.run_gang(nodes)
        finally:
            pool.shutdown()
        assert [v.stdout for v in values] == [f"g{i}\n" for i in range(10)]

    def test_gang_executor_through_lanes(self, tmp_path):
        wdl = """
fleet:
  args:
    i: ["1:6"]
  command: echo member_${args:i}
"""
        study = ParameterStudy(parse_yaml(wdl), root=tmp_path, name="gl")
        pool = LaneWorkerPool(2, render=study.render_node)
        gang = GangExecutor(stackable_key, pool.run_gang)
        try:
            res = study.run(gang=gang)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in res.values())
        assert gang.stats.tasks == 6
        assert gang.stats.dispatches < 6        # fused batches
