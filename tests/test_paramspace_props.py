"""Hypothesis property tests for the combinatorial engine (paper §5.1).

Skipped wholesale when ``hypothesis`` is not installed (it is a dev-only
dependency — see requirements-dev.txt); the example-based tests live in
``test_paramspace.py`` and always run.
"""
import dataclasses

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ParameterSpace, combo_id  # noqa: E402


def small_values():
    return st.lists(st.integers(0, 9), min_size=1, max_size=4, unique=True)


def spaces():
    return st.dictionaries(
        st.sampled_from(list("abcdef")), small_values(),
        min_size=1, max_size=4,
    ).map(lambda params: ParameterSpace(params=params))


class TestCartesianProps:
    @given(spaces())
    @settings(max_examples=100, deadline=None)
    def test_cardinality_is_product(self, space):
        # N_W = ∏ N_i  (paper, §5.1)
        expected = 1
        for vals in space.params.values():
            expected *= len(vals)
        combos = list(space.combinations())
        assert space.size() == expected == len(combos)

    @given(spaces())
    @settings(max_examples=50, deadline=None)
    def test_combinations_unique(self, space):
        ids = [combo_id(c) for c in space.combinations()]
        assert len(ids) == len(set(ids))

    @given(spaces())
    @settings(max_examples=50, deadline=None)
    def test_every_value_appears(self, space):
        combos = list(space.combinations())
        for name, vals in space.params.items():
            seen = {c[name] for c in combos}
            assert seen == set(vals)


class TestFixedProps:
    @given(st.integers(1, 5), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_fixed_cardinality(self, n_fixed, n_free):
        space = ParameterSpace(
            params={"f1": list(range(n_fixed)), "f2": list(range(n_fixed)),
                    "g": list(range(n_free))},
            fixed=[["f1", "f2"]])
        assert space.size() == n_fixed * n_free


class TestSamplingProps:
    @given(spaces(), st.integers(1, 8))
    @settings(max_examples=50, deadline=None)
    def test_sample_always_subset(self, space, k):
        s2 = dataclasses.replace(
            space, sampling={"method": "random", "count": k, "seed": 0})
        full = list(space.combinations())
        sample = s2.sample()
        assert len(sample) == min(k, len(full))
        for c in sample:
            assert c in full
