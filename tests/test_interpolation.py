"""${...} interpolation + substitute tests (paper §5)."""
import pytest

from repro.core import (
    InterpolationError, ParameterStudy, interpolate, parse_yaml,
    substitute_content,
)


class TestInterpolate:
    COMBO = {"args:size": 64, "environ:OMP_NUM_THREADS": 4, "args:mode": "fast"}

    def test_two_level(self):
        out = interpolate("run ${args:size}", self.COMBO)
        assert out == "run 64"

    def test_bare_keyword_resolves_unique_tail(self):
        assert interpolate("m=${mode}", self.COMBO) == "m=fast"

    def test_multiple_refs(self):
        out = interpolate(
            "matmul ${args:size} r_${args:size}N_${environ:OMP_NUM_THREADS}T",
            self.COMBO)
        assert out == "matmul 64 r_64N_4T"

    def test_unresolvable_raises(self):
        with pytest.raises(InterpolationError):
            interpolate("${nope}", self.COMBO)

    def test_float_formatting_integral(self):
        assert interpolate("${x}", {"a:x": 2.0}) == "2"

    def test_inter_task(self):
        studies = {"prep": {"args:outfile": "data.bin"}}
        out = interpolate("consume ${prep:args:outfile}", {}, studies=studies)
        assert out == "consume data.bin"


class TestSubstitute:
    def test_regex_replacement(self):
        content = "<steps>100</steps>\n<agents>50</agents>"
        rules = {r"<steps>\d+</steps>": "<steps>500</steps>"}
        out = substitute_content(content, rules)
        assert "<steps>500</steps>" in out
        assert "<agents>50</agents>" in out

    def test_substitute_parameter_expansion(self):
        # substitute values are sweepable parameters
        spec = parse_yaml("""
sim:
  command: netlogo model.xml
  substitute:
    "NUM_AGENTS": [10, 20, 30]
""")
        study = ParameterStudy(spec, root="/tmp/papas_sub", name="sub")
        assert study.space().size() == 3


class TestEndToEndRender:
    def test_paper_matmul_commands(self):
        spec = parse_yaml("""
matmulOMP:
  environ:
    OMP_NUM_THREADS: ["1:8"]
  args:
    size: ["16:*2:16384"]
  command: matmul ${args:size} result_${args:size}N_${environ:OMP_NUM_THREADS}T.txt
""")
        study = ParameterStudy(spec, root="/tmp/papas_rend", name="rend")
        insts = study.instances()
        assert len(insts) == 88
        dag = study.build_dag(insts)
        cmds = set()
        envs = set()
        for node in dag.nodes.values():
            cmd, env = study.render_node(node)
            cmds.add(cmd)
            envs.add(env["OMP_NUM_THREADS"])
        assert len(cmds) == 88                     # all unique workflows
        assert "matmul 16 result_16N_1T.txt" in cmds
        assert "matmul 16384 result_16384N_8T.txt" in cmds
        assert envs == {str(i) for i in range(1, 9)}
