"""Compiled-template equivalence (the throughput rendering path).

``CompiledTemplate.render`` / ``CompiledEnviron.render`` must be
byte-identical to the reference ``interpolate()`` / ``render_command`` /
``render_environ`` implementations across the WDL corpus used elsewhere
in the test suite, including the ``${...}`` edge cases: missing keys,
nested braces, numeric formatting, and values that re-introduce
references.
"""
import pytest

from repro.core import (
    CompiledEnviron, CompiledTemplate, InterpolationError, ParameterStudy,
    compile_template, interpolate, parse_yaml, render_command,
    render_environ,
)

#: WDL corpus: the specs exercised across tests/ (paper Fig. 5 matmul,
#: quickstart sweeps, inter-task chains)
WDL_CORPUS = [
    """
matmulOMP:
  environ:
    OMP_NUM_THREADS: ["1:8"]
  args:
    size: ["16:*2:16384"]
  command: matmul ${args:size} result_${args:size}N_${environ:OMP_NUM_THREADS}T.txt
""",
    """
sweep:
  args:
    a: ["1:5"]
    b: [0.5, 1.0, 2.5]
    mode: [fast, slow]
  command: run --a=${args:a} --b=${args:b} --mode=${mode}
""",
    """
prep:
  args:
    outfile: [data_a.bin, data_b.bin]
  command: make ${args:outfile}
consume:
  after: [prep]
  args:
    k: ["1:3"]
  command: consume ${prep:args:outfile} k=${args:k}
""",
]


def _all_nodes(wdl: str):
    study = ParameterStudy(parse_yaml(wdl), root="/tmp/papas_ctpl",
                           name="ctpl")
    dag = study.build_dag()
    return study, dag


class TestCorpusEquivalence:
    @pytest.mark.parametrize("wdl", WDL_CORPUS)
    def test_commands_and_environ_byte_identical(self, wdl):
        study, dag = _all_nodes(wdl)
        n_checked = 0
        for node in dag.nodes.values():
            task = study.spec.tasks[node.task]
            studies = {
                other: {k.split("/", 1)[1]: v
                        for k, v in node.payload["global_combo"].items()
                        if k.startswith(other + "/")}
                for other in study.spec.tasks
            }
            # the study's own render path vs the reference functions
            cmd, env = study.render_node(node)
            assert cmd == render_command(task.command, node.combo,
                                         node.task, studies)
            assert env == render_environ(task.environ, node.combo)
            # and the compiled template directly vs interpolate()
            tpl = CompiledTemplate(task.command)
            assert tpl.render(node.combo, node.task, studies) == \
                interpolate(task.command, node.combo, node.task, studies)
            n_checked += 1
        assert n_checked == len(dag.nodes) > 0


class TestEdgeCases:
    COMBO = {"args:size": 64, "environ:OMP_NUM_THREADS": 4,
             "args:mode": "fast", "a:x": 2.0}

    def _both(self, text, combo, studies=None):
        ref = interpolate(text, combo, studies=studies)
        got = CompiledTemplate(text).render(combo, studies=studies)
        assert got == ref
        return got

    def test_static_template_is_identity(self):
        tpl = CompiledTemplate("no slots here")
        assert tpl.static
        assert tpl.render({}) == "no slots here"

    def test_basic_and_bare_keyword(self):
        assert self._both("run ${args:size} m=${mode}", self.COMBO) \
            == "run 64 m=fast"

    def test_missing_key_raises_both(self):
        with pytest.raises(InterpolationError):
            interpolate("${nope}", self.COMBO)
        with pytest.raises(InterpolationError):
            CompiledTemplate("${nope}").render(self.COMBO)

    def test_numeric_formatting(self):
        # integral floats render without the trailing .0
        assert self._both("${x}", {"a:x": 2.0}) == "2"
        assert self._both("${x}", {"a:x": 2.5}) == "2.5"
        assert self._both("${x}", {"a:x": -3.0}) == "-3"

    def test_nested_braces_unresolvable(self):
        # ${a${b}} — the regex grabs "a${b"; both paths raise identically
        with pytest.raises(InterpolationError):
            interpolate("${a${b}}", self.COMBO)
        with pytest.raises(InterpolationError):
            CompiledTemplate("${a${b}}").render(self.COMBO)

    def test_nested_braces_resolvable(self):
        combo = {"q:a${b": "inner"}
        assert self._both("${a${b}}", combo) == "inner}"

    def test_unclosed_brace_passthrough(self):
        assert self._both("${unclosed", self.COMBO) == "${unclosed"

    def test_value_reintroduces_reference(self):
        # one level of nesting: a resolved value containing ${...}
        combo = {"a:outer": "${inner}", "b:inner": "deep"}
        assert self._both("${outer}", combo) == "deep"

    def test_value_is_its_own_placeholder(self):
        # fixpoint: the value renders to exactly its own reference
        combo = {"a:x": "${x}"}
        assert self._both("${x}", combo) == "${x}"

    def test_inter_task_reference(self):
        studies = {"prep": {"args:outfile": "data.bin"}}
        assert self._both("consume ${prep:args:outfile}", {},
                          studies=studies) == "consume data.bin"

    def test_environ_equivalence_including_absent_keys(self):
        environ = {"OMP_NUM_THREADS": [1], "UNSET_VAR": [1]}
        combo = {"environ:OMP_NUM_THREADS": 4.0}
        ref = render_environ(environ, combo)
        got = CompiledEnviron(tuple(environ)).render(combo)
        assert got == ref == {"OMP_NUM_THREADS": "4"}

    def test_compile_cache_identity(self):
        assert compile_template("x ${a:b}") is compile_template("x ${a:b}")


# -- property test (hypothesis optional; the deterministic corpus above
# -- runs regardless, mirroring the tests/test_*_props.py split) --------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:     # pragma: no cover - CI always has hypothesis
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _keys = st.sampled_from(["args:a", "args:b", "environ:V", "args:mode"])
    _vals = st.one_of(st.integers(-100, 100),
                      st.floats(-100, 100, allow_nan=False),
                      st.text(alphabet="abcXYZ_-.", max_size=8))
    _combo = st.dictionaries(_keys, _vals, min_size=1, max_size=4)
    _chunk = st.one_of(
        st.text(alphabet="abc xyz-_=./", max_size=10),
        _keys.map(lambda k: "${%s}" % k),
        _keys.map(lambda k: "${%s}" % k.split(":", 1)[1]),
        st.just("${missing}"),
    )

    class TestPropertyEquivalence:
        @settings(max_examples=200, deadline=None)
        @given(chunks=st.lists(_chunk, max_size=8), combo=_combo)
        def test_render_matches_interpolate(self, chunks, combo):
            text = "".join(chunks)
            try:
                ref = interpolate(text, combo)
                ref_err = None
            except InterpolationError as e:
                ref, ref_err = None, str(e)
            try:
                got = CompiledTemplate(text).render(combo)
                got_err = None
            except InterpolationError as e:
                got, got_err = None, str(e)
            assert got == ref
            assert (got_err is None) == (ref_err is None)
            if ref_err is not None:
                assert got_err == ref_err
