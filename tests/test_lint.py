"""Static analysis (``papas lint``) — the study rule pack.

Covers: every rule id firing on a targeted minimal spec, the clean
example staying clean, the seeded-defect CI fixture tripping its full
rule set, ``lint:`` block suppression/policy keys, merged-spec conflict
errors, structured WDLError context (task/keyword/file/line), the CLI
front end's exit codes and JSON output, and the O(params) cost bound
(linting a 10^5-combination study in well under a second).
"""
import json
import time
from pathlib import Path

import pytest

from repro.core import (
    RULES, WDLError, lint, load_study, merge, parse_yaml,
)
from repro.launch import lint as lint_cli

FIXTURE = Path(__file__).parent / "fixtures" / "broken_study.yaml"
EXAMPLES = Path(__file__).parent.parent / "examples"


def _lint(text, **kw):
    return lint(parse_yaml(text, validate=False), **kw)


def _rules(report):
    return {f.rule for f in report.findings}


class TestRegistry:
    def test_every_rule_has_valid_severity(self):
        assert all(r.severity in ("error", "warn", "info")
                   for r in RULES.values())

    def test_ids_are_stable_and_unique(self):
        assert RULES["E101"].severity == "error"
        assert RULES["W601"].severity == "warn"
        assert RULES["I601"].severity == "info"
        assert len({r.id for r in RULES.values()}) == len(RULES)


class TestReferences:
    def test_unbound_reference_is_e101(self):
        rep = _lint("t:\n  command: run ${args:sizee}\n"
                    "  args:\n    size: [1, 2]\n")
        assert _rules(rep) == {"E101"}
        f = rep.errors[0]
        assert f.task == "t" and f.keyword == "command"
        assert "${args:sizee}" in f.message

    def test_ambiguous_tail_is_e102(self):
        rep = _lint("t:\n  command: run ${x}\n"
                    "  args:\n    x: [1]\n  opts:\n    x: [2]\n")
        assert _rules(rep) == {"E102"}

    def test_intertask_reference_in_command_resolves(self):
        rep = _lint("a:\n  command: gen ${size}\n"
                    "  args:\n    size: [1, 2]\n"
                    "b:\n  command: use ${a:args:size}\n  after: [a]\n")
        assert rep.findings == []

    def test_intertask_reference_in_infile_is_e101(self):
        # infile name templates render against the combo alone
        # (staging passes no studies scope) — mirror that exactly
        rep = _lint("a:\n  command: gen\n  args:\n    size: [1]\n"
                    "  outfiles:\n    dat: out.dat\n"
                    "b:\n  command: use\n  after: [a]\n"
                    "  infiles:\n    dat: in_${a:args:size}.dat\n")
        assert "E101" in _rules(rep)
        assert any(f.keyword == "infiles.dat" for f in rep.errors)

    def test_nested_reference_is_followed(self):
        # a resolvable value re-introduces ${...}: the worklist must
        # chase it, exactly like the render fixpoint
        rep = _lint("t:\n  command: run ${mode}\n"
                    "  mode: ['--flag ${missing}']\n")
        assert _rules(rep) == {"E101"}

    def test_unreferenced_bad_value_is_not_flagged(self):
        # only values reachable from a checked template are scanned
        rep = _lint("t:\n  command: run ${args:size}\n"
                    "  args:\n    size: [1]\n"
                    "  unused: ['${nope}']\n")
        assert rep.findings == []


class TestDAG:
    def test_unknown_after_is_e201(self):
        rep = _lint("t:\n  command: x\n  after: [ghost]\n")
        assert "E201" in _rules(rep)

    def test_cycle_is_e202(self):
        rep = _lint("a:\n  command: x\n  after: [b]\n"
                    "b:\n  command: y\n  after: [a]\n")
        assert "E202" in _rules(rep)
        msg = next(f for f in rep.errors if f.rule == "E202").message
        assert "->" in msg

    def test_downstream_of_cycle_is_e203(self):
        rep = _lint("a:\n  command: x\n  after: [b]\n"
                    "b:\n  command: y\n  after: [a]\n"
                    "c:\n  command: z\n  after: [a]\n")
        assert {"E202", "E203"} <= _rules(rep)
        assert any(f.rule == "E203" and f.task == "c"
                   for f in rep.errors)

    def test_clean_chain_has_no_findings(self):
        rep = _lint("a:\n  command: x\n"
                    "b:\n  command: y\n  after: [a]\n"
                    "c:\n  command: z\n  after: [a, b]\n")
        assert rep.findings == []


class TestDataflow:
    def test_parameterized_infile_without_producer_is_e301(self):
        rep = _lint("t:\n  command: use\n  part: [1, 2]\n"
                    "  infiles:\n    chunk: chunk_${part}.dat\n")
        assert "E301" in _rules(rep)

    def test_matching_outfile_upstream_is_clean(self):
        rep = _lint("a:\n  command: gen\n  part: [1, 2]\n"
                    "  outfiles:\n    chunk: chunk_${part}.dat\n"
                    "b:\n  command: use\n  after: [a]\n  part: [1, 2]\n"
                    "  infiles:\n    chunk: chunk_${part}.dat\n")
        assert rep.findings == []

    def test_producer_not_an_ancestor_is_e302(self):
        rep = _lint("a:\n  command: gen\n  part: [1, 2]\n"
                    "  outfiles:\n    chunk: chunk_${part}.dat\n"
                    "b:\n  command: use\n  part: [1, 2]\n"
                    "  infiles:\n    chunk: chunk_${part}.dat\n")
        assert "E302" in _rules(rep)

    def test_missing_static_infile_is_w303(self):
        rep = _lint("t:\n  command: use\n"
                    "  infiles:\n    cfg: /no/such/file.cfg\n")
        assert _rules(rep) == {"W303"}
        assert rep.ok    # warning, not error

    def test_existing_static_infile_is_clean(self, tmp_path):
        ext = tmp_path / "input.cfg"
        ext.write_text("x")
        rep = _lint(f"t:\n  command: use\n"
                    f"  infiles:\n    cfg: {ext}\n")
        assert rep.findings == []


class TestCaptures:
    def test_numbered_group_beyond_pattern_is_e401(self):
        rep = _lint("t:\n  command: x\n"
                    "  capture:\n    m:\n"
                    "      regex: 'v=([0-9]+)'\n      group: 2\n")
        assert "E401" in _rules(rep)

    def test_named_group_missing_is_e401(self):
        rep = _lint("t:\n  command: x\n"
                    "  capture:\n    m:\n"
                    "      regex: 'v=(?P<val>[0-9]+)'\n      group: nope\n")
        assert "E401" in _rules(rep)

    def test_undeclared_outfile_source_is_e403(self):
        rep = _lint("t:\n  command: x\n"
                    "  capture:\n    m:\n"
                    "      regex: 'v=([0-9]+)'\n"
                    "      source: 'outfile:missing'\n")
        assert "E403" in _rules(rep)
        f = next(f for f in rep.errors if f.rule == "E403")
        assert f.keyword == "capture.m.source"

    def test_valid_capture_is_clean(self):
        rep = _lint("t:\n  command: x\n"
                    "  outfiles:\n    log: run.log\n"
                    "  capture:\n    m:\n"
                    "      regex: 'v=(?P<val>[0-9]+)'\n      group: val\n"
                    "      source: 'outfile:log'\n"
                    "      required: true\n")
        assert rep.findings == []


class TestDeadCaptures:
    def test_unconsumed_capture_is_w802(self):
        rep = _lint("t:\n  command: x\n"
                    "  capture:\n    m:\n"
                    "      regex: 'v=([0-9]+)'\n")
        assert "W802" in _rules(rep)
        f = next(f for f in rep.findings if f.rule == "W802")
        assert f.severity == "warn" and f.keyword == "capture.m"

    def test_required_capture_is_not_dead(self):
        rep = _lint("t:\n  command: x\n"
                    "  capture:\n    m:\n"
                    "      regex: 'v=([0-9]+)'\n      required: true\n")
        assert "W802" not in _rules(rep)

    def test_builtin_capture_is_not_dead(self):
        # builtins cost nothing to extract — never worth a warning
        rep = _lint("t:\n  command: x\n"
                    "  capture:\n    rc: rc\n    duration: duration\n")
        assert "W802" not in _rules(rep)

    def test_baseline_reference_consumes(self):
        # the captured metric is a baseline axis in another task: the
        # report consumes it, so it is not dead
        rep = _lint("a:\n  command: x\n"
                    "  capture:\n    gflops:\n"
                    "      regex: 'g=([0-9]+)'\n"
                    "b:\n  command: y ${args:size}\n"
                    "  args:\n    size: [1, 2]\n"
                    "  baseline:\n    gflops: 1\n")
        assert "W802" not in _rules(rep)


class TestBaseline:
    def test_unknown_key_is_e501(self):
        rep = _lint("t:\n  command: x ${args:size}\n"
                    "  args:\n    size: [1, 2]\n"
                    "  baseline:\n    threads: 1\n")
        assert _rules(rep) == {"E501"}

    def test_value_outside_declared_values_is_e502(self):
        rep = _lint("t:\n  command: x ${args:size}\n"
                    "  args:\n    size: [1, 2, 4]\n"
                    "  baseline:\n    size: 3\n")
        assert _rules(rep) == {"E502"}

    def test_declared_value_is_clean(self):
        rep = _lint("t:\n  command: x ${args:size}\n"
                    "  args:\n    size: [1, 2, 4]\n"
                    "  baseline:\n    size: 2\n")
        assert rep.findings == []

    def test_captured_metric_key_skips_membership(self):
        # baseline on a reported-value axis (captured metric or a
        # builtin like duration) cannot be checked statically
        rep = _lint("t:\n  command: x\n"
                    "  capture:\n    gflops:\n"
                    "      regex: 'g=([0-9.]+)'\n"
                    "  baseline:\n    gflops: 12.5\n"
                    "    duration: 1.0\n")
        assert rep.findings == []

    def test_conflicting_baselines_across_tasks_is_e503(self):
        rep = _lint("a:\n  command: x ${args:n}\n"
                    "  args:\n    n: [1, 2]\n"
                    "  baseline:\n    n: 1\n"
                    "b:\n  command: y ${args:n}\n"
                    "  args:\n    n: [1, 2]\n"
                    "  baseline:\n    n: 2\n")
        assert "E503" in _rules(rep)

    def test_e502_preview_is_truncated(self):
        rep = lint_cli.lint_file(FIXTURE)
        msg = next(f for f in rep.errors if f.rule == "E502").message
        assert "... (" in msg and len(msg) < 500


class TestSpace:
    def test_conflicting_sampling_is_e504(self):
        rep = _lint("a:\n  command: x ${args:n}\n"
                    "  args:\n    n: [1, 2]\n"
                    "  sampling:\n    method: random\n    count: 2\n"
                    "b:\n  command: y ${args:m}\n"
                    "  args:\n    m: [1, 2]\n"
                    "  sampling:\n    method: random\n    count: 3\n")
        assert "E504" in _rules(rep)

    def test_conflicting_hosts_is_e505(self):
        rep = _lint("a:\n  command: x\n  hosts: [h1, h2]\n"
                    "b:\n  command: y\n  hosts: [h3]\n")
        assert "E505" in _rules(rep)

    def test_agreeing_hosts_is_clean(self):
        rep = _lint("a:\n  command: x\n  hosts: [h1, h2]\n"
                    "b:\n  command: y\n  hosts: [h1, h2]\n")
        assert rep.findings == []

    def test_conflicting_straggler_quantile_is_e506(self):
        rep = _lint("a:\n  command: x\n  straggler_quantile: 0.9\n"
                    "b:\n  command: y\n  straggler_quantile: 0.95\n")
        assert "E506" in _rules(rep)


class TestCost:
    def test_timeout_prices_an_i601_estimate(self):
        rep = _lint("t:\n  command: x ${args:n}\n"
                    "  args:\n    n: [1, 2, 3, 4]\n  timeout: 60\n")
        assert _rules(rep) == {"I601"}
        assert "4 instance(s)" in rep.infos[0].message

    def test_over_budget_is_w601(self):
        # 1000 instances x 1h / 1 slot ≈ 41 days > 30-day default
        rep = _lint("t:\n  command: x ${args:n}\n"
                    "  args:\n    n: ['1:1:1000']\n  timeout: 3600\n",
                    slots=1)
        assert _rules(rep) == {"W601"}
        assert rep.ok    # warning: admissible, but flagged

    def test_slots_argument_divides_the_estimate(self):
        rep = _lint("t:\n  command: x ${args:n}\n"
                    "  args:\n    n: ['1:1:1000']\n  timeout: 3600\n",
                    slots=100)
        assert _rules(rep) == {"I601"}

    def test_priors_override_timeout(self):
        # observed medians say the task is fast despite a huge timeout
        rep = _lint("t:\n  command: x ${args:n}\n"
                    "  args:\n    n: ['1:1:1000']\n  timeout: 86400\n",
                    slots=1, priors={"t": 0.5})
        assert _rules(rep) == {"I601"}

    def test_budget_override_flips_severity(self):
        text = ("t:\n  command: x ${args:n}\n"
                "  args:\n    n: [1, 2]\n  timeout: 3600\n")
        assert _rules(_lint(text, slots=1)) == {"I601"}
        assert _rules(_lint(text, slots=1,
                            max_runtime_days=0.01)) == {"W601"}

    def test_unpriced_tasks_are_reported(self):
        rep = _lint("a:\n  command: x\n  timeout: 10\n"
                    "b:\n  command: y\n")
        assert "excluded: b" in rep.infos[0].message

    def test_no_duration_information_no_estimate(self):
        rep = _lint("t:\n  command: x ${args:n}\n"
                    "  args:\n    n: [1, 2]\n")
        assert rep.findings == []


class TestLintBlock:
    def test_suppress_drops_and_records(self):
        rep = _lint("lint:\n  suppress: [W601]\n"
                    "t:\n  command: x ${args:n}\n"
                    "  args:\n    n: ['1:1:1000']\n  timeout: 3600\n",
                    slots=1)
        assert _rules(rep) == set()
        assert rep.suppressed == ["W601"]

    def test_suppressing_a_warning_does_not_hide_errors(self):
        rep = _lint("lint:\n  suppress: [W601]\n"
                    "t:\n  command: x ${nope}\n")
        assert _rules(rep) == {"E101"}
        assert not rep.ok

    def test_block_sets_cost_policy(self):
        # slots: 1 in the block makes the same sweep 100x slower than
        # the default 8 would estimate — enough to cross the budget
        rep = _lint("lint:\n  slots: 1\n  max_runtime_days: 0.01\n"
                    "t:\n  command: x ${args:n}\n"
                    "  args:\n    n: [1, 2]\n  timeout: 3600\n")
        assert _rules(rep) == {"W601"}

    def test_unknown_policy_key_raises(self):
        with pytest.raises(WDLError, match="lint"):
            parse_yaml("lint:\n  bogus: 1\nt:\n  command: x\n")

    def test_lint_only_document_is_not_a_study(self):
        with pytest.raises(WDLError, match="no tasks"):
            parse_yaml("lint:\n  suppress: [W601]\n")


class TestMergeConflicts:
    def test_conflicting_baseline_raises(self):
        a = parse_yaml("t:\n  command: x ${args:n}\n"
                       "  args:\n    n: [1, 2]\n"
                       "  baseline:\n    n: 1\n")
        b = parse_yaml("t:\n  baseline:\n    n: 2\n", validate=False)
        with pytest.raises(WDLError, match="baseline") as ei:
            merge(a, b)
        assert ei.value.task == "t" and ei.value.keyword == "baseline"

    def test_identical_baseline_merges(self):
        a = parse_yaml("t:\n  command: x ${args:n}\n"
                       "  args:\n    n: [1, 2]\n"
                       "  baseline:\n    n: 1\n")
        b = parse_yaml("t:\n  baseline:\n    n: 1\n", validate=False)
        assert merge(a, b).tasks["t"].baseline == {"n": 1}

    def test_suppress_lists_union(self):
        a = parse_yaml("lint:\n  suppress: [W601]\nt:\n  command: x\n")
        b = parse_yaml("lint:\n  suppress: [W303, W601]\n"
                       "t:\n  command: y\n")
        assert merge(a, b).lint["suppress"] == ["W601", "W303"]

    def test_conflicting_lint_scalar_raises(self):
        a = parse_yaml("lint:\n  slots: 4\nt:\n  command: x\n")
        b = parse_yaml("lint:\n  slots: 8\nt:\n  command: y\n")
        with pytest.raises(WDLError, match="lint.slots"):
            merge(a, b)


class TestWDLErrorContext:
    def test_parse_error_carries_task_keyword_file_line(self, tmp_path):
        bad = tmp_path / "bad.yaml"
        bad.write_text("t:\n  command: x\n"
                       "  capture:\n    m:\n      regex: '(unclosed'\n")
        with pytest.raises(WDLError) as ei:
            from repro.core import parse_file
            parse_file(bad)
        e = ei.value
        assert e.task == "t"
        assert e.keyword == "capture.m.regex"
        assert e.file == str(bad) and isinstance(e.line, int)
        assert str(e).startswith(f"{bad}:{e.line}: t.capture.m.regex:")

    def test_fixture_findings_are_located(self):
        rep = lint_cli.lint_file(FIXTURE)
        e101 = next(f for f in rep.errors if f.rule == "E101")
        assert e101.file == str(FIXTURE)
        assert e101.line == 18    # the prep command line
        assert e101.keyword_path == "prep.command"


class TestFixtureAndExamples:
    def test_broken_fixture_trips_every_seeded_rule(self):
        rep = lint_cli.lint_file(FIXTURE)
        assert _rules(rep) == {"E101", "E201", "E202", "E203", "E301",
                               "E403", "E502", "W601", "W701", "W802"}
        assert not rep.ok

    def test_shipped_examples_lint_clean(self):
        for f in sorted(EXAMPLES.glob("*.yaml")):
            rep = lint_cli.lint_file(f)
            assert rep.findings == [], \
                f"{f.name}: {[x.render() for x in rep.findings]}"


class TestCLI:
    def test_broken_file_exits_1_with_rule_ids(self, capsys):
        assert lint_cli.main([str(FIXTURE)]) == 1
        out = capsys.readouterr().out
        for rid in ("E101", "E201", "E202", "E301", "E403", "E502"):
            assert rid in out
        assert "[FAIL]" in out

    def test_clean_file_exits_0(self, capsys):
        example = EXAMPLES / "matmul_perf.yaml"
        assert lint_cli.main([str(example)]) == 0
        assert "[clean]" in capsys.readouterr().out

    def test_strict_promotes_warnings(self, tmp_path, capsys):
        warn_only = tmp_path / "warn.yaml"
        warn_only.write_text("t:\n  command: use\n"
                             "  infiles:\n    cfg: /no/such/file.cfg\n")
        assert lint_cli.main([str(warn_only)]) == 0
        assert lint_cli.main([str(warn_only), "--strict"]) == 1

    def test_unparseable_file_is_e001(self, tmp_path, capsys):
        bad = tmp_path / "bad.yaml"
        bad.write_text("t:\n  command: x\n  timeout: not-a-number\n")
        assert lint_cli.main([str(bad)]) == 1
        assert "E001" in capsys.readouterr().out

    def test_missing_file_is_e001(self, tmp_path, capsys):
        assert lint_cli.main([str(tmp_path / "nope.yaml")]) == 1
        assert "E001" in capsys.readouterr().out

    def test_json_format_is_machine_readable(self, capsys):
        assert lint_cli.main([str(FIXTURE), "--format", "json"]) == 1
        doc = json.loads(capsys.readouterr().out)
        assert doc["ok"] is False
        (rep,) = doc["files"].values()
        ids = {f["rule"] for f in rep["findings"]}
        assert {"E101", "E202", "W601"} <= ids
        # every finding is located
        assert all("severity" in f and "message" in f
                   for f in rep["findings"])


class TestStudyLint:
    def test_study_method_prices_from_provenance(self, tmp_path):
        wdl = tmp_path / "s.yaml"
        wdl.write_text("t:\n  command: 'true'\n"
                       "  environ:\n    N: [1, 2]\n  timeout: 60\n")
        study = load_study(wdl, root=tmp_path / ".papas")
        rep = study.lint()
        assert rep.ok
        assert _rules(rep) == {"I601"}


class TestPerformance:
    def test_lint_of_1e5_combo_study_is_index_math(self):
        # 50 x 50 x 40 = 100k combinations: lint never enumerates
        # instances, so this must cost the same as a 10-combo study
        text = ("t:\n"
                "  command: run ${args:a} ${args:b} ${args:c}\n"
                "  args:\n"
                "    a: ['1:1:50']\n"
                "    b: ['1:1:50']\n"
                "    c: ['1:1:40']\n"
                "  timeout: 60\n")
        spec = parse_yaml(text, validate=False)
        t0 = time.perf_counter()
        rep = lint(spec, slots=8)
        elapsed = time.perf_counter() - t0
        assert elapsed < 1.0
        assert "100000 instance(s)" in rep.findings[0].message
