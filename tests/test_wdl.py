"""WDL parser tests: formats, ranges, keywords, validation errors.

Property-based range coverage (requires ``hypothesis``) lives in
``test_wdl_props.py``.
"""
import pytest

from repro.core import (
    WDLError, merge, parse_dict, parse_ini, parse_json, parse_range,
    parse_yaml,
)


class TestRanges:
    def test_additive_default_step(self):
        assert parse_range("1:8") == [1, 2, 3, 4, 5, 6, 7, 8]

    def test_additive_step(self):
        assert parse_range("1:2:9") == [1, 3, 5, 7, 9]

    def test_multiplicative(self):
        assert parse_range("16:*2:16384") == [
            16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384]

    def test_float_range(self):
        vals = parse_range("0.5:0.25:1.5")
        assert vals == pytest.approx([0.5, 0.75, 1.0, 1.25, 1.5])

    def test_negative_step(self):
        assert parse_range("5:-2:1") == [5, 3, 1]

    def test_not_a_range(self):
        assert parse_range("hello") is None
        assert parse_range("a:b:c") is None

    def test_zero_step_raises(self):
        with pytest.raises(WDLError):
            parse_range("1:0:5")


class TestParsing:
    YAML = """
matmulOMP:
  name: scaling study
  environ:
    OMP_NUM_THREADS: ["1:8"]
  args:
    size: ["16:*2:16384"]
  command: matmul ${args:size} out.txt
"""

    def test_yaml_matches_paper_example(self):
        spec = parse_yaml(self.YAML)
        task = spec.tasks["matmulOMP"]
        params = task.parameters()
        assert len(params["environ:OMP_NUM_THREADS"]) == 8
        assert len(params["args:size"]) == 11
        # paper: "This study corresponds to 88 independent executions"
        from repro.core import from_task
        assert from_task(params, task.fixed).size() == 88

    def test_json_equivalent(self):
        spec = parse_json(
            '{"t": {"command": "run ${args:x}", "args": {"x": ["1:3"]}}}')
        assert spec.tasks["t"].parameters()["args:x"] == [1, 2, 3]

    def test_ini_flavor(self):
        spec = parse_ini("[t]\ncommand = run\nargs.x = 1, 2, 3\n")
        assert spec.tasks["t"].parameters()["args:x"] == [1, 2, 3]

    def test_comments_ignored(self):
        spec = parse_yaml("# comment\nt:\n  command: run  # trailing\n")
        assert spec.tasks["t"].command.startswith("run")

    def test_unknown_dependency_rejected(self):
        with pytest.raises(WDLError):
            parse_yaml("t:\n  command: x\n  after: [missing]\n")

    def test_fixed_mismatched_lengths_rejected(self):
        with pytest.raises(WDLError):
            parse_yaml("""
t:
  command: x
  args:
    a: [1, 2]
    b: [1, 2, 3]
  fixed: [[a, b]]
""")

    def test_value_type_inference(self):
        spec = parse_yaml("""
t:
  command: x
  args:
    i: ["7"]
    f: ["2.5"]
    b: ["true"]
    s: [hello]
""")
        p = spec.tasks["t"].parameters()
        assert p["args:i"] == [7]
        assert p["args:f"] == [2.5]
        assert p["args:b"] == [True]
        assert p["args:s"] == ["hello"]

    def test_merge_multiple_files(self):
        a = parse_yaml("t:\n  command: run ${args:x}\n  args:\n    x: [1]\n")
        b = parse_yaml("t:\n  args:\n    y: [2, 3]\n")
        spec = merge(a, b)
        p = spec.tasks["t"].parameters()
        assert set(p) == {"args:x", "args:y"}

    def test_two_level_entries(self):
        spec = parse_dict({"t": {"command": "x",
                                 "environ": {"A": [1, 2], "B": 3}}})
        p = spec.tasks["t"].parameters()
        assert p["environ:A"] == [1, 2]
        assert p["environ:B"] == [3]

    def test_reserved_keywords_parsed(self):
        spec = parse_yaml("""
t:
  command: x
  parallel: mesh-slice
  batch: grouped
  nnodes: 4
  ppnode: 2
  hosts: [a, b]
""")
        t = spec.tasks["t"]
        assert t.parallel == "mesh-slice"
        assert t.batch == "grouped"
        assert (t.nnodes, t.ppnode) == (4, 2)
        assert t.hosts == ["a", "b"]

    def test_timeout_and_allow_nonzero_keywords(self):
        spec = parse_yaml("""
t:
  command: x
  timeout: 2.5
  allow_nonzero: true
""")
        assert spec.tasks["t"].timeout == 2.5
        assert spec.tasks["t"].allow_nonzero is True
        # defaults: no timeout, nonzero exit is a failure
        spec2 = parse_yaml("t:\n  command: x\n")
        assert spec2.tasks["t"].timeout is None
        assert spec2.tasks["t"].allow_nonzero is False

    def test_nonpositive_timeout_rejected(self):
        with pytest.raises(WDLError):
            parse_yaml("t:\n  command: x\n  timeout: -1\n")
