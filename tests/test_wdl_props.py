"""Hypothesis property tests for the WDL range parser.

Skipped wholesale when ``hypothesis`` is not installed (dev-only
dependency); the example-based parser tests live in ``test_wdl.py``.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import parse_range  # noqa: E402


class TestRangeProps:
    @given(st.integers(-50, 50), st.integers(1, 7), st.integers(-50, 50))
    @settings(max_examples=100, deadline=None)
    def test_additive_matches_python_range(self, a, s, b):
        got = parse_range(f"{a}:{s}:{b}")
        assert got == list(range(a, b + 1, s))
