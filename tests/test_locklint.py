"""Engine lock-order auditor (``repro.core.locklint``).

Covers: acquisition-order edge recording (including non-LIFO release
and cross-thread traces), cycle detection with canonical dedup,
``assert_no_cycles``, the ``make_lock`` factory's creation-time env
gating, ``Condition`` compatibility (the gang-coordination path), the
E901/I601 bridge into the lint report formatter, and an end-to-end
smoke: the lane pool under ``PAPAS_LOCKLINT=1`` runs a study with an
instrumented lock and a cycle-free graph.
"""
import threading

import pytest

from repro.core import (
    InstrumentedLock, LaneWorkerPool, LockOrderAuditor, LockOrderError,
    Scheduler, TaskDAG, TaskNode, get_auditor, make_lock,
)
from repro.core.lint import findings_from_lock_report
from repro.core.locklint import enabled


def _locks(auditor, *names):
    return [InstrumentedLock(n, auditor) for n in names]


class TestAuditor:
    def test_nested_acquisition_records_an_edge(self):
        aud = LockOrderAuditor()
        a, b = _locks(aud, "a", "b")
        with a:
            with b:
                pass
        assert aud.locks == {"a", "b"}
        assert aud.edges == {("a", "b"): 1}
        assert aud.n_acquisitions == 2

    def test_disjoint_acquisitions_record_no_edge(self):
        aud = LockOrderAuditor()
        a, b = _locks(aud, "a", "b")
        with a:
            pass
        with b:
            pass
        assert aud.edges == {}

    def test_edge_counts_accumulate(self):
        aud = LockOrderAuditor()
        a, b = _locks(aud, "a", "b")
        for _ in range(3):
            with a, b:
                pass
        assert aud.edges[("a", "b")] == 3

    def test_non_lifo_release_keeps_stack_consistent(self):
        # hand-over-hand: acquire a, acquire b, release a, acquire c —
        # the c edge must come from b only, a is no longer held
        aud = LockOrderAuditor()
        a, b, c = _locks(aud, "a", "b", "c")
        a.acquire()
        b.acquire()
        a.release()
        c.acquire()
        c.release()
        b.release()
        assert ("a", "b") in aud.edges
        assert ("b", "c") in aud.edges
        assert ("a", "c") not in aud.edges

    def test_reacquire_same_name_is_not_a_self_edge(self):
        aud = LockOrderAuditor()
        (a,) = _locks(aud, "a")
        a2 = InstrumentedLock("a", aud)
        with a, a2:
            pass
        assert aud.edges == {}


class TestCycles:
    def _cycle_auditor(self):
        aud = LockOrderAuditor()
        a, b = _locks(aud, "a", "b")
        # opposite orders recorded by two (non-overlapping) threads —
        # exactly the latent deadlock the auditor exists to catch
        t1 = threading.Thread(target=lambda: [a.acquire(), b.acquire(),
                                              b.release(), a.release()])
        t1.start()
        t1.join()
        t2 = threading.Thread(target=lambda: [b.acquire(), a.acquire(),
                                              a.release(), b.release()])
        t2.start()
        t2.join()
        return aud

    def test_opposite_orders_are_a_cycle(self):
        aud = self._cycle_auditor()
        assert aud.cycles() == [["a", "b"]]

    def test_assert_no_cycles_raises(self):
        aud = self._cycle_auditor()
        with pytest.raises(LockOrderError, match="a -> b -> a"):
            aud.assert_no_cycles()

    def test_consistent_order_has_no_cycle(self):
        aud = LockOrderAuditor()
        a, b, c = _locks(aud, "a", "b", "c")
        with a, b, c:
            pass
        with a, c:
            pass
        assert aud.cycles() == []
        aud.assert_no_cycles()

    def test_cycle_reported_once_despite_repetition(self):
        aud = self._cycle_auditor()
        a, b = _locks(aud, "a", "b")
        with a, b:
            pass
        assert len(aud.cycles()) == 1

    def test_three_lock_cycle(self):
        aud = LockOrderAuditor()
        a, b, c = _locks(aud, "a", "b", "c")
        for first, second in ((a, b), (b, c), (c, a)):
            with first, second:
                pass
        assert aud.cycles() == [["a", "b", "c"]]

    def test_report_is_json_friendly(self):
        aud = self._cycle_auditor()
        rep = aud.report()
        assert rep["locks"] == ["a", "b"]
        assert rep["n_acquisitions"] == 4
        assert {"from": "a", "to": "b", "count": 1} in rep["edges"]
        assert rep["cycles"] == [["a", "b"]]

    def test_reset_clears_state(self):
        aud = self._cycle_auditor()
        aud.reset()
        assert aud.report() == {"locks": [], "n_acquisitions": 0,
                                "edges": [], "cycles": []}


class TestFactory:
    def test_disabled_returns_plain_lock(self, monkeypatch):
        monkeypatch.delenv("PAPAS_LOCKLINT", raising=False)
        assert not enabled()
        assert not isinstance(make_lock("x"), InstrumentedLock)

    def test_zero_means_disabled(self, monkeypatch):
        monkeypatch.setenv("PAPAS_LOCKLINT", "0")
        assert not enabled()
        assert not isinstance(make_lock("x"), InstrumentedLock)

    def test_enabled_returns_instrumented_lock(self, monkeypatch):
        monkeypatch.setenv("PAPAS_LOCKLINT", "1")
        lk = make_lock("factory.test")
        assert isinstance(lk, InstrumentedLock)
        assert lk.name == "factory.test"

    def test_instrumented_lock_duck_types(self, monkeypatch):
        monkeypatch.setenv("PAPAS_LOCKLINT", "1")
        lk = make_lock("duck")
        assert lk.acquire() is True
        assert lk.locked()
        lk.release()
        assert not lk.locked()
        assert lk.acquire(blocking=False) is True
        lk.release()

    def test_condition_over_instrumented_lock(self):
        # the gang path wraps the pool lock in a Condition: wait/notify
        # must work and the _is_owned try-acquire probe must stay
        # balanced in the auditor's per-thread stack
        aud = LockOrderAuditor()
        cv = threading.Condition(InstrumentedLock("pool", aud))
        ready = []

        def waiter():
            with cv:
                while not ready:
                    cv.wait(timeout=5)

        t = threading.Thread(target=waiter)
        t.start()
        with cv:
            ready.append(1)
            cv.notify()
        t.join(timeout=5)
        assert not t.is_alive()
        assert aud.cycles() == []


class TestLintBridge:
    def test_cycles_become_e901_errors(self):
        aud = LockOrderAuditor()
        a, b = _locks(aud, "a", "b")
        with a, b:
            pass
        with b, a:
            pass
        rep = findings_from_lock_report(aud.report())
        assert not rep.ok
        (f,) = rep.errors
        assert f.rule == "E901"
        assert "a -> b -> a" in f.message

    def test_clean_graph_is_an_info_summary(self):
        aud = LockOrderAuditor()
        a, b = _locks(aud, "a", "b")
        with a, b:
            pass
        rep = findings_from_lock_report(aud.report())
        assert rep.ok and len(rep.findings) == 1
        f = rep.findings[0]
        assert f.severity == "info"
        assert "2 lock(s)" in f.message and "no cycles" in f.message


class TestEngineSmoke:
    def test_lane_pool_under_locklint_is_cycle_free(self, monkeypatch):
        monkeypatch.setenv("PAPAS_LOCKLINT", "1")
        aud = get_auditor()
        aud.reset()
        dag = TaskDAG()
        for i in range(6):
            dag.add(TaskNode(id=f"t{i:03d}", task="t", combo={},
                             payload={"command": f"echo {i}"}))
        pool = LaneWorkerPool(
            2, render=lambda n: (n.payload["command"], {}))
        try:
            res = Scheduler(slots=2).execute(dag, None, pool=pool)
        finally:
            pool.shutdown()
        assert all(r.status == "ok" for r in res.values())
        assert "lane.pool" in aud.locks
        assert aud.n_acquisitions > 0
        aud.assert_no_cycles()
        assert findings_from_lock_report(aud.report()).ok
        aud.reset()

    def test_journal_lock_is_instrumented(self, monkeypatch, tmp_path):
        monkeypatch.setenv("PAPAS_LOCKLINT", "1")
        aud = get_auditor()
        aud.reset()
        from repro.core import StudyJournal
        j = StudyJournal(tmp_path / "journal.json")
        j.mark_complete("t000")
        assert "journal" in aud.locks
        aud.assert_no_cycles()
        aud.reset()
