#!/bin/sh
#SBATCH --job-name=papas-demo
#SBATCH --nodes=2
#SBATCH --ntasks-per-node=4
#SBATCH --output=/spool/job.out
#SBATCH --error=/spool/job.err

# 2 tasks inside one slurm allocation (2 nodes x 4 procs)
( ( export OMP_NUM_THREADS=1; matmul 16 result_16N_1T.txt ) > /spool/0.out 2> /spool/0.err; printf '%s' "$?" > /spool/0.rc.tmp && mv /spool/0.rc.tmp /spool/0.rc ) &
( ( export OMP_NUM_THREADS=2; matmul 32 result_32N_2T.txt ) > /spool/1.out 2> /spool/1.err; printf '%s' "$?" > /spool/1.rc.tmp && mv /spool/1.rc.tmp /spool/1.rc ) &
wait
