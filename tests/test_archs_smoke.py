"""Per-architecture smoke tests: reduced config, one train step on CPU,
shape + finiteness assertions; decode step where applicable."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALIASES, all_archs, get, get_smoke
from repro.models import Model, SHAPES, cell_applicable, synthetic_batch
from repro.models.config import ShapeConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=all_archs())
def arch(request):
    return request.param


class TestSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke(arch)
        m = Model(cfg)
        params = m.init(KEY)
        batch = synthetic_batch(cfg, batch=2, seq=32, key=KEY)
        logits, aux = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        for v in aux.values():
            assert bool(jnp.isfinite(v))

    def test_one_train_step(self, arch):
        cfg = get_smoke(arch)
        opt = AdamW(schedule=cosine_schedule(1e-3, 10, 100))
        state = init_train_state(cfg, opt, KEY)
        step = jax.jit(make_train_step(cfg, opt))
        batch = synthetic_batch(cfg, batch=2, seq=32, key=KEY)
        new_state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        assert int(new_state["step"]) == 1
        # parameters actually moved
        delta = jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)).max()),
            state["params"], new_state["params"])
        assert max(jax.tree.leaves(delta)) > 0

    def test_decode_step_if_applicable(self, arch):
        cfg = get_smoke(arch)
        if not cfg.has_decode():
            pytest.skip("encoder-only")
        m = Model(cfg)
        params = m.init(KEY)
        cache = m.init_cache(batch=2, max_len=16)
        tok = jnp.zeros((2, 1), jnp.int32)
        step = jax.jit(m.decode_step)
        logits, cache = step(params, cache, tok)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        assert int(cache["pos"]) == 1
        logits2, cache = step(params, cache, tok)
        assert int(cache["pos"]) == 2

    def test_prefill_decode_consistency(self, arch):
        """Greedy decode after teacher-forcing matches forward logits."""
        cfg = get_smoke(arch)
        if not cfg.has_decode() or cfg.input_mode != "tokens":
            pytest.skip("needs token-mode causal LM")
        m = Model(cfg)
        params = m.init(KEY)
        toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
        logits_all, _ = m.forward(params, {"tokens": toks})
        cache = m.init_cache(batch=1, max_len=16, dtype=jnp.float32)
        outs = []
        for t in range(8):
            lg, cache = m.decode_step(params, cache, toks[:, t:t + 1])
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        err = jnp.abs(dec - logits_all).max()
        assert float(err) < 0.1, f"decode/prefill mismatch {float(err)}"


class TestConfigsExact:
    """The full configs carry the exact published hyperparameters."""

    EXPECT = {
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=92553),
        "gemma-7b": dict(n_layers=28, d_model=3072, n_heads=16,
                         n_kv_heads=16, d_ff=24576, vocab_size=256000,
                         head_dim=256),
        "h2o-danube-1.8b": dict(n_layers=24, d_model=2560, n_heads=32,
                                n_kv_heads=8, d_ff=6912, vocab_size=32000),
        "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32,
                            n_kv_heads=32, d_ff=11008, vocab_size=102400),
        "gemma3-1b": dict(n_layers=26, d_model=1152, n_heads=4,
                          n_kv_heads=1, d_ff=6912, vocab_size=262144),
        "hubert-xlarge": dict(n_layers=48, d_model=1280, n_heads=16,
                              n_kv_heads=16, d_ff=5120, vocab_size=504),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                n_kv_heads=16, vocab_size=151936,
                                n_experts=60, top_k=4, moe_d_ff=1408),
        "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_heads=16,
                            n_kv_heads=16, vocab_size=50304,
                            n_experts=64, top_k=8, moe_d_ff=1024),
        "mamba2-780m": dict(n_layers=48, d_model=1536, vocab_size=50280,
                            ssm_state=128),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
    }

    @pytest.mark.parametrize("arch", sorted(ALIASES))
    def test_exact_numbers(self, arch):
        cfg = get(arch)
        for field, want in self.EXPECT[arch].items():
            assert getattr(cfg, field) == want, (arch, field)

    def test_gemma3_pattern_five_to_one(self):
        lt = get("gemma3-1b").layer_types
        assert len(lt) == 26
        assert lt[5] == "attn" and lt[11] == "attn"
        assert lt.count("attn") == 4 and lt.count("swa") == 22

    def test_hymba_three_global(self):
        lt = get("hymba-1.5b").layer_types
        assert [i for i, k in enumerate(lt) if k == "hyb_g"] == [0, 15, 31]

    def test_cell_applicability_matrix(self):
        rows = {a: {s: cell_applicable(get(a), SHAPES[s])[0]
                    for s in SHAPES} for a in all_archs()}
        # encoder-only: no decode cells
        assert not rows["hubert-xlarge"]["decode_32k"]
        assert not rows["hubert-xlarge"]["long_500k"]
        # long_500k only for sub-quadratic archs
        long_ok = {a for a in rows if rows[a]["long_500k"]}
        assert long_ok == {"h2o-danube-1.8b", "gemma3-1b", "mamba2-780m",
                           "hymba-1.5b"}
        # everything trains and prefills
        assert all(rows[a]["train_4k"] and rows[a]["prefill_32k"]
                   for a in rows)
        n_cells = sum(v for r in rows.values() for v in r.values())
        assert n_cells == 33


class TestVocabPadding:
    def test_padded_model_matches_unpadded_loss(self):
        cfg = get_smoke("deepseek-7b")
        cfgp = dataclasses.replace(cfg, vocab_pad=64)
        assert cfgp.padded_vocab % 64 == 0 and cfgp.padded_vocab >= cfg.vocab_size
        m = Model(cfgp)
        params = m.init(KEY)
        batch = synthetic_batch(cfgp, 2, 16, KEY)
        loss, _ = m.loss(params, batch)
        # pad columns masked → loss insensitive to pad weights
        params2 = jax.tree.map(lambda x: x, params)
        emb = params2["embed"]
        params2["embed"] = emb.at[cfg.vocab_size:].set(100.0)
        loss2, _ = m.loss(params2, batch)
        assert abs(float(loss) - float(loss2)) < 1e-5
