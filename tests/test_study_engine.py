"""Parameter-study engine integration: provenance, journal, gang exec."""
import json

import pytest

from repro.core import (
    GangExecutor, ParameterStudy, StudyJournal, parse_yaml, stackable_key,
)

SPEC = """
work:
  args:
    x: [1, 2, 3]
    y: [10, 20]
  command: echo ${args:x} ${args:y}
"""


def make_study(tmp_path, registry=None, name="s"):
    return ParameterStudy(parse_yaml(SPEC), registry=registry,
                          root=tmp_path, name=name)


class TestRun:
    def test_registry_execution(self, tmp_path):
        calls = []
        study = make_study(tmp_path,
                           {"work": lambda c: calls.append(dict(c)) or 0})
        res = study.run()
        assert len(calls) == 6
        assert all(r.status == "ok" for r in res.values())

    def test_provenance_records(self, tmp_path):
        study = make_study(tmp_path, {"work": lambda c: 0})
        study.run()
        recs = list(study.db.records())
        assert len(recs) == 6
        assert all(r["status"] == "ok" for r in recs)
        assert study.db.runtime_summary()["count"] == 6

    def test_journal_resume(self, tmp_path):
        boom = {"armed": True}

        def worker(combo):
            if boom["armed"] and combo["args:x"] == 3:
                raise RuntimeError("node died")
            return combo["args:x"]

        study = make_study(tmp_path, {"work": worker}, name="resume")
        res1 = study.run(max_retries=0)
        ok1 = {k for k, r in res1.items() if r.status == "ok"}
        assert len(ok1) == 4   # two x==3 instances failed

        # "restart the study" — a fresh engine object, same journal
        boom["armed"] = False
        study2 = make_study(tmp_path, {"work": worker}, name="resume")
        ran = []
        res2 = study2.run(resume=True,
                          runner=lambda n: ran.append(n.id) or 0)
        assert len(ran) == 2   # only the failed instances re-ran
        assert all(r.status == "ok" for r in res2.values())

    def test_shell_execution(self, tmp_path):
        spec = parse_yaml("""
sh:
  args:
    n: [1, 2]
  command: echo value-${args:n}
""")
        study = ParameterStudy(spec, root=tmp_path, name="sh")
        res = study.run()
        outs = sorted(r.value.stdout.strip() for r in res.values())
        assert outs == ["value-1", "value-2"]

    EXIT3 = 'python -c "import sys; sys.exit(3)"'

    def test_nonzero_exit_classified_by_scheduler(self, tmp_path):
        spec = parse_yaml(f"sh:\n  command: {self.EXIT3}\n")
        study = ParameterStudy(spec, root=tmp_path, name="rc")
        (r,) = study.run(max_retries=0).values()
        assert r.status == "failed"
        assert "nonzero exit 3" in r.error

    def test_allow_nonzero_keyword_accepts_exit_code(self, tmp_path):
        spec = parse_yaml(
            f"sh:\n  command: {self.EXIT3}\n  allow_nonzero: true\n")
        study = ParameterStudy(spec, root=tmp_path, name="rc2")
        (r,) = study.run(max_retries=0).values()
        assert r.status == "ok"
        assert r.value.returncode == 3

    def test_wdl_timeout_propagates_to_dispatch(self, tmp_path):
        spec = parse_yaml("sh:\n  command: sleep 5\n  timeout: 0.2\n")
        study = ParameterStudy(spec, root=tmp_path, name="tmo")
        (r,) = study.run(max_retries=0).values()
        assert r.status == "failed"
        assert "timeout" in r.error.lower()
        assert r.attempts == 1

    def test_environ_propagates_to_subprocess(self, tmp_path):
        spec = parse_yaml("""
sh:
  environ:
    PAPAS_TEST_VAR: [abc]
  command: printenv PAPAS_TEST_VAR
""")
        study = ParameterStudy(spec, root=tmp_path, name="env")
        res = study.run()
        (r,) = res.values()
        assert r.value.stdout.strip() == "abc"


class TestGang:
    def test_gang_batches_dispatches(self, tmp_path):
        study = make_study(tmp_path, name="gang")

        def gang_runner(nodes):
            return [n.combo["args:x"] * n.combo["args:y"] for n in nodes]

        gang = GangExecutor(stackable_key, gang_runner)
        res = study.run(gang=gang)
        assert len(res) == 6
        assert gang.stats.dispatches == 1          # one launch for all 6
        assert gang.stats.batching_factor == 6.0
        values = {r.value for r in res.values()}
        assert values == {10, 20, 30, 40, 60, 20 * 3}

    def test_gang_respects_max_group(self, tmp_path):
        study = make_study(tmp_path, name="gang2")
        gang = GangExecutor(stackable_key,
                            lambda nodes: [0] * len(nodes), max_group=4)
        study.run(gang=gang)
        assert gang.stats.dispatches == 2           # 4 + 2

    def test_gang_dag_levels(self, tmp_path):
        spec = parse_yaml("""
prep:
  args:
    x: [1, 2]
  command: echo prep
train:
  after: [prep]
  command: echo train
""")
        study = ParameterStudy(spec, root=tmp_path, name="gang3")
        order = []

        def gang_runner(nodes):
            order.append({n.task for n in nodes})
            return [0] * len(nodes)

        study.run(gang=GangExecutor(stackable_key, gang_runner))
        assert order == [{"prep"}, {"train"}]       # level-synchronous


class TestVisualization:
    def test_dot_output(self, tmp_path):
        study = make_study(tmp_path, name="viz")
        dot = study.visualize("dot")
        assert dot.startswith("digraph")
        assert dot.count("work@") >= 6

    def test_ascii_output(self, tmp_path):
        study = make_study(tmp_path, name="viz2")
        txt = study.visualize("ascii")
        assert "level 0:" in txt


class TestJournal:
    def test_atomic_save_load(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json")
        j.save([{"a": 1}], {"x"}, {"name": "n"})
        insts, completed, meta = j.load()
        assert insts == [{"a": 1}]
        assert completed == {"x"}
        assert meta["name"] == "n"

    def test_mark_complete(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json")
        j.save([], set(), {})
        j.mark_complete("t1")
        _, completed, _ = j.load()
        assert completed == {"t1"}
