"""Dispatch-throughput levers (the 10^4 tasks/s path).

Covers the four levers end to end at the unit/wiring level: adaptive
batch sizing (duration-driven chunk caps), spawn elimination
(``posix_spawn`` vs ``Popen`` parity), the ``straggler_quantile`` WDL
keyword / run parameter, and ``run(window="auto")`` adaptive streaming
admission.  Throughput itself is measured by
``benchmarks/engine_overhead.py``; these tests pin semantics.
"""
import subprocess

import pytest

from repro.core import (
    LaneWorkerPool, ParameterStudy, Scheduler, parse_yaml, run_subprocess,
)
from repro.core.executors import _HAS_POSIX_SPAWN
from repro.core.scheduler import AdaptiveWindow
from repro.core.wdl import WDLError

WDL = """
sweep:
  args:
    n: [1, 2, 3, 4, 5, 6]
  command: echo v-${args:n}
"""


# ---------------------------------------------------------------------------
# straggler_quantile: WDL keyword → scheduler wiring
# ---------------------------------------------------------------------------


class TestStragglerQuantile:
    def _spec(self, q):
        return parse_yaml(f"""
t:
  args:
    x: [1, 2]
  straggler_quantile: {q}
  command: echo ${{args:x}}
""")

    def test_wdl_pq_form(self):
        assert self._spec("p90").tasks["t"].straggler_quantile == 0.9

    def test_wdl_float_form(self):
        assert self._spec("0.75").tasks["t"].straggler_quantile == 0.75

    @pytest.mark.parametrize("bad", ["p200", "frog", "1.5", "0", "p0"])
    def test_wdl_invalid_rejected(self, bad):
        with pytest.raises(WDLError, match="straggler_quantile"):
            self._spec(bad)

    def test_scheduler_validates_range(self):
        with pytest.raises(ValueError, match="straggler_quantile"):
            Scheduler(straggler_quantile=1.5)
        assert Scheduler(straggler_quantile=0.9).straggler_quantile == 0.9

    def test_run_forwards_spec_keyword(self, tmp_path, monkeypatch):
        seen = {}
        orig = Scheduler.__init__

        def spy(self, *a, **kw):
            seen["q"] = kw.get("straggler_quantile")
            return orig(self, *a, **kw)

        monkeypatch.setattr(Scheduler, "__init__", spy)
        study = ParameterStudy(self._spec("p90"), root=tmp_path, name="sq")
        study.run(runner=lambda n: 0)
        assert seen["q"] == 0.9

    def test_run_param_overrides_spec(self, tmp_path, monkeypatch):
        seen = {}
        orig = Scheduler.__init__

        def spy(self, *a, **kw):
            seen["q"] = kw.get("straggler_quantile")
            return orig(self, *a, **kw)

        monkeypatch.setattr(Scheduler, "__init__", spy)
        study = ParameterStudy(self._spec("p90"), root=tmp_path, name="sq2")
        study.run(runner=lambda n: 0, straggler_quantile=0.5, window=2)
        assert seen["q"] == 0.5

    def test_conflicting_task_keywords_rejected(self, tmp_path):
        spec = parse_yaml("""
a:
  args:
    x: [1]
  straggler_quantile: p90
  command: echo a
b:
  args:
    x: [1]
  straggler_quantile: p50
  command: echo b
""")
        study = ParameterStudy(spec, root=tmp_path, name="conf")
        with pytest.raises(ValueError, match="straggler_quantile"):
            study.run(runner=lambda n: 0)


# ---------------------------------------------------------------------------
# window="auto": rate-driven streaming admission
# ---------------------------------------------------------------------------


class TestAdaptiveWindowUnit:
    def test_grows_with_fast_completions(self):
        w = AdaptiveWindow(slots=2, horizon=0.5)
        w.observe(0.0, 0)
        before = w.current
        w.observe(0.25, 500)    # 2000 tasks/s → target 1000
        assert w.current > before
        w.observe(0.5, 1000)
        assert w.current <= w.max

    def test_shrinks_for_slow_studies(self):
        w = AdaptiveWindow(slots=2, horizon=0.5)
        w.current = 512
        w.observe(0.0, 0)
        w.observe(1.0, 2)       # 2 tasks/s → target 1
        assert w.current < 512
        for i in range(2, 12):
            w.observe(float(i), 2 * i)
        assert w.current == w.min   # converges to the floor

    def test_clamped_to_bounds(self):
        w = AdaptiveWindow(slots=4, max_window=64)
        w.observe(0.0, 0)
        for i in range(1, 10):
            w.observe(i * 0.25, i * 100_000)
        assert w.current == 64
        assert w.min == 4


class TestWindowAutoRun:
    def test_auto_window_completes_and_reports_int(self, tmp_path):
        study = ParameterStudy(parse_yaml(WDL), root=tmp_path, name="wa")
        res = study.run(window="auto", runner=lambda n: 0)
        assert len(res) == 6
        assert all(r.status == "ok" for r in res.values())
        assert isinstance(study.last_run_stats["window"], int)

    def test_auto_window_resumes(self, tmp_path):
        class Stop(Exception):
            pass

        seen = []

        def tripwire(res):
            seen.append(res.id)
            if len(seen) == 3:
                raise Stop

        study = ParameterStudy(parse_yaml(WDL), root=tmp_path, name="war")
        with pytest.raises(Stop):
            study.run(window="auto", runner=lambda n: 0, on_result=tripwire)
        resumed = ParameterStudy(parse_yaml(WDL), root=tmp_path, name="war")
        resumed.run(window="auto", resume=True, runner=lambda n: 0)
        assert resumed.last_run_stats["skipped_complete"] == 3

    def test_bad_window_string_rejected(self, tmp_path):
        study = ParameterStudy(parse_yaml(WDL), root=tmp_path, name="wb")
        with pytest.raises(ValueError, match="window"):
            study.run(window="turbo", runner=lambda n: 0)


# ---------------------------------------------------------------------------
# spawn elimination: posix_spawn fast path vs subprocess.run
# ---------------------------------------------------------------------------

posix_only = pytest.mark.skipif(not _HAS_POSIX_SPAWN,
                                reason="posix_spawnp unavailable")


class TestSpawnPaths:
    @posix_only
    def test_paths_agree_on_stdout_stderr_rc(self):
        cmd = "echo out; echo err >&2; exit 4"
        a = run_subprocess(cmd, shell=True, spawn="posix")
        b = run_subprocess(cmd, shell=True, spawn="popen")
        assert (a.returncode, a.stdout, a.stderr) \
            == (b.returncode, b.stdout, b.stderr) == (4, "out\n", "err\n")

    @posix_only
    def test_posix_env_overlay(self):
        r = run_subprocess("echo $PAPAS_LEVER", shell=True, spawn="posix",
                           env={"PAPAS_LEVER": "d"})
        assert r.ok and r.stdout == "d\n"

    @posix_only
    def test_posix_timeout_matches_popen_contract(self):
        with pytest.raises(subprocess.TimeoutExpired):
            run_subprocess("sleep 30", shell=True, spawn="posix",
                           timeout=0.2)

    def test_missing_binary_raises_either_path(self):
        for spawn in (("posix",) if _HAS_POSIX_SPAWN else ()) + ("popen",):
            with pytest.raises(FileNotFoundError):
                run_subprocess("papas_no_such_binary_xyz", spawn=spawn)

    def test_cwd_falls_back_to_popen(self, tmp_path):
        # posix_spawn has no portable chdir file action: auto must fall
        # back, and forcing posix with cwd is an explicit error
        r = run_subprocess("pwd", shell=True, cwd=str(tmp_path))
        assert r.ok and r.stdout.strip() == str(tmp_path)
        with pytest.raises(RuntimeError, match="posix spawn"):
            run_subprocess("pwd", shell=True, cwd=str(tmp_path),
                           spawn="posix")

    @posix_only
    def test_large_capture_drains_both_pipes(self):
        # both pipes carry more than one pipe buffer: the select loop
        # must interleave reads, never deadlock on a full pipe
        n = 30_000
        r = run_subprocess(f"seq 1 {n}; seq 1 {n} >&2", shell=True,
                           spawn="posix")
        expected = "".join(f"{i}\n" for i in range(1, n + 1))
        assert r.ok and r.stdout == expected and r.stderr == expected


# ---------------------------------------------------------------------------
# adaptive batch sizing
# ---------------------------------------------------------------------------


def _payload_render(node):
    return node.payload.get("command"), node.payload.get("env") or {}


class TestAdaptiveBatch:
    def _fed(self, durations, **kw):
        pool = LaneWorkerPool(1, render=_payload_render, **kw)
        for d in durations:
            pool._observe(d)
        return pool

    def test_warmup_before_enough_samples(self):
        pool = LaneWorkerPool(1, render=_payload_render)
        try:
            assert pool._batch_now() == pool.WARMUP_BATCH
        finally:
            pool.shutdown()

    def test_cheap_tasks_grow_the_batch(self):
        pool = self._fed([0.001] * 16)
        try:
            # ~BATCH_LATENCY/median, clamped
            assert pool._batch_now() == min(pool.MAX_BATCH,
                                            int(pool.BATCH_LATENCY / 0.001))
        finally:
            pool.shutdown()

    def test_straggler_pressure_shrinks_the_batch(self):
        # p90 >> median: worst-case batch latency bounds the size
        pool = self._fed([0.001] * 20 + [1.0] * 4)
        try:
            assert pool._batch_now() == 1
        finally:
            pool.shutdown()

    def test_pinned_batch_ignores_observations(self):
        pool = self._fed([0.001] * 32, batch=4)
        try:
            assert pool._batch_now() == 4
        finally:
            pool.shutdown()

    def test_invalid_batch_rejected(self):
        for bad in (0, -1, "turbo", 2.5, True):
            with pytest.raises(ValueError, match="batch"):
                LaneWorkerPool(1, batch=bad)

    def test_auto_batch_end_to_end(self, tmp_path):
        study = ParameterStudy(parse_yaml("""
sweep:
  args:
    n: ["1:40"]
  command: echo v-${args:n}
"""), root=tmp_path, name="ab")
        res = study.run(pool="lane", slots=2)   # batch defaults to auto
        assert len(res) == 40
        assert all(r.status == "ok" for r in res.values())
