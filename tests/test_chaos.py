"""Chaos harness + retry-policy layer: deterministic fault injection
over the backend seams (repro.core.chaos), scheduler retry backoff and
failure-kind filtering, SSH host quarantine probation, corrupt-segment
resume tolerance, durability ordering (journal pre_flush -> DB flush),
the WDL ``retry:`` block, and the W701 lint rule."""
import subprocess
import time
from pathlib import Path

import pytest

from repro.core import (
    LocalSubmitter, LocalTransport, ParameterStudy, RetryPolicy, Scheduler,
    ShellResult, SSHWorkerPool, StudyDB, StudyJournal, TaskDAG, TaskNode,
    VirtualClock, VirtualPool, classify_failure, parse_yaml,
    record_fingerprint, truncate_tail,
)
from repro.core import chaos
from repro.core.chaos import ChaosController, FaultEvent, FaultPlan
from repro.core.groupcommit import iter_jsonl
from repro.core.remote import AllHostsQuarantinedError, TransportError


def make_dag(names, command=None):
    dag = TaskDAG()
    for name in names:
        dag.add(TaskNode(id=name, task=name, combo={},
                         payload={"command": command or f"run {name}"}))
    return dag


def render(node):
    return node.payload["command"], {}


def run(dag, pool, **kw):
    sched = Scheduler(slots=pool.slots, **kw)
    try:
        return sched.execute(dag, runner=None, pool=pool)
    finally:
        pool.shutdown()


SHELL_WDL = """
t:
  args:
    x: ["1:6"]
  command: echo ${args:x}
"""


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_from_dict_mapping_and_list(self):
        doc = {"name": "p", "seed": 3,
               "events": [{"kind": "kill_lane", "lane": 1, "after": 2}]}
        plan = FaultPlan.from_dict(doc)
        assert plan.name == "p" and plan.seed == 3
        assert plan.events[0].kind == "kill_lane"
        assert plan.events[0].lane == 1 and plan.events[0].after == 2
        # a bare list is shorthand for {"events": [...]}
        plan2 = FaultPlan.from_dict([{"kind": "sigkill", "after": 5}])
        assert plan2.events[0].kind == "sigkill"

    def test_unknown_kind_and_keys_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent("explode")
        with pytest.raises(ValueError, match="unknown key"):
            FaultPlan.from_dict({"events": [{"kind": "sigkill",
                                             "whoops": 1}]})
        with pytest.raises(ValueError, match="after must be"):
            FaultEvent("sigkill", after=-1)
        with pytest.raises(ValueError, match="times >= 1"):
            FaultEvent("sigkill", times=0)

    def test_load_yaml(self, tmp_path):
        p = tmp_path / "plan.yaml"
        p.write_text("seed: 9\nevents:\n  - kind: fail_host\n    host: h\n")
        plan = FaultPlan.load(p)
        assert plan.seed == 9 and plan.name == "plan"
        assert plan.events[0].host == "h"

    def test_generate_is_reproducible(self):
        a = FaultPlan.generate(42, lanes=3, hosts=["x", "y"])
        b = FaultPlan.generate(42, lanes=3, hosts=["x", "y"])
        assert a.to_dict() == b.to_dict()
        assert a.events, "generated plan must contain events"

    def test_to_dict_roundtrip(self):
        plan = FaultPlan([FaultEvent("hang_host", host="h", delay=0.5)],
                         seed=1, name="n")
        again = FaultPlan.from_dict(plan.to_dict())
        assert again.to_dict() == plan.to_dict()

    def test_shipped_plans_parse(self):
        chaos_dir = Path(__file__).parent.parent / "examples" / "chaos"
        plans = sorted(chaos_dir.glob("*.yaml"))
        assert len(plans) >= 3, "CI chaos gate needs >= 3 canned plans"
        for p in plans:
            plan = FaultPlan.load(p)
            assert plan.events, f"{p.name}: empty plan"


# ---------------------------------------------------------------------------
# arming / zero overhead when disabled
# ---------------------------------------------------------------------------

class TestArming:
    def test_disabled_by_default(self):
        assert chaos.current() is None

    def test_activated_restores_previous(self):
        c1 = FaultPlan([]).controller()
        c2 = FaultPlan([]).controller()
        with chaos.activated(c1):
            assert chaos.current() is c1
            with chaos.activated(c2):
                assert chaos.current() is c2
            assert chaos.current() is c1
        assert chaos.current() is None

    def test_env_arming_checked_lazily(self, tmp_path, monkeypatch):
        plan = tmp_path / "p.yaml"
        plan.write_text("events:\n  - kind: sigkill\n    after: 99\n")
        monkeypatch.setenv("PAPAS_CHAOS", str(plan))
        monkeypatch.setattr(chaos, "_controller", None)
        monkeypatch.setattr(chaos, "_env_checked", False)
        ctrl = chaos.current()
        assert ctrl is not None and ctrl.plan.name == "p"
        # the env is checked exactly once
        assert chaos.current() is ctrl

    def test_pools_capture_none_when_disarmed(self):
        from repro.core import make_pool
        pool = make_pool("lane", 1, render=render)
        try:
            assert pool._chaos is None
        finally:
            pool.shutdown()


# ---------------------------------------------------------------------------
# controller seam semantics (pure, no engine)
# ---------------------------------------------------------------------------

class TestControllerSeams:
    def test_lane_frame_trigger_and_budget(self):
        ctrl = FaultPlan([FaultEvent("kill_lane", lane=0, after=2,
                                     times=2)]).controller()
        # frames 1, 2 pass; 3 and 4 fire; 5 exhausted
        hits = [ctrl.lane_frame(0) for _ in range(5)]
        assert hits == [False, False, True, True, False]
        # a different lane never matches an addressed event
        assert not any(ctrl.lane_frame(1) for _ in range(5))
        led = ctrl.ledger.as_list()
        assert len(led) == 2 and all(e["fault"] == "kill_lane"
                                     for e in led)

    def test_unaddressed_event_matches_any_target(self):
        ctrl = FaultPlan([FaultEvent("fail_host")]).controller()
        assert ctrl.host_action("anything") == ("fail_host", 0.25)
        assert ctrl.host_action("anything") is None     # budget spent

    def test_host_action_kinds(self):
        ctrl = FaultPlan([
            FaultEvent("hang_host", host="h", delay=0.01),
            FaultEvent("fail_host", host="h"),
        ]).controller()
        assert ctrl.host_action("h") == ("hang_host", 0.01)
        assert ctrl.host_action("h") == ("fail_host", 0.25)
        assert ctrl.host_action("h") is None

    def test_job_action(self):
        ctrl = FaultPlan([FaultEvent("lose_job"),
                          FaultEvent("dup_job", after=1)]).controller()
        assert ctrl.job_action() == "lose_job"
        assert ctrl.job_action() == "dup_job"
        assert ctrl.job_action() is None


# ---------------------------------------------------------------------------
# retry policy + scheduler backoff
# ---------------------------------------------------------------------------

class TestRetryPolicy:
    def test_classify_failure(self):
        assert classify_failure("timeout after 5s") == "timeout"
        assert classify_failure("nonzero exit 2: boom") == "nonzero"
        assert classify_failure("host h failed: nope") == "host"
        assert classify_failure("no live hosts (all 2 quarantined)") == "host"
        assert classify_failure("lane worker died") == "host"
        assert classify_failure("ValueError: x") == "error"
        assert classify_failure(None) == "error"

    def test_from_any_validation(self):
        with pytest.raises(ValueError, match="unknown retry key"):
            RetryPolicy.from_any({"maxx": 3})
        with pytest.raises(ValueError, match="backoff"):
            RetryPolicy.from_any({"backoff": "cubic"})
        with pytest.raises(ValueError, match="max must be"):
            RetryPolicy.from_any({"max": -1})
        pol = RetryPolicy.from_any(
            {"max": 2, "backoff": "fixed", "base": 0.5,
             "retry_on": ["timeout", "HOST"]})
        assert pol.retries(99) == 2 and pol.backoff == "fixed"
        assert pol.retry_on == frozenset({"timeout", "host"})
        assert RetryPolicy.from_any(pol) is pol

    def test_delay_shapes(self):
        fixed = RetryPolicy(backoff="fixed", base=2.0)
        assert fixed.delay(1) == fixed.delay(3) == 2.0
        exp = RetryPolicy(base=1.0, max_delay=5.0)
        assert exp.delay(1) == 1.0 and exp.delay(2) == 2.0
        assert exp.delay(4) == 5.0          # capped
        jit = RetryPolicy(base=1.0, jitter=0.5)
        d1, d2 = jit.delay(1, key="n"), jit.delay(1, key="n")
        assert d1 == d2                     # deterministic per (key, k)
        assert 0.5 <= d1 <= 1.5

    def test_ceiling(self):
        pol = RetryPolicy.from_any({"max": 3, "base": 3000,
                                    "max_delay": 86400})
        assert pol.ceiling() == 12000.0     # 3000 * 2**2
        # the default max_delay caps the worst case
        assert RetryPolicy.from_any({"max": 3, "base": 3000}).ceiling() \
            == 30.0
        assert RetryPolicy.from_any({"max": 0}).ceiling() == 0.0

    def test_scheduler_backoff_delays_retry(self):
        clock = VirtualClock()
        attempts = {"n": 0}

        def flaky(node):
            attempts["n"] += 1
            if attempts["n"] == 1:
                raise RuntimeError("transient")
            return "ok"

        pool = VirtualPool({"t": 1.0}, clock, call_runner=True)
        sched = Scheduler(slots=1, clock=clock, max_retries=2,
                          retry_policy={"base": 10.0, "backoff": "fixed"})
        dag = TaskDAG()
        dag.add(TaskNode(id="t", task="t", combo={}, payload={}))
        results = sched.execute(dag, flaky, pool=pool)
        assert results["t"].status == "ok" and results["t"].attempts == 2
        # first attempt finished at t=1; retry waited out the 10s backoff
        assert clock.now >= 11.0

    def test_retry_on_filters_kinds(self):
        clock = VirtualClock()
        calls = {"n": 0}

        def always_raises(node):
            calls["n"] += 1
            raise RuntimeError("boom")      # kind "error"

        pool = VirtualPool({"t": 1.0}, clock, call_runner=True)
        sched = Scheduler(slots=1, clock=clock, max_retries=3,
                          retry_policy={"base": 0.0,
                                        "retry_on": ["timeout"]})
        dag = TaskDAG()
        dag.add(TaskNode(id="t", task="t", combo={}, payload={}))
        results = sched.execute(dag, always_raises, pool=pool)
        assert results["t"].status == "failed"
        assert calls["n"] == 1              # not a retryable kind

    def test_per_node_policy_overrides_default(self):
        clock = VirtualClock()
        calls = {"n": 0}

        def always_raises(node):
            calls["n"] += 1
            raise RuntimeError("boom")

        pool = VirtualPool(lambda nid, k: 1.0, clock, call_runner=True)
        sched = Scheduler(slots=1, clock=clock, max_retries=5,
                          retry_policy={"base": 0.0})
        dag = TaskDAG()
        dag.add(TaskNode(id="t", task="t", combo={},
                         payload={"retry": {"max": 1, "base": 0.0}}))
        results = sched.execute(dag, always_raises, pool=pool)
        assert results["t"].status == "failed"
        assert calls["n"] == 2              # 1 attempt + max 1 retry


# ---------------------------------------------------------------------------
# lane-kill fault through the engine
# ---------------------------------------------------------------------------

class TestLaneKill:
    def test_killed_lane_task_retried_to_success(self, tmp_path):
        clean = ParameterStudy(parse_yaml(SHELL_WDL), root=tmp_path,
                               name="clean")
        clean.run(pool="lane", slots=2)
        fp_clean = record_fingerprint(clean.db.records())

        plan = FaultPlan([FaultEvent("kill_lane", lane=0, after=1)])
        faulty = ParameterStudy(parse_yaml(SHELL_WDL), root=tmp_path,
                                name="faulty")
        ctrl = plan.controller()
        results = faulty.run(pool="lane", slots=2, chaos=ctrl,
                             max_retries=3, retry={"base": 0.01})
        assert all(r.status == "ok" for r in results.values())
        assert len(ctrl.ledger) == 1
        assert record_fingerprint(faulty.db.records()) == fp_clean
        meta = faulty.db.read_meta()
        assert meta.get("degraded") is True
        assert meta["fault_ledger"][0]["fault"] == "kill_lane"


# ---------------------------------------------------------------------------
# host quarantine probation
# ---------------------------------------------------------------------------

class TestProbation:
    def test_flaky_host_recovers_through_probation(self):
        plan = FaultPlan([FaultEvent("fail_host", host="flaky", times=2)])

        def hook(host, command):
            time.sleep(0.08 if host == "ok" else 0.005)
            return ShellResult(0, host, "", 0)

        pool = SSHWorkerPool(["flaky", "ok"], ppnode=1,
                             transport=LocalTransport(hook=hook),
                             render=render, probation=0.05)
        with chaos.activated(plan.controller()):
            results = run(make_dag([f"t{i}" for i in range(6)]), pool,
                          max_retries=3)
        assert all(r.status == "ok" for r in results.values())
        assert "flaky" not in pool.dead_hosts
        assert "flaky" in {r.host for r in results.values()}

    def test_persistent_failure_exhausts_probation(self):
        def hook(host, command):
            time.sleep(0.05)
            return ShellResult(0, host, "", 0)

        pool = SSHWorkerPool(["bad", "good"], ppnode=1,
                             transport=LocalTransport(
                                 fail_hosts=["bad"], hook=hook),
                             render=render, probation=0.02, max_probes=2)
        results = run(make_dag([f"t{i}" for i in range(6)]), pool,
                      max_retries=3)
        assert all(r.status == "ok" for r in results.values())
        assert pool.dead_hosts == {"bad"}
        assert "unreachable" in pool.host_causes["bad"]

    def test_all_hosts_quarantined_is_structured(self):
        pool = SSHWorkerPool(["a", "b"], ppnode=1,
                             transport=LocalTransport(fail_hosts=["a", "b"]),
                             render=render, probation=0.01, max_probes=1)
        results = run(make_dag(["t1", "t2", "t3"]), pool, max_retries=1)
        assert all(r.status in ("failed", "skipped")
                   for r in results.values())
        exc = pool.all_quarantined
        assert isinstance(exc, AllHostsQuarantinedError)
        assert isinstance(exc, TransportError)
        assert set(exc.causes) == {"a", "b"}
        msg = str(exc)
        assert msg.startswith("no live hosts (all 2 quarantined)")
        assert "a:" in msg and "unreachable" in msg

    def test_probation_zero_is_legacy_immediate_death(self):
        pool = SSHWorkerPool(["bad", "good"], ppnode=1,
                             transport=LocalTransport(fail_hosts=["bad"]),
                             render=render, probation=0.0)
        results = run(make_dag(["t1", "t2", "t3", "t4"], command="true"),
                      pool, max_retries=2)
        assert all(r.status == "ok" for r in results.values())
        assert pool.dead_hosts == {"bad"}


# ---------------------------------------------------------------------------
# batch-queue faults
# ---------------------------------------------------------------------------

class TestBatchJobFaults:
    def test_lose_job_never_spawns(self, tmp_path):
        marker = tmp_path / "ran"
        script = tmp_path / "job.sh"
        script.write_text(f"touch {marker}\n")
        sub = LocalSubmitter()
        plan = FaultPlan([FaultEvent("lose_job")])
        with chaos.activated(plan.controller()):
            jid = sub.submit(script)
        assert jid.endswith(".lost") and not sub._procs
        time.sleep(0.2)
        assert not marker.exists(), "a lost job must never run"
        # the next submission is healthy (budget spent)
        with chaos.activated(plan.controller()) as ctrl:
            ctrl.job_action()               # burn the single firing
            jid2 = sub.submit(script)
        assert not jid2.endswith(".lost")
        sub._procs[jid2].wait(5)
        assert marker.exists()

    def test_dup_job_spawns_twice(self, tmp_path):
        out = tmp_path / "count"
        script = tmp_path / "job.sh"
        script.write_text(f"echo x >> {out}\n")
        sub = LocalSubmitter()
        plan = FaultPlan([FaultEvent("dup_job")])
        with chaos.activated(plan.controller()):
            jid = sub.submit(script)
        sub._procs[jid].wait(5)
        for p in sub._dups:
            p.wait(5)
        assert len(sub._dups) == 1
        assert out.read_text().count("x") == 2


# ---------------------------------------------------------------------------
# torn segments: tolerant resume everywhere
# ---------------------------------------------------------------------------

class TestCorruptTail:
    def test_truncate_tail_tears_last_line(self, tmp_path):
        p = tmp_path / "seg"
        p.write_text('{"a": 1}\n{"b": 22}\n')
        assert truncate_tail(p)
        text = p.read_text()
        assert text.startswith('{"a": 1}\n{"b"')
        assert not text.endswith("\n")
        assert not truncate_tail(tmp_path / "empty_missing") \
            if (tmp_path / "empty_missing").exists() else True

    def test_iter_jsonl_warns_and_drops(self, tmp_path):
        p = tmp_path / "seg"
        p.write_text('{"a": 1}\n\n{"b": 2\n{"c": 3}\n')
        with pytest.warns(RuntimeWarning, match="dropping corrupt"):
            rows = list(iter_jsonl(p, "test"))
        assert rows == [{"a": 1}, {"c": 3}]

    def test_journal_resume_survives_torn_tail(self, tmp_path):
        j = StudyJournal(tmp_path / "journal.json")
        j.save([{"x": i} for i in range(3)], set(), {"name": "s"})
        for nid in ("a", "b", "c"):
            j.mark_complete(nid)
        truncate_tail(j.log_path)
        j2 = StudyJournal(tmp_path / "journal.json")
        with pytest.warns(RuntimeWarning, match="journal"):
            state = j2.load_state()
        # the torn final entry is dropped; everything before survives
        assert state.completed == {"a", "b"}

    def test_db_records_survive_torn_tail(self, tmp_path):
        db = StudyDB(tmp_path, "s")
        for i in range(3):
            db.record(f"t{i}", "ok", 0.0, combo={"i": i})
        db.close()
        truncate_tail(db.records_path)
        db2 = StudyDB(tmp_path, "s")
        with pytest.warns(RuntimeWarning, match="provenance"):
            recs = list(db2.records())
        assert [r["task_id"] for r in recs] == ["t0", "t1"]

    def test_apply_file_faults_is_deterministic(self, tmp_path):
        for k in range(3):
            (tmp_path / f"seg.s{k}").write_text('{"n": 1}\n{"n": 2}\n')
        plan = FaultPlan([FaultEvent("truncate_segment", glob="seg.s*")],
                         seed=5)
        torn1 = plan.controller().apply_file_faults(tmp_path)
        assert len(torn1) == 1
        # same plan, same tree shape -> same pick
        for k in range(3):
            (tmp_path / f"seg.s{k}").write_text('{"n": 1}\n{"n": 2}\n')
        torn2 = plan.controller().apply_file_faults(tmp_path)
        assert [p.name for p in torn1] == [p.name for p in torn2]


# ---------------------------------------------------------------------------
# durability ordering: journal flush forces DB flush first
# ---------------------------------------------------------------------------

class TestPreFlush:
    def test_journal_flush_drags_db_records_to_disk(self, tmp_path):
        db = StudyDB(tmp_path, "s", flush_count=100)     # buffered
        journal = StudyJournal(tmp_path / "s" / "journal.json",
                               flush_count=1)
        journal.set_pre_flush(db.flush)
        db.record("t@1", "ok", 0.0, combo={"x": 1})
        assert db._writer.n_flushes == 0                 # still buffered
        journal.save([], set(), {"name": "s"})
        journal.mark_complete("t@1")                     # flushes journal
        assert db._writer.n_flushes >= 1, \
            "journal flush must force the record flush first"
        assert any(r["task_id"] == "t@1" for r in
                   iter_jsonl(db.records_path, "t"))
        journal.set_pre_flush(None)
        db.record("t@2", "ok", 0.0, combo={"x": 2})
        n = db._writer.n_flushes
        journal.mark_complete("t@2")
        assert db._writer.n_flushes == n                 # hook cleared

    def test_pre_flush_survives_resharding(self, tmp_path):
        fired = []
        db = StudyDB(tmp_path, "s2", flush_count=100)
        journal = StudyJournal(tmp_path / "s2" / "journal.json",
                               flush_count=1)
        journal.set_pre_flush(lambda: fired.append(1))
        journal.set_shards(3)
        journal.save([], set(), {"name": "s2"})
        for i in range(3):
            journal.mark_complete(f"t@{i}")
        assert len(fired) >= 3, "new shard writers must inherit the hook"


# ---------------------------------------------------------------------------
# WDL retry block + merge + lint
# ---------------------------------------------------------------------------

class TestWDLRetry:
    def test_parse_retry_block(self):
        spec = parse_yaml("""
t:
  command: echo hi
  retry:
    max: 4
    backoff: fixed
    base: 0.5
    jitter: 0.1
    retry_on: [timeout, host]
""")
        assert spec.tasks["t"].retry == {
            "max": 4, "backoff": "fixed", "base": 0.5, "jitter": 0.1,
            "retry_on": ["timeout", "host"]}

    def test_retry_validation_errors(self):
        from repro.core import WDLError
        with pytest.raises(WDLError, match="backoff"):
            parse_yaml("t:\n  command: c\n  retry:\n    backoff: cubic\n")
        with pytest.raises(WDLError, match="retry"):
            parse_yaml("t:\n  command: c\n  retry:\n    nope: 1\n")
        with pytest.raises(WDLError, match="retry_on"):
            parse_yaml("t:\n  command: c\n  retry:\n"
                       "    retry_on: [explosions]\n")
        with pytest.raises(WDLError, match="max"):
            parse_yaml("t:\n  command: c\n  retry:\n    max: -2\n")

    def test_merge_conflicting_retry_rejected(self):
        from repro.core import WDLError, merge
        a = parse_yaml("t:\n  command: c\n  retry:\n    max: 1\n")
        b = parse_yaml("t:\n  command: c\n  retry:\n    max: 2\n")
        with pytest.raises(WDLError, match="retry"):
            merge(a, b)
        # identical blocks merge fine
        c = parse_yaml("t:\n  command: c\n  retry:\n    max: 1\n")
        assert merge(a, c).tasks["t"].retry == {"max": 1}

    def test_retry_reaches_scheduler_payload(self, tmp_path):
        study = ParameterStudy(
            parse_yaml("t:\n  command: echo hi\n  retry:\n    max: 2\n"),
            root=tmp_path, name="s")
        nodes = study._instance_nodes({})
        assert nodes[0].payload["retry"] == {"max": 2}


class TestLintW701:
    def _lint(self, wdl):
        from repro.core.lint import lint
        return lint(parse_yaml(wdl, validate=False))

    def test_backoff_ceiling_over_timeout_flagged(self):
        rep = self._lint("""
t:
  command: echo hi
  timeout: 3600
  retry:
    max: 3
    base: 3000
    max_delay: 86400
""")
        w = [f for f in rep.findings if f.rule == "W701"]
        assert len(w) == 1 and w[0].severity == "warn"
        assert w[0].task == "t" and w[0].keyword == "retry"

    def test_sane_policy_not_flagged(self):
        rep = self._lint("""
t:
  command: echo hi
  timeout: 3600
  retry:
    max: 3
    base: 1
""")
        assert not [f for f in rep.findings if f.rule == "W701"]

    def test_no_timeout_no_finding(self):
        rep = self._lint("t:\n  command: c\n  retry:\n    base: 9999\n")
        assert not [f for f in rep.findings if f.rule == "W701"]


# ---------------------------------------------------------------------------
# fingerprints + degraded report banner
# ---------------------------------------------------------------------------

class TestFingerprint:
    def test_latest_ok_wins_and_volatile_fields_ignored(self):
        a = [{"task_id": "t@1", "status": "failed", "combo": {"x": 1},
              "runtime": 9.0, "timestamp": 1},
             {"task_id": "t@1", "status": "ok", "combo": {"x": 1},
              "runtime": 1.0, "timestamp": 2},
             {"task_id": "t@2", "status": "ok", "combo": {"x": 2},
              "host": "lane0", "timestamp": 3}]
        b = [{"task_id": "t@2", "status": "ok", "combo": {"x": 2},
              "host": "lane1", "timestamp": 9},
             {"task_id": "t@1", "status": "ok", "combo": {"x": 1},
              "runtime": 55.0, "timestamp": 11}]
        assert record_fingerprint(a) == record_fingerprint(b)
        assert len(record_fingerprint(a)) == 2


class TestDegradedBanner:
    def test_banner_renders_causes_and_ledger(self, tmp_path):
        import json
        from repro.launch.report import degraded_banner
        d = tmp_path / "study"
        d.mkdir()
        (d / "study.json").write_text(json.dumps({
            "degraded": True, "lost_hosts": ["bad"],
            "host_causes": {"bad": "host bad unreachable"},
            "fault_ledger": [{"n": 1, "fault": "fail_host",
                              "target": "bad", "at": 1}]}))
        banner = degraded_banner(d)
        assert banner and "DEGRADED" in banner
        assert "bad" in banner and "fail_host" in banner

    def test_no_banner_when_healthy(self, tmp_path):
        import json
        d = tmp_path / "study"
        d.mkdir()
        (d / "study.json").write_text(json.dumps({"name": "s"}))
        from repro.launch.report import degraded_banner
        assert degraded_banner(d) is None
        assert degraded_banner(tmp_path / "nope") is None
