"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.ssd_scan import ssd_scan as ssd_kernel
from repro.kernels.moe_gmm import grouped_matmul as gmm_kernel

KEY = jax.random.PRNGKey(7)


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize("s,hq,hkv,d", [
        (128, 4, 4, 32),     # MHA
        (128, 4, 2, 32),     # GQA
        (256, 8, 1, 64),     # MQA
        (128, 2, 2, 128),    # big head_dim
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, s, hq, hkv, d, dtype):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (2, s, hq, d), dtype)
        k = rand(ks[1], (2, s, hkv, d), dtype)
        v = rand(ks[2], (2, s, hkv, d), dtype)
        out = fa_kernel(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=TOLS[dtype], rtol=TOLS[dtype])

    @pytest.mark.parametrize("window", [32, 64, 128])
    def test_sliding_window(self, window):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (1, 256, 4, 32), jnp.float32)
        k = rand(ks[1], (1, 256, 2, 32), jnp.float32)
        v = rand(ks[2], (1, 256, 2, 32), jnp.float32)
        out = fa_kernel(q, k, v, causal=True, window=window,
                        block_q=64, block_k=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_bidirectional(self):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (2, 128, 4, 32), jnp.float32)
        k = rand(ks[1], (2, 128, 4, 32), jnp.float32)
        v = rand(ks[2], (2, 128, 4, 32), jnp.float32)
        out = fa_kernel(q, k, v, causal=False, block_q=64, block_k=64,
                        interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_ops_wrapper_pads_ragged_seq(self):
        ks = jax.random.split(KEY, 3)
        q = rand(ks[0], (1, 100, 2, 32), jnp.float32)
        k = rand(ks[1], (1, 100, 2, 32), jnp.float32)
        v = rand(ks[2], (1, 100, 2, 32), jnp.float32)
        for causal in (True, False):
            out = ops.flash_attention(q, k, v, causal=causal,
                                      block_q=32, block_k=32)
            want = ref.flash_attention_ref(q, k, v, causal=causal)
            np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                       atol=2e-5, rtol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("s,h,p,g,n,chunk", [
        (64, 2, 16, 1, 16, 16),
        (128, 4, 32, 2, 16, 32),
        (128, 4, 32, 4, 8, 64),
    ])
    def test_sweep_vs_sequential(self, s, h, p, g, n, chunk):
        ks = jax.random.split(KEY, 4)
        x = rand(ks[0], (2, s, h, p), jnp.float32, 0.5)
        log_a = -jax.nn.softplus(
            jax.random.normal(ks[1], (2, s, h))) * 0.3
        b = rand(ks[2], (2, s, g, n), jnp.float32, 0.3)
        c = rand(ks[3], (2, s, g, n), jnp.float32, 0.3)
        y, hf = ssd_kernel(x, log_a, b, c, chunk=chunk, interpret=True)
        y_ref, h_ref = ref.ssd_scan_ref(x, log_a, b, c)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref),
                                   atol=1e-4, rtol=1e-4)

    def test_initial_state(self):
        ks = jax.random.split(KEY, 5)
        x = rand(ks[0], (1, 64, 2, 16), jnp.float32, 0.5)
        log_a = -jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 2))) * 0.3
        b = rand(ks[2], (1, 64, 1, 16), jnp.float32, 0.3)
        c = rand(ks[3], (1, 64, 1, 16), jnp.float32, 0.3)
        h0 = rand(ks[4], (1, 2, 16, 16), jnp.float32, 0.2)
        y, hf = ssd_kernel(x, log_a, b, c, chunk=16, initial_state=h0,
                           interpret=True)
        y_ref, h_ref = ref.ssd_scan_ref(x, log_a, b, c, initial_state=h0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref),
                                   atol=1e-4, rtol=1e-4)

    def test_bf16_inputs(self):
        ks = jax.random.split(KEY, 4)
        x = rand(ks[0], (1, 64, 2, 16), jnp.bfloat16, 0.5)
        log_a = (-jax.nn.softplus(
            jax.random.normal(ks[1], (1, 64, 2))) * 0.3)
        b = rand(ks[2], (1, 64, 1, 16), jnp.bfloat16, 0.3)
        c = rand(ks[3], (1, 64, 1, 16), jnp.bfloat16, 0.3)
        y, _ = ssd_kernel(x, log_a, b, c, chunk=16, interpret=True)
        y_ref, _ = ref.ssd_scan_ref(x, log_a, b, c)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            atol=5e-2, rtol=5e-2)


class TestGroupedMatmul:
    @pytest.mark.parametrize("t,d,e,f,br,bc", [
        (64, 32, 4, 64, 16, 16),
        (128, 64, 8, 128, 32, 64),
        (96, 64, 5, 96, 16, 32),
    ])
    def test_sweep(self, t, d, e, f, br, bc):
        ks = jax.random.split(KEY, 3)
        x = rand(ks[0], (t, d), jnp.float32)
        w = rand(ks[1], (e, d, f), jnp.float32, 0.1)
        # random group sizes summing to t
        cuts = np.sort(np.random.RandomState(0).randint(0, t, e - 1))
        gs = jnp.asarray(np.diff(np.concatenate([[0], cuts, [t]])),
                         jnp.int32)
        out = gmm_kernel(x, w, gs, block_rows=br, block_cols=bc,
                         interpret=True)
        want = ref.grouped_matmul_ref(x, w, gs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_empty_groups(self):
        ks = jax.random.split(KEY, 2)
        x = rand(ks[0], (32, 16), jnp.float32)
        w = rand(ks[1], (4, 16, 32), jnp.float32, 0.1)
        gs = jnp.array([0, 32, 0, 0], jnp.int32)
        out = gmm_kernel(x, w, gs, block_rows=8, block_cols=16,
                         interpret=True)
        want = ref.grouped_matmul_ref(x, w, gs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(KEY, 2)
        x = rand(ks[0], (64, 32), jnp.bfloat16)
        w = rand(ks[1], (4, 32, 32), jnp.bfloat16, 0.1)
        gs = jnp.array([16, 16, 16, 16], jnp.int32)
        out = gmm_kernel(x, w, gs, block_rows=16, block_cols=16,
                         interpret=True)
        want = ref.grouped_matmul_ref(x, w, gs)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            atol=2e-2, rtol=2e-2)


class TestMoEDispatchEquivalence:
    def test_einsum_vs_ragged_moe(self):
        """The two dispatch strategies agree when nothing is dropped."""
        import dataclasses
        from repro.configs import get_smoke
        from repro.models import Model, synthetic_batch
        cfg_e = dataclasses.replace(get_smoke("olmoe-1b-7b"),
                                    capacity_factor=8.0)  # no drops
        cfg_r = dataclasses.replace(cfg_e, moe_dispatch="ragged")
        m_e, m_r = Model(cfg_e), Model(cfg_r)
        params = m_e.init(KEY)
        batch = synthetic_batch(cfg_e, 2, 32, KEY)
        le, _ = jax.jit(lambda p, b: m_e.loss(p, b))(params, batch)
        lr_, _ = jax.jit(lambda p, b: m_r.loss(p, b))(params, batch)
        assert abs(float(le) - float(lr_)) < 5e-3
