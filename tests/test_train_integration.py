"""End-to-end training integration: loss decreases; gang == serial."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.pipeline import SyntheticStream
from repro.optim.adamw import (
    AdamW, compress_int8, cosine_schedule, decompress_int8, global_norm,
    linear_schedule,
)
from repro.train.step import TrainStepConfig, init_train_state, make_train_step

KEY = jax.random.PRNGKey(11)


class TestTrainingLoop:
    def test_loss_decreases_on_learnable_data(self):
        """Tiny LM on a fixed repeating batch must overfit."""
        cfg = get_smoke("deepseek-7b")
        opt = AdamW(schedule=cosine_schedule(3e-3, 5, 60),
                    weight_decay=0.0)
        state = init_train_state(cfg, opt, KEY)
        step = jax.jit(make_train_step(cfg, opt))
        toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        first = last = None
        for i in range(60):
            state, m = step(state, batch)
            if i == 0:
                first = float(m["loss"])
            last = float(m["loss"])
        assert last < first * 0.7, (first, last)

    def test_microbatching_matches_full_batch_grads(self):
        """n_micro=2 must give (numerically) the same step as n_micro=1."""
        cfg = get_smoke("gemma-7b")
        opt = AdamW(schedule=cosine_schedule(1e-3, 2, 10), clip_norm=0.0)
        state1 = init_train_state(cfg, opt, KEY)
        state2 = jax.tree.map(lambda x: x, state1)
        stream = SyntheticStream(cfg, global_batch=4, seq_len=16, seed=0)
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
        s1, m1 = jax.jit(make_train_step(cfg, opt))(state1, batch)
        s2, m2 = jax.jit(make_train_step(
            cfg, opt, TrainStepConfig(n_micro=2)))(state2, batch)
        # bf16 compute reassociates across the micro split: ~1% slack
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-2)
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(s2["params"])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=2e-2)

    def test_compressed_grads_still_train(self):
        cfg = get_smoke("deepseek-7b")
        opt = AdamW(schedule=cosine_schedule(3e-3, 5, 40), weight_decay=0.0)
        state = init_train_state(cfg, opt, KEY)
        step = jax.jit(make_train_step(
            cfg, opt, TrainStepConfig(compress_grads=True)))
        toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        first = last = None
        for i in range(40):
            state, m = step(state, batch)
            first = first or float(m["loss"])
            last = float(m["loss"])
        assert last < first * 0.85


class TestOptim:
    def test_schedules(self):
        cos = cosine_schedule(1.0, 10, 100)
        assert float(cos(jnp.asarray(0))) == 0.0
        assert float(cos(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(cos(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)
        lin = linear_schedule(1.0, 10, 110)
        assert float(lin(jnp.asarray(60))) == pytest.approx(0.5)

    def test_clipping_bounds_update(self):
        opt = AdamW(schedule=lambda c: 1e-2, clip_norm=1.0)
        params = {"w": jnp.ones((8, 8))}
        state = opt.init(params)
        grads = {"w": jnp.full((8, 8), 1e6)}
        _, _, metrics = opt.update(grads, state, params)
        assert float(metrics["grad_norm"]) > 1e6

    def test_int8_roundtrip_error_bounded(self):
        tree = {"a": jax.random.normal(KEY, (64, 64))}
        rt = decompress_int8(compress_int8(tree))
        err = jnp.abs(rt["a"] - tree["a"]).max()
        amax = jnp.abs(tree["a"]).max()
        assert float(err) <= float(amax) / 127.0 + 1e-6

    def test_global_norm(self):
        tree = {"a": jnp.ones((3,)), "b": jnp.ones((4,))}
        assert float(global_norm(tree)) == pytest.approx(7 ** 0.5)


class TestDataPipeline:
    def test_deterministic_and_stateless(self):
        cfg = get_smoke("deepseek-7b")
        s1 = SyntheticStream(cfg, global_batch=4, seq_len=8, seed=5)
        s2 = SyntheticStream(cfg, global_batch=4, seq_len=8, seed=5,
                             start_step=2)
        np.testing.assert_array_equal(s1.batch_at(2)["tokens"],
                                      s2.batch_at(2)["tokens"])

    def test_host_sharding_partitions_batch(self):
        cfg = get_smoke("deepseek-7b")
        a = SyntheticStream(cfg, global_batch=4, seq_len=8, seed=0,
                            n_hosts=2, host_id=0)
        b = SyntheticStream(cfg, global_batch=4, seq_len=8, seed=0,
                            n_hosts=2, host_id=1)
        assert a.local_batch == b.local_batch == 2
        assert not np.array_equal(a.batch_at(0)["tokens"],
                                  b.batch_at(0)["tokens"])

    def test_batch_not_divisible_rejected(self):
        cfg = get_smoke("deepseek-7b")
        with pytest.raises(ValueError):
            SyntheticStream(cfg, global_batch=3, seq_len=8, n_hosts=2)


class TestEnsembleGang:
    def test_vmap_stack_matches_per_member(self):
        from repro.train.ensemble import train_ensemble, train_members
        members = [{"args:lr": lr, "args:seed": 0, "args:arch": "gemma3-1b",
                    "args:steps": 4, "args:batch": 2, "args:seq": 16}
                   for lr in (1e-3, 3e-3)]
        a = train_members(members)
        b = train_ensemble(members)
        np.testing.assert_allclose(a, b, atol=1e-4)

    def test_heterogeneous_members_rejected(self):
        from repro.train.ensemble import train_ensemble
        members = [{"args:arch": "gemma3-1b", "args:seq": 16},
                   {"args:arch": "gemma3-1b", "args:seq": 32}]
        with pytest.raises(ValueError):
            train_ensemble(members)


class TestDonationSafety:
    def test_master_does_not_alias_fp32_params(self):
        """fp32 params: master must be a COPY or donation breaks
        (regression: 'Attempt to donate the same buffer twice')."""
        cfg = get_smoke("gemma3-1b")           # param_dtype float32
        opt = AdamW(schedule=cosine_schedule(1e-3, 2, 10))
        state = init_train_state(cfg, opt, KEY)
        step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        state, m = step(state, batch)          # would raise on aliasing
        assert bool(jnp.isfinite(m["loss"]))
