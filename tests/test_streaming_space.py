"""Indexed parameter addressing (streaming pipeline, paper §5.1).

``combo_at``/``index_of`` give every combination an O(1) mixed-radix
address; ``iter_sample`` streams the post-``sampling`` subset as indices
— the basis for studies over spaces too large to materialize.  Also
covers the study-level spec hardening that rides along: conflicting
``sampling`` blocks and conflicting per-task remote keywords now raise
instead of silently picking a winner.
"""
import itertools

import pytest

from repro.core import ParameterSpace, ParameterStudy, parse_yaml


def spaces_under_test():
    return [
        ParameterSpace(params={"a": [1, 2, 3]}),
        ParameterSpace(params={"a": [1, 2], "b": ["x", "y", "z"]}),
        ParameterSpace(params={"a": [1, 2, 3], "b": [10, 20, 30],
                               "c": [0, 1], "d": ["p", "q"]},
                       fixed=[["a", "b"]]),
        ParameterSpace(params={"a": [1, 2], "b": [3, 4], "c": [5, 6],
                               "d": [7, 8], "e": [0]},
                       fixed=[["a", "b"], ["c", "d"]]),
    ]


class TestComboAt:
    @pytest.mark.parametrize("space", spaces_under_test())
    def test_matches_enumeration_order(self, space):
        combos = list(space.combinations())
        assert [space.combo_at(i) for i in range(space.size())] == combos

    @pytest.mark.parametrize("space", spaces_under_test())
    def test_index_of_is_inverse(self, space):
        for i, combo in enumerate(space.combinations()):
            assert space.index_of(combo) == i

    def test_out_of_range(self):
        space = ParameterSpace(params={"a": [1, 2]})
        with pytest.raises(IndexError):
            space.combo_at(2)
        with pytest.raises(IndexError):
            space.combo_at(-1)

    def test_foreign_combo_rejected(self):
        space = ParameterSpace(params={"a": [1, 2]})
        with pytest.raises(ValueError):
            space.index_of({"a": 99})

    def test_no_enumeration_needed_for_huge_space(self):
        # 10^12 combinations: any materialization would hang the test
        space = ParameterSpace(
            params={c: list(range(100)) for c in "abcdef"})
        assert space.size() == 10**12
        combo = space.combo_at(987_654_321_012)
        assert space.index_of(combo) == 987_654_321_012


class TestIterSample:
    def test_no_sampling_streams_all_indices(self):
        space = ParameterSpace(params={"a": [1, 2], "b": [3, 4]})
        assert list(space.iter_sample()) == [0, 1, 2, 3]

    def test_uniform_matches_sample(self):
        space = ParameterSpace(params={"a": list(range(10))},
                               sampling={"method": "uniform", "count": 4})
        assert space.sample() == [space.combo_at(i)
                                  for i in space.iter_sample()]
        assert space.sample_count() == 4 == len(space.sample())

    def test_random_deterministic_without_replacement(self):
        space = ParameterSpace(
            params={"a": list(range(50))},
            sampling={"method": "random", "count": 7, "seed": 3})
        first = list(space.iter_sample())
        assert first == list(space.iter_sample())
        assert len(set(first)) == 7 == space.sample_count()

    def test_fraction(self):
        space = ParameterSpace(params={"a": list(range(10))},
                               sampling={"method": "uniform",
                                         "fraction": 0.3})
        assert space.sample_count() == 3
        assert len(list(space.iter_sample())) == 3

    def test_streaming_is_lazy(self):
        space = ParameterSpace(params={c: list(range(100))
                                       for c in "abcdef"})
        # grabbing a prefix of a 10^12-index stream must be instant
        head = list(itertools.islice(space.iter_sample(), 5))
        assert head == [0, 1, 2, 3, 4]

    def test_unknown_method_rejected_at_construction(self):
        # must fail before a windowed run touches journal/provenance
        with pytest.raises(ValueError, match="unknown sampling method"):
            ParameterSpace(params={"a": [1, 2]},
                           sampling={"method": "sobol", "count": 1})

    def test_space_hash_tracks_declaration(self):
        s1 = ParameterSpace(params={"a": [1, 2]})
        s2 = ParameterSpace(params={"a": [1, 2]})
        s3 = ParameterSpace(params={"a": [1, 2, 3]})
        assert s1.space_hash() == s2.space_hash() != s3.space_hash()


class TestIterInstances:
    def test_streams_what_instances_materializes(self, tmp_path):
        spec = parse_yaml("""
work:
  args:
    x: ["1:5"]
    y: [10, 20]
  sampling:
    method: uniform
    count: 6
  command: echo ${args:x} ${args:y}
""")
        study = ParameterStudy(spec, root=tmp_path, name="iter")
        pairs = list(study.iter_instances())
        assert [combo for _, combo in pairs] == study.instances()
        space = study.space()
        assert all(space.combo_at(i) == combo for i, combo in pairs)
        assert len(pairs) == study.instance_count() == 6


class TestStudySamplingValidation:
    def test_conflicting_sampling_blocks_rejected(self, tmp_path):
        spec = parse_yaml("""
first:
  args:
    x: [1, 2, 3, 4]
  sampling:
    method: uniform
    count: 2
  command: echo a
second:
  args:
    y: [1, 2]
  sampling:
    method: random
    count: 3
  command: echo b
""")
        study = ParameterStudy(spec, root=tmp_path, name="conflict")
        with pytest.raises(ValueError, match="conflicting sampling"):
            study.space()

    def test_identical_sampling_blocks_accepted(self, tmp_path):
        spec = parse_yaml("""
first:
  args:
    x: [1, 2, 3, 4]
  sampling:
    method: uniform
    count: 2
  command: echo a
second:
  args:
    y: [1, 2]
  sampling:
    method: uniform
    count: 2
  command: echo b
""")
        study = ParameterStudy(spec, root=tmp_path, name="same")
        assert study.space().sampling == {"method": "uniform", "count": 2}
        assert study.instance_count() == 2


class TestRemoteSpecDefaults:
    def test_later_task_fills_unset_keywords(self, tmp_path):
        spec = parse_yaml("""
first:
  command: echo a
second:
  hosts: [h0, h1]
  ppnode: 2
  command: echo b
""")
        study = ParameterStudy(spec, root=tmp_path, name="merge")
        d = study._remote_spec_defaults()
        assert d["hosts"] == ["h0", "h1"]
        assert d["ppnode"] == 2

    def test_conflicting_keywords_rejected(self, tmp_path):
        spec = parse_yaml("""
first:
  ppnode: 2
  command: echo a
second:
  ppnode: 4
  command: echo b
""")
        study = ParameterStudy(spec, root=tmp_path, name="clash")
        with pytest.raises(ValueError, match="conflicting remote keyword"):
            study._remote_spec_defaults()

    def test_agreeing_keywords_accepted(self, tmp_path):
        spec = parse_yaml("""
first:
  ppnode: 2
  command: echo a
second:
  ppnode: 2
  command: echo b
""")
        study = ParameterStudy(spec, root=tmp_path, name="agree")
        assert study._remote_spec_defaults()["ppnode"] == 2
