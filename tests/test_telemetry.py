"""Telemetry layer: metrics-registry semantics, Chrome-trace schema
validity (every ``B`` closed, stable tids across lane respawns), exact
retry-backoff span timings under VirtualClock, counters checked
against scheduler ground truth on a seeded chaos run, live status, and
the ``/metrics`` + ``/status`` HTTP surface."""
import io
import json
import urllib.error
import urllib.request
from pathlib import Path

import pytest

from repro.core import (
    ParameterStudy, Scheduler, TaskDAG, TaskNode, Telemetry, VirtualClock,
    VirtualPool, parse_yaml,
)
from repro.core import telemetry
from repro.core.chaos import FaultEvent, FaultPlan
from repro.core.telemetry import MetricsRegistry, TraceCollector


def assert_trace_wellformed(events):
    """Chrome-trace ``B``/``E`` stack discipline: per tid, every begin
    is closed by a matching end and depth never goes negative."""
    depth: dict[int, int] = {}
    for ev in events:
        ph = ev["ph"]
        if ph == "M":
            continue
        assert ev["pid"] == TraceCollector.PID
        tid = ev["tid"]
        if ph == "B":
            depth[tid] = depth.get(tid, 0) + 1
        elif ph == "E":
            depth[tid] = depth.get(tid, 0) - 1
            assert depth[tid] >= 0, f"E without open B on tid {tid}"
    leaks = {t: d for t, d in depth.items() if d}
    assert not leaks, f"unclosed B spans: {leaks}"


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("papas_x_total")
        c.inc()
        c.inc(2)
        assert reg.value("papas_x_total") == 3
        g = reg.gauge("papas_busy")
        g.set(5)
        g.add(-2)
        assert reg.value("papas_busy") == 3
        h = reg.histogram("papas_runtime")
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        snap = reg.value("papas_runtime")
        assert snap["count"] == 3 and snap["sum"] == 6.0
        assert snap["min"] == 1.0 and snap["max"] == 3.0
        assert 1.0 <= snap["p50"] <= 3.0

    def test_labels_families_and_handles(self):
        reg = MetricsRegistry()
        a = reg.counter("papas_retries_total", kind="error")
        b = reg.counter("papas_retries_total", kind="timeout")
        a.inc()
        a.inc()
        b.inc()
        # same (name, labels) → the same handle, not a new series
        assert reg.counter("papas_retries_total", kind="error") is a
        assert reg.value("papas_retries_total", kind="error") == 2
        assert reg.sum_values("papas_retries_total") == 3
        # an untouched series reads as 0 (status math before any event)
        assert reg.value("papas_nope_total") == 0

    def test_type_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.counter("papas_m")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("papas_m")

    def test_snapshot_is_json_serializable(self):
        reg = MetricsRegistry()
        reg.counter("papas_c_total").inc(4)
        reg.histogram("papas_h").observe(0.5)
        snap = reg.snapshot()
        doc = json.loads(json.dumps(snap))
        assert doc["papas_c_total"] == 4
        assert doc["papas_h"]["count"] == 1

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("papas_tasks_completed_total").inc(7)
        reg.counter("papas_retries_total", kind="error").inc()
        reg.histogram("papas_task_runtime_seconds").observe(0.5)
        text = reg.prometheus()
        assert "# TYPE papas_tasks_completed_total counter" in text
        assert "papas_tasks_completed_total 7" in text
        assert 'papas_retries_total{kind="error"} 1' in text
        # histograms render as summaries with quantile labels
        assert "# TYPE papas_task_runtime_seconds summary" in text
        assert 'papas_task_runtime_seconds{quantile="0.5"} 0.5' in text
        assert "papas_task_runtime_seconds_count 1" in text
        assert "papas_task_runtime_seconds_sum 0.5" in text


# ---------------------------------------------------------------------------
# trace collector
# ---------------------------------------------------------------------------

class TestTraceCollector:
    def test_schema_and_stable_tids(self):
        tr = TraceCollector()
        tr.begin("lane0", "t1", 1.0)
        tr.end("lane0", 2.0)
        tr.begin("lane1", "t2", 1.5)
        tr.end("lane1", 2.5)
        tr.begin("lane0", "t3", 3.0)    # same track name → same tid
        tr.end("lane0", 4.0)
        evs = tr.events()
        assert_trace_wellformed(evs)
        meta = [e for e in evs if e["ph"] == "M"]
        # exactly one thread_name metadata record per track, ever
        assert sorted(e["args"]["name"] for e in meta) == ["lane0", "lane1"]
        tids = {e["args"]["name"]: e["tid"] for e in meta}
        lane0 = [e for e in evs if e["ph"] == "B"
                 and e["tid"] == tids["lane0"]]
        assert [e["name"] for e in lane0] == ["t1", "t3"]
        # timestamps are seconds scaled to trace microseconds
        assert lane0[0]["ts"] == 1.0 * 1e6

    def test_async_and_instant_events(self):
        tr = TraceCollector()
        tr.async_begin("retry-wait", "n", "n#1", 1.0, args={"delay": 2.0})
        tr.async_end("retry-wait", "n", "n#1", 3.0)
        tr.instant("chaos", "kill_lane", 2.0, args={"lane": 0})
        evs = tr.events()
        b = next(e for e in evs if e["ph"] == "b")
        e = next(ev for ev in evs if ev["ph"] == "e")
        assert b["id"] == e["id"] == "n#1"
        assert b["args"]["delay"] == 2.0
        i = next(ev for ev in evs if ev["ph"] == "i")
        assert i["s"] == "t" and i["name"] == "kill_lane"

    def test_write_perfetto_document(self, tmp_path):
        tr = TraceCollector()
        tr.complete("slot0", "t", 0.0, 1.0, cat="dispatch")
        out = tr.write(tmp_path / "trace.json")
        doc = json.loads(Path(out).read_text())
        assert doc["displayTimeUnit"] == "ms"
        assert_trace_wellformed(doc["traceEvents"])
        assert any(e["ph"] == "B" for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# arming seam
# ---------------------------------------------------------------------------

class TestArming:
    def test_disarmed_by_default(self, monkeypatch):
        monkeypatch.delenv("PAPAS_TRACE", raising=False)
        monkeypatch.setattr(telemetry, "_controller", None)
        monkeypatch.setattr(telemetry, "_env_checked", False)
        assert telemetry.current() is None

    def test_env_arming_with_path(self, monkeypatch):
        monkeypatch.setattr(telemetry, "_controller", None)
        monkeypatch.setattr(telemetry, "_env_checked", False)
        monkeypatch.setenv("PAPAS_TRACE", "/tmp/papas_env/trace.json")
        tel = telemetry.current()
        assert tel is not None
        assert tel.path == "/tmp/papas_env/trace.json"

    def test_env_arming_boolean(self, monkeypatch):
        monkeypatch.setattr(telemetry, "_controller", None)
        monkeypatch.setattr(telemetry, "_env_checked", False)
        monkeypatch.setenv("PAPAS_TRACE", "1")
        tel = telemetry.current()
        assert tel is not None and tel.path is None

    def test_activated_restores_previous(self):
        prev = telemetry.current()
        tel = Telemetry()
        with telemetry.activated(tel):
            assert telemetry.current() is tel
        assert telemetry.current() is prev


# ---------------------------------------------------------------------------
# scheduler spans under VirtualClock: exact retry-backoff timings
# ---------------------------------------------------------------------------

class TestRetrySpans:
    def test_backoff_span_duration_is_exact(self):
        clock = VirtualClock()
        attempts = {"n": 0}

        def flaky(node):
            attempts["n"] += 1
            if attempts["n"] == 1:
                raise RuntimeError("transient")
            return "ok"

        tel = Telemetry()
        with telemetry.activated(tel):
            pool = VirtualPool({"t": 1.0}, clock, call_runner=True)
            sched = Scheduler(slots=1, clock=clock, max_retries=2,
                              retry_policy={"base": 2.0,
                                            "backoff": "fixed"})
            dag = TaskDAG()
            dag.add(TaskNode(id="t", task="t", combo={}, payload={}))
            results = sched.execute(dag, flaky, pool=pool)
            pool.shutdown()
        assert results["t"].status == "ok" and results["t"].attempts == 2

        evs = tel.trace.events()
        assert_trace_wellformed(evs)
        # the backoff wait is an async slice keyed by node#attempt; the
        # virtual clock jumps to the due time, so its duration is the
        # configured delay exactly (in trace microseconds)
        b = next(e for e in evs if e["ph"] == "b")
        e = next(ev for ev in evs if ev["ph"] == "e")
        assert b["id"] == e["id"] == "t#1"
        assert b["args"]["delay"] == 2.0
        assert e["ts"] - b["ts"] == pytest.approx(2.0 * 1e6)
        # both attempts are dispatch slices on the slot track, closed,
        # with the attempt number recorded at begin time
        disp = [ev for ev in evs
                if ev["ph"] == "B" and ev["cat"] == "dispatch"]
        assert [d["args"]["attempt"] for d in disp] == [1, 2]
        # the retrying gauge drained back to zero at re-queue
        assert tel.metrics.value("papas_tasks_retrying") == 0
        assert tel.metrics.sum_values("papas_retries_total") == 1


# ---------------------------------------------------------------------------
# counters vs scheduler ground truth
# ---------------------------------------------------------------------------

class TestCountersGroundTruth:
    def test_counters_match_results(self):
        clock = VirtualClock()
        calls: dict[str, int] = {}

        def runner(node):
            n = calls[node.id] = calls.get(node.id, 0) + 1
            if node.id == "flaky" and n == 1:
                raise RuntimeError("transient")
            if node.id == "doomed":
                raise RuntimeError("permanent")
            return node.id

        tel = Telemetry()
        with telemetry.activated(tel):
            pool = VirtualPool(lambda nid, k: 1.0, clock, call_runner=True)
            sched = Scheduler(slots=2, clock=clock, max_retries=2,
                              retry_policy={"base": 0.01})
            dag = TaskDAG()
            for nid in ("ok1", "ok2", "flaky", "doomed"):
                dag.add(TaskNode(id=nid, task=nid, combo={}, payload={}))
            dag.add(TaskNode(id="child", task="child", combo={},
                             deps=["doomed"], payload={}))
            results = sched.execute(dag, runner, pool=pool)
            pool.shutdown()

        by_status = {"ok": 0, "failed": 0, "skipped": 0}
        for r in results.values():
            by_status[r.status] += 1
        assert by_status == {"ok": 3, "failed": 1, "skipped": 1}

        m = tel.metrics
        assert m.value("papas_tasks_completed_total") == by_status["ok"]
        assert m.value("papas_tasks_failed_total") == by_status["failed"]
        assert m.value("papas_tasks_skipped_total") == by_status["skipped"]
        # every scheduled retry shows up in the labeled retry family
        retries = sum(max(0, r.attempts - 1) for r in results.values())
        assert retries == 3     # flaky ×1, doomed ×2
        assert m.sum_values("papas_retries_total") == retries
        assert m.value("papas_retries_total", kind="error") == retries
        # dispatches = attempts actually launched (skipped never ran)
        dispatched = sum(r.attempts for r in results.values()
                         if r.status != "skipped")
        assert m.value("papas_tasks_dispatched_total") == dispatched
        # gauges drain back to zero when the loop ends
        assert m.value("papas_tasks_running") == 0
        assert m.value("papas_tasks_retrying") == 0
        # runtime histogram observes ok completions only
        assert m.value("papas_task_runtime_seconds")["count"] \
            == by_status["ok"]


# ---------------------------------------------------------------------------
# end to end: a seeded chaos lane study through ParameterStudy.run
# ---------------------------------------------------------------------------

class TestStudyTrace:
    def _wdl(self, markers: Path, n: int = 12) -> str:
        # every instance fails its first attempt (marker-file trick:
        # `false`, not `exit 1` — the lane shell is persistent), so the
        # run produces deterministic scheduler-level retries
        return """
t:
  args:
    i: ["1:%d"]
  command: "test -e %s/t${args:i} || { : > %s/t${args:i}; false; }"
""" % (n, markers, markers)

    def test_chaos_lane_trace_and_finalize(self, tmp_path):
        markers = tmp_path / "markers"
        markers.mkdir()
        tel = Telemetry()
        plan = FaultPlan([FaultEvent("kill_lane", lane=0, after=3)])
        study = ParameterStudy(parse_yaml(self._wdl(markers)),
                               root=tmp_path, name="traced")
        results = study.run(pool="lane", slots=2, trace=tel,
                            chaos=plan.controller(), max_retries=3,
                            retry={"base": 0.01})
        assert all(r.status == "ok" for r in results.values())
        assert len(results) == 12

        evs = tel.trace.events()
        assert_trace_wellformed(evs)
        # one tid per track name even though lane 0 was killed and
        # respawned mid-run — the logical track survives the OS thread
        meta = [e for e in evs if e["ph"] == "M"]
        names = [e["args"]["name"] for e in meta]
        assert len(names) == len(set(names))
        tids = {e["args"]["name"]: e["tid"] for e in meta}
        assert tel.metrics.value("papas_lane_respawns_total") >= 1
        # the chaos firing is an instant event on the chaos track
        assert any(e["ph"] == "i" and e["tid"] == tids["chaos"]
                   for e in evs)
        assert tel.metrics.sum_values("papas_faults_total") >= 1
        # dispatch spans cover every instance (retries add more)
        disp = [e for e in evs
                if e["ph"] == "B" and e.get("cat") == "dispatch"]
        assert sum(e["args"]["tasks"] for e in disp) >= len(results)
        # one retry per instance at minimum (all first attempts fail)
        assert tel.metrics.sum_values("papas_retries_total") \
            >= len(results)
        assert all(r.attempts >= 2 for r in results.values())

        # finalize: metrics snapshot lands in study.json, the trace
        # next to it, and both agree with the results
        meta_doc = study.db.read_meta()
        snap = meta_doc["telemetry"]
        assert snap["papas_tasks_completed_total"] == len(results)
        trace_path = Path(meta_doc["trace"])
        assert trace_path.exists()
        doc = json.loads(trace_path.read_text())
        assert doc["traceEvents"] and doc["displayTimeUnit"] == "ms"

    def test_disarmed_run_records_nothing(self, tmp_path):
        study = ParameterStudy(
            parse_yaml('t:\n  args:\n    i: ["1:4"]\n  command: "true"\n'),
            root=tmp_path, name="dark")
        results = study.run(pool="lane", slots=2)
        assert all(r.status == "ok" for r in results.values())
        assert telemetry.current() is None
        assert "telemetry" not in study.db.read_meta()
        assert not (study.db.dir / "trace.json").exists()


# ---------------------------------------------------------------------------
# live status + HTTP surface
# ---------------------------------------------------------------------------

class TestStatusAndHTTP:
    def test_status_snapshot_and_eta(self):
        tel = Telemetry()
        tel.begin_run(total=10, slots=2)
        m = tel.metrics
        m.counter("papas_tasks_completed_total").inc(4)
        for _ in range(4):
            m.histogram("papas_task_runtime_seconds").observe(2.0)
        s = tel.status()
        assert s["total"] == 10 and s["done"] == 4
        # 6 remaining × 2 s median ÷ 2 slots
        assert s["eta_s"] == pytest.approx(6.0, abs=0.1)
        assert "4/10 done" in tel.status_line()

    def test_tick_redraws_in_place(self):
        tel = Telemetry()
        tel.begin_run(total=2, slots=1)
        buf = io.StringIO()
        tel.attach_status(stream=buf)
        tel.tick(force=True)
        tel.metrics.counter("papas_tasks_completed_total").inc(2)
        tel.finish_status()
        out = buf.getvalue()
        # every redraw is carriage-return + full line; the final one
        # adds the newline that keeps the shell prompt clean
        assert out.startswith("\r") and out.endswith("\n")
        assert out.count("\r") == 2 and out.count("\n") == 1
        # detached: further ticks are no-ops
        tel.tick(force=True)
        assert buf.getvalue() == out

    def test_http_metrics_and_status(self):
        tel = Telemetry()
        tel.begin_run(total=5, slots=1)
        tel.metrics.counter("papas_tasks_completed_total").inc(3)
        port = tel.serve(0)
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/metrics", timeout=5) as r:
                text = r.read().decode()
            assert "papas_tasks_completed_total 3" in text
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/status", timeout=5) as r:
                doc = json.loads(r.read().decode())
            assert doc["done"] == 3 and doc["total"] == 5
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/nope", timeout=5)
        finally:
            tel.close()
