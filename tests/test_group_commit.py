"""Group-commit durability (journal + provenance batched writers).

The contract under test: batching may defer disk writes, but (1) a run
that *raises* mid-study still flushes every completion recorded before
the failure, (2) a run killed through pool shutdown does the same, (3)
readers always see buffered entries, and (4) the amortization is real —
N appends produce far fewer than N flushes.
"""
import json

import pytest

from repro.core import (
    ParameterStudy, StudyDB, StudyJournal, WorkerPool, parse_yaml,
)

WDL = """
work:
  args:
    x: ["1:12"]
  command: noop ${args:x}
"""


def make_study(tmp_path, registry, name="gc", **kw):
    return ParameterStudy(parse_yaml(WDL), registry=registry,
                          root=tmp_path, name=name, **kw)


class TestJournalBatching:
    def test_appends_buffer_until_flush_count(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json", flush_count=4)
        for i in range(3):
            j.mark_complete(f"t@{i}")
        assert not j.log_path.exists()          # still buffered
        assert j.n_appends == 3 and j.n_flushes == 0
        j.mark_complete("t@3")                  # 4th append → group flush
        assert j.log_path.exists()
        assert j.n_flushes == 1
        assert len(j.log_path.read_text().splitlines()) == 4

    def test_readers_see_buffered_entries(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json", flush_count=100)
        j.mark_complete("t@0", host="h1")
        j.mark_complete("t@1")
        state = j.load_state()                  # nothing flushed yet
        assert state.completed == {"t@0", "t@1"}
        assert j.hosts() == {"t@0": "h1"}

    def test_flush_and_close_force_durability(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json", flush_count=100)
        j.mark_complete("t@0")
        j.flush()
        # a fresh object (≈ restarted process) sees the entry on disk
        assert StudyJournal(tmp_path / "j.json").load_state().completed \
            == {"t@0"}
        j.mark_complete("t@1")
        j.close()
        assert StudyJournal(tmp_path / "j.json").load_state().completed \
            == {"t@0", "t@1"}

    def test_group_commit_context_restores_policy(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json")   # legacy: durable per write
        with j.group_commit(flush_count=50):
            j.mark_complete("t@0")
            assert not j.log_path.exists()
        assert j.log_path.exists()              # flushed on exit
        j.mark_complete("t@1")                  # immediate again
        assert len(j.log_path.read_text().splitlines()) == 2

    def test_compaction_absorbs_buffered_entries(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json", flush_count=100)
        j.mark_complete("t@0")
        # caller folds its completed set into the base (run() semantics)
        j.save([], {"t@0"}, {})
        assert not j.log_path.exists()
        assert j.load_state().completed == {"t@0"}


class TestDBBatching:
    def test_failure_flushes_immediately(self, tmp_path):
        db = StudyDB(tmp_path, "s", flush_count=100)
        db.record("t@0", "ok", 0.1)
        assert not db.records_path.exists()     # buffered
        db.record("t@1", "failed", 0.1, error="boom")
        assert db.records_path.exists()         # failure forced the flush
        assert len(db.records_path.read_text().splitlines()) == 2

    def test_records_reader_flushes(self, tmp_path):
        db = StudyDB(tmp_path, "s", flush_count=100)
        db.record("t@0", "ok", 0.1)
        assert {r["task_id"] for r in db.records()} == {"t@0"}
        assert db.records_path.exists()


class TestSharding:
    """Sharded group commit: the stream splits over per-shard append
    segments (base + ``.s<k>``); readers union whatever exists on disk,
    so any shard layout folds back to the single-handle state."""

    def test_round_robin_over_segments(self, tmp_path):
        from repro.core.groupcommit import ShardedGroupCommit
        w = ShardedGroupCommit(tmp_path / "j.log", shards=3)
        for i in range(7):
            w.append(f"{i}\n")
        assert w.n_appends == 7
        paths = w.segment_paths()
        assert [p.name for p in paths] == ["j.log", "j.log.s1", "j.log.s2"]
        assert paths[0].read_text() == "0\n3\n6\n"
        assert paths[1].read_text() == "1\n4\n"
        assert paths[2].read_text() == "2\n5\n"

    def test_segment_glob_ignores_foreign_files(self, tmp_path):
        from repro.core.groupcommit import ShardedGroupCommit
        w = ShardedGroupCommit(tmp_path / "j.log", shards=2)
        w.append("a\n")
        w.append("b\n")
        # non-segment neighbors must not be swept into the union
        (tmp_path / "j.log.sx").write_text("junk\n")
        (tmp_path / "j.log.s1.bak").write_text("junk\n")
        assert [p.name for p in w.segment_paths()] == ["j.log", "j.log.s1"]

    def test_set_shards_flushes_dropped_writers(self, tmp_path):
        from repro.core.groupcommit import ShardedGroupCommit
        w = ShardedGroupCommit(tmp_path / "j.log", flush_count=100,
                               shards=3)
        for i in range(3):
            w.append(f"{i}\n")      # one buffered line per shard
        w.set_shards(1)             # dropped shards must flush, not lose
        on_disk = "".join(p.read_text() for p in w.segment_paths())
        union = sorted(on_disk.splitlines() + w.pending())
        assert [s.strip() for s in union] == ["0", "1", "2"]

    def test_journal_sharded_crash_resume_matches_single_handle(
            self, tmp_path):
        """Kill before compaction with 3 shards; a fresh (default,
        single-shard) journal must fold every segment to the same state
        a single-handle journal reaches."""
        sharded = StudyJournal(tmp_path / "a.json", shards=3)
        single = StudyJournal(tmp_path / "b.json")
        for j in (sharded, single):
            j.save_indexed("h", 8, {}, {})      # v2 base, no completions
        for i in range(8):
            for j in (sharded, single):
                j.mark_complete(f"w@{i}", host=f"h{i % 2}", index=i,
                                task="w")
        sharded.close()
        assert (tmp_path / "a.json.log.s2").exists()
        # fresh objects ≈ restarted process after a crash
        sa = StudyJournal(tmp_path / "a.json").load_state()
        sb = StudyJournal(tmp_path / "b.json").load_state()
        assert sa.completed == sb.completed == {f"w@{i}" for i in range(8)}
        assert sa.completed_indices == sb.completed_indices \
            == {"w": set(range(8))}
        assert sa.hosts == sb.hosts

    def test_journal_compaction_unlinks_all_segments(self, tmp_path):
        j = StudyJournal(tmp_path / "j.json", shards=2)
        for i in range(4):
            j.mark_complete(f"w@{i}", index=i, task="w")
        assert (tmp_path / "j.json.log.s1").exists()
        j.save_indexed("h", 4, {"w": {0, 1, 2, 3}}, {})
        assert not j.log_path.exists()
        assert not (tmp_path / "j.json.log.s1").exists()
        assert StudyJournal(tmp_path / "j.json").load_state() \
            .completed_indices == {"w": {0, 1, 2, 3}}

    def test_db_sharded_records_merge_by_timestamp(self, tmp_path):
        db = StudyDB(tmp_path, "sh", shards=3)
        for i in range(9):
            db.record(f"t@{i}", "ok", 0.1, index=i)
        recs = list(db.records())
        assert {r["task_id"] for r in recs} == {f"t@{i}" for i in range(9)}
        stamps = [r["timestamp"] for r in recs]
        assert stamps == sorted(stamps)     # merged stream stays ordered
        assert db.completed_indices() == {"t": set(range(9))}

    def test_db_latest_record_wins_across_segments(self, tmp_path):
        # a failed attempt and its later retry land on different shards;
        # latest-wins must survive the merge
        db = StudyDB(tmp_path, "rw", shards=2)
        db.record("t@0", "failed", 0.1, error="flaky")
        db.record("t@0", "ok", 0.1)
        assert db.completed_ids() == {"t@0"}
        by_id = {}
        for r in db.records():              # last occurrence wins
            by_id[r["task_id"]] = r
        assert by_id["t@0"]["status"] == "ok"


class _Bomb(Exception):
    pass


class TestRunRaisesMidStudy:
    def test_no_completed_entry_lost_on_raise(self, tmp_path):
        """A user on_result callback raising mid-study aborts the run;
        every completion recorded before the raise must be durable."""
        seen = []

        def boom(res):
            seen.append(res.id)
            if len(seen) == 7:
                raise _Bomb("mid-study failure")

        study = make_study(tmp_path, {"work": lambda c: 0},
                           flush_count=1000, flush_interval=None)
        with pytest.raises(_Bomb):
            study.run(on_result=boom)
        assert len(seen) == 7
        # fresh objects (≈ restarted process): all 7 completions durable
        j = StudyJournal(study.journal.path)
        assert j.load_state().completed == set(seen)
        db = StudyDB(tmp_path, "gc")
        assert db.completed_ids() == set(seen)
        # and the resumed run only executes the remainder
        ran = []
        study2 = make_study(tmp_path, {"work": lambda c: ran.append(c) or 0})
        res = study2.run(resume=True)
        assert len(ran) == 12 - 7
        assert all(r.status == "ok" for r in res.values())

    def test_windowed_raise_loses_nothing(self, tmp_path):
        seen = []

        def boom(res):
            seen.append(res.id)
            if len(seen) == 5:
                raise _Bomb

        study = make_study(tmp_path, {"work": lambda c: 0}, name="gcw",
                           flush_count=1000, flush_interval=None)
        with pytest.raises(_Bomb):
            study.run(window=2, on_result=boom)
        state = StudyJournal(study.journal.path).load_state()
        assert state.version == 2
        assert len(state.completed_indices["work"]) == 5

    def test_pool_shutdown_kill_loses_nothing(self, tmp_path):
        """A pool dying mid-run (next_event raising — e.g. the backend
        was shut down under the scheduler) propagates, and buffered
        completions still hit disk before run() raises."""

        class DyingPool(WorkerPool):
            kind = "dying"

            def __init__(self, die_after):
                self.die_after = die_after
                self._events = []
                self._served = 0

            def submit(self, token, runner, nodes):
                import time as _t
                t0 = _t.monotonic()
                values, errors = [], []
                for node in nodes:
                    values.append(runner(node))
                    errors.append(None)
                from repro.core import CompletionEvent
                self._events.append(
                    CompletionEvent(token, values, errors, t0, _t.monotonic()))

            def next_event(self, timeout=None):
                if self._served >= self.die_after:
                    raise RuntimeError("pool shut down")
                self._served += 1
                return self._events.pop(0) if self._events else None

        study = make_study(tmp_path, {"work": lambda c: 0}, name="gck",
                           flush_count=1000, flush_interval=None)
        with pytest.raises(RuntimeError, match="pool shut down"):
            study.run(pool=DyingPool(die_after=6))
        state = StudyJournal(study.journal.path).load_state()
        assert len(state.completed) == 6
        db = StudyDB(tmp_path, "gck")
        assert len(db.completed_ids()) == 6


class TestAmortization:
    def test_flushes_far_fewer_than_appends(self, tmp_path):
        study = make_study(tmp_path, {"work": lambda c: 0}, name="gca",
                           flush_count=64, flush_interval=None)
        study.run()
        assert study.journal.n_appends == 12
        assert study.db.n_appends == 12
        # 12 completions, flush_count 64 → exactly one flush each at
        # run exit (plus zero mid-run)
        assert study.journal.n_flushes <= 2
        assert study.db.n_flushes <= 2
        # post-run state identical to the unbatched world
        doc = json.loads(study.journal.path.read_text())
        assert len(doc["completed"]) == 12
